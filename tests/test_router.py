"""Router logic: benchmark table selection, Algorithm 2 (incl. fallback),
RuleRouter tree, MLP-Reg convergence, end-to-end routing quality."""

import numpy as np
import pytest

from repro.ann.predicates import Predicate
from repro.core.mlp import Scaler, train_mlp, predict, params_from_numpy, params_to_numpy
from repro.core.router import MLRouter
from repro.core.rule_router import RuleRouter
from repro.core.table import BenchmarkTable


def _toy_table():
    t = BenchmarkTable.new()
    # method A: fast but capped recall; method B: slower, high recall
    t.add("ds", 1, "A", "p1", recall=0.80, qps=1000)
    t.add("ds", 1, "A", "p2", recall=0.92, qps=400)
    t.add("ds", 1, "B", "p1", recall=0.95, qps=300)
    t.add("ds", 1, "B", "p2", recall=0.99, qps=100)
    return t


def test_table_best_qps_setting():
    t = _toy_table()
    assert t.best_qps_setting("ds", 1, "A", 0.9)[0] == "p2"
    assert t.best_qps_setting("ds", 1, "A", 0.5)[0] == "p1"
    assert t.best_qps_setting("ds", 1, "A", 0.95) is None
    assert t.max_recall_setting("ds", 1, "B")[0] == "p2"


def test_table_roundtrip(tmp_path):
    t = _toy_table()
    p = str(tmp_path / "b.json")
    t.save(p)
    t2 = BenchmarkTable.load(p)
    assert t2.entries == t.entries


def _router_with(models=None):
    return MLRouter(feature_names=["selectivity", "lid_mean", "pred"],
                    methods=["A", "B"], models=models or {},
                    scaler=Scaler(np.zeros(5), np.ones(5)),
                    table=_toy_table())


def test_algorithm2_picks_max_qps_passing():
    r = _router_with()
    r_hat = np.array([[0.95, 0.99], [0.5, 0.96], [0.3, 0.2]])
    dec = r.route_from_predictions(r_hat, "ds", Predicate.AND, t=0.9)
    # q0: both pass -> A (higher qps at its T-setting p2: 400 vs B 300)
    assert dec[0] == ("A", "p2")
    # q1: only B passes
    assert dec[1] == ("B", "p1")
    # q2: none pass -> fallback argmax r_hat = A, best setting meeting T
    assert dec[2][0] == "A"


def test_algorithm2_fallback_max_recall():
    r = _router_with()
    r_hat = np.array([[0.1, 0.05]])
    dec = r.route_from_predictions(r_hat, "ds", Predicate.AND, t=0.999)
    # no setting of A meets T=0.999 -> max-recall setting p2
    assert dec[0] == ("A", "p2")


@pytest.mark.parametrize("seed", range(8))
def test_vectorised_route_matches_loop(seed):
    """Randomized tables + r_hat: array-op Algorithm 2 must reproduce the
    per-query loop exactly, including fallback and tie-break order."""
    rng = np.random.default_rng(seed)
    methods = [f"m{j}" for j in range(int(rng.integers(2, 6)))]
    table = BenchmarkTable.new()
    for pt in range(3):
        for m in methods:
            for ps_id in ("a", "b", "c"):
                if rng.random() < 0.8:          # leave some methods sparse
                    table.add("ds", pt, m, ps_id,
                              recall=float(rng.uniform(0.5, 1.0)),
                              qps=float(rng.uniform(10, 5000)))
    r = MLRouter(feature_names=["selectivity", "lid_mean", "pred"],
                 methods=methods, models={},
                 scaler=Scaler(np.zeros(5), np.ones(5)), table=table)
    r_hat = rng.uniform(0.3, 1.05, size=(64, len(methods)))
    for pred in Predicate:
        for t in (0.7, 0.9, 0.999):
            got = r.route_from_predictions(r_hat, "ds", pred, t)
            want = r.route_from_predictions_loop(r_hat, "ds", pred, t)
            assert got == want, (pred, t)


def test_vectorised_route_unknown_dataset():
    """No table entries at all: every query falls back to argmax-r̂ with a
    None setting (deployment dataset not yet benchmarked)."""
    r = _router_with()
    r_hat = np.array([[0.95, 0.2], [0.1, 0.8]])
    dec = r.route_from_predictions(r_hat, "unknown_ds", Predicate.AND, t=0.9)
    assert dec == [("A", None), ("B", None)]


def test_predict_recalls_stacked_matches_numpy():
    """The stacked vmapped forward must agree with per-method forward_np."""
    from repro.core import mlp as mlp_mod

    rng = np.random.default_rng(0)
    x = rng.normal(size=(33, 5)).astype(np.float32)
    models = {m: params_to_numpy(train_mlp(x, x[:, 0], epochs=3, seed=j))
              for j, m in enumerate(("A", "B"))}
    r = _router_with(models)
    got = r.predict_recalls_from_features(x)
    xs = r.scaler.transform(x)
    want = np.stack([mlp_mod.forward_np(models[m], xs)[:, 0]
                     for m in ("A", "B")], axis=1)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


def test_rule_router_tree():
    rr = RuleRouter(lid_hi=40, card_lo=100)
    assert rr.route(Predicate.EQUALITY, 10, 1000) == "labelnav"
    assert rr.route(Predicate.AND, 50, 1000) == "labelnav"
    assert rr.route(Predicate.AND, 10, 50) == "labelnav"
    assert rr.route(Predicate.AND, 10, 1000) == "sieve"
    assert rr.route(Predicate.OR, 50, 50) == "labelnav"
    assert rr.route(Predicate.OR, 10, 50) == "postfilter"


def test_mlp_reg_convergence():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(512, 5)).astype(np.float32)
    y = (0.5 * x[:, 0] - 0.2 * x[:, 1] ** 2).astype(np.float32)
    params = train_mlp(x, y, hidden=(64, 32), epochs=150, seed=0)
    pred = np.asarray(predict(params, x))[:, 0]
    mse = float(((pred - y) ** 2).mean())
    assert mse < 0.05, mse


def test_mlp_classifier():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(512, 4)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.int32)
    params = train_mlp(x, y, hidden=(32, 16), n_out=2, classification=True,
                       epochs=250, seed=0)
    acc = (np.asarray(predict(params, x)).argmax(1) == y).mean()
    assert acc > 0.9


def test_router_save_load(tmp_path):
    """Versioned artifact directory round-trips weights + table."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 5)).astype(np.float32)
    models = {m: params_to_numpy(train_mlp(x, x[:, 0], epochs=5))
              for m in ("A", "B")}
    r = _router_with(models)
    p = str(tmp_path / "router")
    r.save(p)
    r2 = MLRouter.load(p)
    assert r2.table.entries == r.table.entries
    got = r2.predict_recalls_from_features(x)
    want = r.predict_recalls_from_features(x)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_router_end_to_end_tiny(tiny_ds, tiny_index, tiny_queries):
    """Router trained on the tiny dataset routes at least as well as the
    mean single method on it (served via RouterService)."""
    from repro.ann.index import QueryBatch
    from repro.ann.service import RouterService
    from repro.core import training as T
    from repro.ann.dataset import recall_at_k

    coll = T.collect({"tiny": tiny_index}, n_queries=25,
                     seed=3, verbose=False)
    router = T.train_router(coll, coll.table, epochs=60)
    svc = RouterService(tiny_index, router, t=0.9)
    qs = tiny_queries[Predicate.AND]
    res = svc.search(QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10))
    rec = recall_at_k(res.ids, qs.ground_truth).mean()
    per_method = [coll.cells[("tiny", 1)].recall[m].mean()
                  for m in T.METHOD_ORDER]
    assert rec >= np.mean(per_method) - 0.05
    assert len(res.decisions) == qs.q
    assert set(res.timings) >= {"route_s", "search_s", "total_s"}
