"""Fault-tolerance runtime: straggler monitor, preemption, elastic reshard,
token-stream resumability."""

import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import StepMonitor, PreemptionHandler, elastic_reshard
from repro.data.tokens import TokenStream


def test_step_monitor_flags_stragglers():
    m = StepMonitor(factor=2.0, escalate_after=2)
    for _ in range(8):
        m.start_step()
        m._t0 -= 0.10          # simulate a 100ms step
        assert not m.end_step()["straggler"]
    m.start_step()
    m._t0 -= 0.50              # 5x median
    s = m.end_step()
    assert s["straggler"] and not s["escalate"]
    m.start_step()
    m._t0 -= 0.50
    assert m.end_step()["escalate"]


def test_step_monitor_deadline():
    m = StepMonitor(deadline_s=0.05)
    m.start_step()
    m._t0 -= 0.01
    m.end_step()
    m.start_step()
    m._t0 -= 0.2
    assert m.end_step()["straggler"]


def test_preemption_handler():
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    try:
        assert not h.requested
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert h.requested
    finally:
        h.restore()


def test_elastic_reshard_roundtrip():
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    host = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.zeros(8, np.float32)}
    specs = {"w": P("data", "model"), "b": P(None)}
    placed = elastic_reshard(host, specs, mesh)
    assert (np.asarray(placed["w"]) == host["w"]).all()


def test_token_stream_pure_function_of_step():
    s1 = TokenStream(1000, 32, 4, seed=5)
    s2 = TokenStream(1000, 32, 4, seed=5)
    b1 = s1.batch(17)
    b2 = s2.batch(17)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (b1["targets"] == b2["targets"]).all()
    # shifted-by-one structure
    assert (b1["tokens"][:, 1:] == b1["targets"][:, :-1]).all()
    assert (s1.batch(18)["tokens"] != b1["tokens"]).any()


def test_token_stream_learnable():
    """Bigram structure: a trained smoke model beats the uniform bound."""
    from repro.configs.base import get_smoke_config
    from repro.launch.train import train_loop

    cfg = get_smoke_config("qwen2-0.5b")
    _, _, hist = train_loop(cfg, steps=30, global_batch=8, seq_len=64,
                            lr=2e-3, verbose=False)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first - 0.5, (first, last)
