"""PR-10 Prometheus exposition conformance: a strict text-format 0.0.4
parser is applied to `metrics_text()` rendered over every surface at
once — HELP/TYPE pairing and ordering, metric/label name grammar,
label-value and HELP escaping round-trips, histogram bucket cumulative
monotonicity with a terminal `+Inf` equal to `_count`, and no
duplicate samples.  Plus the serving-side contracts of
`MetricsServer`: concurrent scrape-vs-serve consistency, and the
`/healthz` endpoint degrading to HTTP 503 on queue/WAL backpressure
(formerly it answered 200 unconditionally)."""

import json
import re
import threading
import urllib.error
import urllib.request

import pytest

from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.ledger import ResourceLedger
from repro.ann.metrics import (MetricsServer, _esc, _esc_help,
                               backpressure_health, metrics_text)
from repro.ann.obslog import WideEventLog
from repro.ann.predicates import Predicate
from repro.ann.registry import candidate_methods
from repro.ann.service import RouterService
from repro.ann.slo import Objective, SLOEngine
from repro.ann.telemetry import TelemetrySink, constant_router
from repro.ann.trace import Tracer
from repro.core import features as F
from repro.core.table import BenchmarkTable
from repro.data.ann_synth import make_queries

_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_TYPES = {"counter", "gauge", "histogram", "summary", "untyped"}


def _unescape(s: str, *, help_text: bool = False) -> str:
    out, i = [], 0
    while i < len(s):
        c = s[i]
        if c == "\\":
            assert i + 1 < len(s), f"dangling backslash in {s!r}"
            n = s[i + 1]
            if n == "\\":
                out.append("\\")
            elif n == "n":
                out.append("\n")
            elif n == '"' and not help_text:
                out.append('"')
            else:
                raise AssertionError(f"invalid escape \\{n} in {s!r}")
            i += 2
        else:
            assert c != "\n"
            if not help_text:
                assert c != '"' or True
            out.append(c)
            i += 1
    return "".join(out)


def _parse_labels(body: str) -> dict:
    """Strict label-block parser: name="value" pairs, comma separated,
    escapes limited to \\\\ \\" \\n inside values."""
    labels: dict[str, str] = {}
    i = 0
    while i < len(body):
        m = re.match(r"[a-zA-Z_][a-zA-Z0-9_]*", body[i:])
        assert m, f"bad label name at {body[i:]!r}"
        name = m.group(0)
        assert name not in labels, f"duplicate label {name}"
        i += len(m.group(0))
        assert body[i] == "=", body
        assert body[i + 1] == '"', body
        i += 2
        raw = []
        while True:
            assert i < len(body), f"unterminated label value in {body!r}"
            c = body[i]
            if c == "\\":
                raw.append(body[i:i + 2])
                i += 2
            elif c == '"':
                i += 1
                break
            else:
                raw.append(c)
                i += 1
        labels[name] = _unescape("".join(raw))
        if i < len(body):
            assert body[i] == ",", f"junk after label value: {body[i:]!r}"
            i += 1
    return labels


def parse_exposition(text: str):
    """Returns (samples, helps, types); raises AssertionError on any
    conformance violation."""
    assert text.endswith("\n"), "exposition must end with a newline"
    helps: dict[str, str] = {}
    types: dict[str, str] = {}
    samples: list[tuple[str, tuple, float]] = []
    seen_keys: set[tuple] = set()
    for line in text.splitlines():
        assert line == line.strip(), f"stray whitespace: {line!r}"
        if not line:
            continue
        if line.startswith("# HELP "):
            rest = line[len("# HELP "):]
            name, _, help_ = rest.partition(" ")
            assert _NAME.match(name), name
            assert name not in helps, f"duplicate HELP for {name}"
            helps[name] = _unescape(help_, help_text=True)
            continue
        if line.startswith("# TYPE "):
            rest = line[len("# TYPE "):]
            name, _, mtype = rest.partition(" ")
            assert _NAME.match(name), name
            assert mtype in _TYPES, mtype
            assert name not in types, f"duplicate TYPE for {name}"
            assert name in helps, f"TYPE before HELP for {name}"
            types[name] = mtype
            continue
        assert not line.startswith("#"), f"unknown comment: {line!r}"
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{(.*)\})? (\S+)$",
                     line)
        assert m, f"unparseable sample: {line!r}"
        name, _, lab_body, value = m.groups()
        labels = _parse_labels(lab_body) if lab_body else {}
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        family = base if base in types else name
        assert family in types, f"sample {name} without TYPE"
        assert family in helps, f"sample {name} without HELP"
        if value in ("+Inf", "-Inf", "NaN"):
            val = float(value.replace("Inf", "inf"))
        else:
            val = float(value)
        key = (name, tuple(sorted(labels.items())))
        assert key not in seen_keys, f"duplicate sample {key}"
        seen_keys.add(key)
        samples.append((name, tuple(sorted(labels.items())), val))
    return samples, helps, types


def _check_histograms(samples, types):
    """Cumulative bucket monotonicity and +Inf == _count per series."""
    hist_families = {n for n, t in types.items() if t == "histogram"}
    checked = 0
    for fam in hist_families:
        series: dict[tuple, list] = {}
        counts: dict[tuple, float] = {}
        for name, labels, val in samples:
            lab = dict(labels)
            grouping = tuple(sorted((k, v) for k, v in lab.items()
                                    if k != "le"))
            if name == f"{fam}_bucket":
                series.setdefault(grouping, []).append((lab["le"], val))
            elif name == f"{fam}_count":
                counts[grouping] = val
        for grouping, buckets in series.items():
            vals = [v for _le, v in buckets]      # exposition order
            assert vals == sorted(vals), f"non-cumulative {fam}"
            assert buckets[-1][0] == "+Inf", f"{fam} missing +Inf"
            assert buckets[-1][1] == counts[grouping], \
                f"{fam}: +Inf bucket != _count"
            checked += 1
    return checked


# ---------------------------------------------------------- unit: escaping


def test_label_and_help_escaping_round_trip():
    tricky = 'sla\\sh "quote"\nnewline'
    assert _unescape(_esc(tricky)) == tricky
    assert _unescape(_esc_help(tricky), help_text=True) == tricky
    led = ResourceLedger()
    led.register_collector(tricky, lambda: {"v": 1})
    samples, helps, _ = parse_exposition(metrics_text(ledger=led))
    sources = [dict(lab)["source"] for n, lab, _v in samples
               if n == "ann_ledger_gauge"]
    assert sources == [tricky]                    # exact round-trip


def test_help_text_newline_is_escaped_on_the_wire():
    from repro.ann.metrics import _Writer
    w = _Writer()
    w.header("m_total", "counter", 'line one\nline "two" \\ three')
    w.sample("m_total", None, 1)
    text = w.text()
    # the embedded newline must be escaped, not split the HELP line
    assert len(text.splitlines()) == 3            # HELP, TYPE, sample
    _, helps, _ = parse_exposition(text)
    assert helps["m_total"] == 'line one\nline "two" \\ three'


# ---------------------------------------- full-surface strict conformance


def _two_method_table(ds_name):
    cand = candidate_methods()
    table = BenchmarkTable.new()
    for pt in range(3):
        for s in cand["ivf_gamma"].param_settings():
            table.add(ds_name, pt, "ivf_gamma", s.ps_id, 0.97, 5000.0)
        for s in cand["postfilter"].param_settings():
            table.add(ds_name, pt, "postfilter", s.ps_id, 0.95, 500.0)
    return table


@pytest.fixture()
def observed_service(tiny_ds, tmp_path):
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"],
                             _two_method_table(tiny_ds.name))
    sink = TelemetrySink(capacity=256, reservoir=32, seed=5)
    tracer = Tracer(slow_ms=0.0, sample=1.0, flight_capacity=8, seed=9)
    slo = SLOEngine([Objective(name="lat", kind="latency", target=0.99,
                               threshold_us=5e6)], min_events=1,
                    tracer=tracer)
    led = ResourceLedger()
    led.acquire("pin", "tiny", bytes=64)
    with FilteredIndex(tiny_ds) as fx, \
            WideEventLog(str(tmp_path / "ev.jsonl")) as log:
        svc = RouterService(fx, router, t=0.9, telemetry=sink,
                            tracer=tracer, slo=slo, obslog=log)
        qs = make_queries(tiny_ds, Predicate.AND, 8, seed=3)
        batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 5)
        svc.search(batch)
        yield svc, led, batch


def test_full_surface_exposition_is_conformant(observed_service):
    svc, led, _batch = observed_service
    text = metrics_text(service=svc, ledger=led)
    samples, helps, types = parse_exposition(text)
    assert _check_histograms(samples, types) >= 1   # span histograms
    names = {n for n, _l, _v in samples}
    for expected in ("ann_queries_total", "ann_traces_total",
                     "ann_span_latency_us_bucket", "ann_ledger_leases_held",
                     "ann_slo_firing", "ann_obslog_events_total"):
        assert expected in names, f"missing {expected}"
    # counters end in _total per convention (ledger gauges excepted)
    for fam, t in types.items():
        if t == "counter" and fam != "ann_counter":
            assert fam.endswith("_total"), fam


def test_exposition_has_no_duplicate_samples_under_traffic(
        observed_service):
    svc, led, batch = observed_service
    for _ in range(3):
        svc.search(batch)
    samples, _h, _t = parse_exposition(metrics_text(service=svc,
                                                    ledger=led))
    keys = [(n, l) for n, l, _v in samples]
    assert len(keys) == len(set(keys))


def test_concurrent_scrape_vs_serve_race(observed_service):
    """Scrapes taken while the serve path mutates every surface must
    all parse strictly — torn reads would show as grammar violations
    or non-cumulative histograms."""
    svc, led, batch = observed_service
    srv = MetricsServer(lambda: metrics_text(service=svc, ledger=led),
                        ledger=led, slo=svc.slo, obslog=svc.obslog)
    stop = threading.Event()
    errors: list[BaseException] = []

    def serve_loop():
        try:
            while not stop.is_set():
                svc.search(batch)
        except BaseException as e:     # surfaced in the main thread
            errors.append(e)

    t = threading.Thread(target=serve_loop, daemon=True)
    t.start()
    try:
        for _ in range(10):
            body = urllib.request.urlopen(srv.url + "/metrics",
                                          timeout=10).read().decode()
            samples, _h, types = parse_exposition(body)
            _check_histograms(samples, types)
            for route in ("/statusz", "/debug/ledger", "/debug/slo"):
                payload = json.loads(urllib.request.urlopen(
                    srv.url + route, timeout=10).read())
                assert isinstance(payload, dict)
    finally:
        stop.set()
        t.join(timeout=30)
        srv.close()
    assert not errors


def test_online_table_shard_cells_export_per_shard(tiny_ds):
    from repro.ann.telemetry import OnlineBenchmarkTable
    ot = OnlineBenchmarkTable(_two_method_table(tiny_ds.name))
    ot.observe_shard(tiny_ds.name, 0, qps=1000.0)
    ot.observe_shard(tiny_ds.name, 1, qps=250.0)
    samples, _h, types = parse_exposition(metrics_text(table=ot))
    assert types["ann_table_shard_qps"] == "gauge"
    qps = {dict(lab)["shard"]: v for n, lab, v in samples
           if n == "ann_table_shard_qps"}
    assert set(qps) == {"0", "1"}           # one series per shard
    assert qps["0"] == pytest.approx(1000.0)
    div = [v for n, _l, v in samples if n == "ann_table_shard_divergence"]
    assert div == [pytest.approx(4.0)]
    # service introspection reaches the table behind the router
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"], ot)
    svc = type("S", (), {"router": router, "telemetry": None,
                         "tracer": None, "slo": None, "obslog": None})()
    names = {n for n, _l, _v in
             parse_exposition(metrics_text(service=svc))[0]}
    assert "ann_table_shard_qps" in names


# ------------------------------------------------- healthz backpressure


class _FakeQueue:
    def __init__(self, pending):
        self.pending = pending

    def stats(self):
        return {"pending": self.pending}


class _FakeWAL:
    def __init__(self, records=0, bytes=0):
        self._bl = {"records": records, "bytes": bytes}

    def backlog(self):
        return self._bl


def _get(url):
    try:
        r = urllib.request.urlopen(url, timeout=10)
        return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_healthz_degrades_on_queue_backpressure():
    q = _FakeQueue(pending=0)
    health = backpressure_health(queue=q, queue_high_water=4)
    with MetricsServer(lambda: "ann_up 1\n", health=health) as srv:
        code, payload = _get(srv.url + "/healthz")
        assert code == 200 and payload["status"] == "ok"
        q.pending = 100                  # saturated, no exception raised
        code, payload = _get(srv.url + "/healthz")
        assert code == 503
        assert payload["status"] == "degraded"
        assert any("queue_pending" in r for r in payload["reasons"])


def test_healthz_degrades_on_wal_fsync_backlog():
    wal = _FakeWAL()
    health = backpressure_health(wal=wal, wal_records_max=10,
                                 wal_bytes_max=1000)
    with MetricsServer(lambda: "ann_up 1\n", health=health) as srv:
        assert _get(srv.url + "/healthz")[0] == 200
        wal._bl = {"records": 11, "bytes": 0}
        code, payload = _get(srv.url + "/healthz")
        assert code == 503 and "reasons" in payload
        wal._bl = {"records": 0, "bytes": 2000}
        assert _get(srv.url + "/healthz")[0] == 503
        wal._bl = {"records": 0, "bytes": 0}
        assert _get(srv.url + "/healthz")[0] == 200   # recovers


def test_healthz_still_degrades_on_exception():
    def health():
        raise RuntimeError("probe exploded")
    with MetricsServer(lambda: "ann_up 1\n", health=health) as srv:
        code, payload = _get(srv.url + "/healthz")
        assert code == 503 and payload["status"] == "degraded"


def test_debug_endpoints_404_without_handles():
    with MetricsServer(lambda: "ann_up 1\n") as srv:
        assert _get(srv.url + "/debug/ledger")[0] == 404
        assert _get(srv.url + "/debug/slo")[0] == 404
        code, payload = _get(srv.url + "/statusz")
        assert code == 200 and payload["health"]["status"] == "ok"
