"""Checkpoint save/restore: bitwise fidelity, rotation, async, and
mid-training resume equivalence (the fault-tolerance contract)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_pytree, restore_pytree


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": (jnp.ones((3,), jnp.bfloat16),
                             jnp.zeros((), jnp.float32))}}


def test_save_restore_bitwise(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path / "ck"), t, metadata={"step": 7})
    r = restore_pytree(str(tmp_path / "ck"), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert (np.asarray(a) == np.asarray(b)).all()


def test_restore_shape_mismatch(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path / "ck"), t)
    bad = dict(t)
    bad["a"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="mismatch"):
        restore_pytree(str(tmp_path / "ck"), bad)


def test_manager_rotation_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_n=2)
    t = _tree()
    for step in (5, 10, 15):
        m.save(step, t)
    assert m.steps() == [10, 15]
    assert m.latest_step() == 15
    r, meta = m.restore(t)
    assert meta["step"] == 15


def test_manager_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_n=3)
    t = _tree()
    m.save(1, t, background=True)
    m.wait()
    assert m.latest_step() == 1


def test_atomicity_tmpdir_cleanup(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(3, _tree())
    assert not any(x.endswith(".tmp") for x in os.listdir(tmp_path))


def test_training_resume_bitwise(tmp_path):
    """Interrupted-and-resumed training == uninterrupted training."""
    from repro.configs.base import get_smoke_config
    from repro.launch.train import train_loop

    cfg = get_smoke_config("internlm2-1.8b")
    # uninterrupted 6 steps
    p_full, _, _ = train_loop(cfg, steps=6, global_batch=4, seq_len=32,
                              verbose=False)
    # 3 steps + checkpoint, then resume to 6
    d = str(tmp_path / "ck")
    train_loop(cfg, steps=3, global_batch=4, seq_len=32, ckpt_dir=d,
               save_every=3, verbose=False)
    p_res, _, hist = train_loop(cfg, steps=6, global_batch=4, seq_len=32,
                                ckpt_dir=d, save_every=100, verbose=False)
    assert hist[0]["step"] == 4          # resumed from step 3
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
