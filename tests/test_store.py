"""The durable storage subsystem: segment files, the write-ahead log,
manifest-committed generations, stable external keys, and crash
recovery (torn WAL tails, kill-mid-checkpoint, replay-vs-clean-save
equivalence)."""

import json
import os

import numpy as np
import pytest

from repro.ann.dataset import ANNDataset
from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.live import LiveFilteredIndex, ShardedLiveIndex
from repro.ann.predicates import Predicate, eval_predicate_np
from repro.ann.service import RouterService
from repro.ann.store import IndexStore, WriteAheadLog

ALL_PREDS = (Predicate.EQUALITY, Predicate.AND, Predicate.OR)


def _assert_same_result(res, want):
    np.testing.assert_array_equal(res.ids, want.ids)
    np.testing.assert_allclose(res.distances, want.distances,
                               rtol=1e-5, atol=1e-5, equal_nan=True)
    np.testing.assert_array_equal(res.keys, want.keys)


def _mixed_ops(live, ds, rng):
    """A deterministic upsert/delete mix (returns the new ids)."""
    new_v = ds.vectors[:90] + np.float32(0.01)
    ids_a = live.upsert(new_v[:50], ds.bitmaps[:50])
    live.delete(ids_a[::7])
    live.delete(np.arange(0, 30, 5))          # base tombstones
    ids_b = live.upsert(new_v[50:], ds.bitmaps[50:90])
    live.delete(ids_b[:3])
    return np.concatenate([ids_a, ids_b])


def _live_oracle(vectors, bitmaps, tomb, qv, qb, pred, k):
    """Exact masked top-k ids over an explicit (rows, tombstones) state."""
    norms = np.sum(vectors.astype(np.float64) ** 2, axis=1)
    out = np.full((qv.shape[0], k), -1, np.int32)
    for qi in range(qv.shape[0]):
        ok = eval_predicate_np(bitmaps, qb[qi][None], pred) & ~tomb
        idx = np.nonzero(ok)[0]
        if not idx.size:
            continue
        d = norms[idx] - 2.0 * vectors[idx] @ qv[qi].astype(np.float64)
        o = np.argsort(d, kind="stable")[:k]
        out[qi, : o.size] = idx[o]
    return out


# ---------------------------------------------------------------------------
# segment files
# ---------------------------------------------------------------------------

def test_segment_roundtrip_memmap(tmp_path, tiny_ds):
    seg = str(tmp_path / "seg")
    meta = tiny_ds.save_segment(seg)
    assert meta["n"] == tiny_ds.n and meta["files"]["vectors"]["sha1"]
    ds2 = ANNDataset.load_segment(seg)                  # memmap'd
    assert isinstance(ds2.vectors, np.memmap)
    np.testing.assert_array_equal(ds2.vectors, tiny_ds.vectors)
    np.testing.assert_array_equal(ds2.bitmaps, tiny_ds.bitmaps)
    np.testing.assert_array_equal(ds2.group_start, tiny_ds.group_start)
    assert ds2.group_lookup == tiny_ds.group_lookup
    # verify=True passes on an intact segment
    ANNDataset.load_segment(seg, verify=True)


def test_segment_detects_corruption(tmp_path, tiny_ds):
    seg = str(tmp_path / "seg")
    tiny_ds.save_segment(seg)
    vec = os.path.join(seg, "vectors.npy")
    with open(vec, "r+b") as f:                         # size-preserving flip
        f.seek(os.path.getsize(vec) - 4)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(ValueError, match="sha1"):
        ANNDataset.load_segment(seg, verify=True)
    with open(vec, "ab") as f:                          # size change
        f.write(b"x")
    with pytest.raises(ValueError, match="bytes"):
        ANNDataset.load_segment(seg)


# ---------------------------------------------------------------------------
# write-ahead log
# ---------------------------------------------------------------------------

def test_wal_record_roundtrip(tmp_path, rng):
    p = str(tmp_path / "w.log")
    wal = WriteAheadLog.create(p, dim=4, width=2, generation=3)
    vec = rng.normal(size=(5, 4)).astype(np.float32)
    bm = rng.integers(0, 2 ** 16, size=(5, 2)).astype(np.uint32)
    keys = np.arange(100, 105, dtype=np.int64)
    wal.log_upsert(3, keys, vec, bm)
    wal.log_delete(3, np.array([7, 9], np.int64))
    wal.log_compact(3)
    wal.close()
    recs = WriteAheadLog.replay(p, dim=4, width=2)
    assert [r.kind for r in recs] == ["upsert", "delete", "compact"]
    np.testing.assert_array_equal(recs[0].keys, keys)
    np.testing.assert_array_equal(recs[0].vectors, vec)
    np.testing.assert_array_equal(recs[0].bitmaps, bm)
    np.testing.assert_array_equal(recs[1].ids, [7, 9])
    assert recs[2].gen == 3
    # dim/width mismatch refuses to replay
    with pytest.raises(ValueError, match="dim"):
        WriteAheadLog.replay(p, dim=8, width=2)


@pytest.mark.parametrize("cut", [1, 10, 20])
def test_wal_torn_tail_truncates_to_last_good_record(tmp_path, rng, cut):
    p = str(tmp_path / "w.log")
    wal = WriteAheadLog.create(p, dim=4, width=1, generation=0)
    for i in range(3):
        wal.log_upsert(0, np.array([i], np.int64),
                       rng.normal(size=(1, 4)).astype(np.float32),
                       np.ones((1, 1), np.uint32))
    wal.close()
    size = os.path.getsize(p)
    with open(p, "r+b") as f:
        f.truncate(size - cut)                         # tear mid-record
    recs = WriteAheadLog.replay(p, dim=4, width=1)
    assert len(recs) == 2                              # last record dropped
    # truncation repaired the file: appends after recovery stay readable
    wal = WriteAheadLog.open_append(p, dim=4, width=1)
    wal.log_delete(0, np.array([0], np.int64))
    wal.close()
    recs = WriteAheadLog.replay(p, dim=4, width=1)
    assert [r.kind for r in recs] == ["upsert", "upsert", "delete"]


def test_wal_sync_every_batches_fsync(tmp_path):
    wal = WriteAheadLog.create(str(tmp_path / "w.log"), dim=2, width=1,
                               generation=0, sync_every=8)
    for _ in range(5):
        seq = wal.log_delete(0, np.array([0], np.int64))
        wal.commit(seq)
    assert wal._durable_seq == 0                       # still batched
    for _ in range(3):
        seq = wal.log_delete(0, np.array([0], np.int64))
        wal.commit(seq)
    assert wal._durable_seq == 8                       # batch flushed
    wal.sync()
    assert wal._durable_seq == wal._seq == 8
    wal.close()


def test_wal_group_commit_ack_after_fsync(tmp_path):
    """sync_every=1: commit() makes the record durable before returning,
    and a single leader fsync covers every record appended before it."""
    wal = WriteAheadLog.create(str(tmp_path / "w.log"), dim=2, width=1,
                               generation=0, sync_every=1)
    seqs = [wal.log_delete(0, np.array([i], np.int64)) for i in range(4)]
    wal.commit(seqs[-1])                               # leader covers all
    assert wal._durable_seq == 4
    for s in seqs:                                     # followers: no fsync
        wal.commit(s)
    assert wal._durable_seq == 4
    wal.close()


# ---------------------------------------------------------------------------
# the acceptance bar: save → reopen is bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pred", ALL_PREDS)
def test_roundtrip_bit_identical_to_never_persisted(tmp_path, tiny_ds,
                                                    tiny_queries, rng,
                                                    pred):
    """build → upsert/delete mix → save → reopen equals the
    never-persisted index exactly: ids, distances and keys, for every
    predicate."""
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    with LiveFilteredIndex(tiny_ds) as ref:
        _mixed_ops(ref, tiny_ds, rng)
        want = ref.search(batch, "prefilter")
        with IndexStore.create(str(tmp_path / "s"),
                               LiveFilteredIndex(tiny_ds)) as st:
            _mixed_ops(st.index, tiny_ds, rng)
            _assert_same_result(st.index.search(batch, "prefilter"), want)
        with IndexStore.open(str(tmp_path / "s")) as st2:
            _assert_same_result(st2.index.search(batch, "prefilter"), want)


def test_wal_replayed_equals_clean_checkpoint(tmp_path, tiny_ds,
                                              tiny_queries, rng):
    """A store recovered purely from WAL replay equals one that
    checkpointed cleanly after the same operations."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    for name, clean in (("dirty", False), ("clean", True)):
        with IndexStore.create(str(tmp_path / name),
                               LiveFilteredIndex(tiny_ds)) as st:
            _mixed_ops(st.index, tiny_ds, rng)
            if clean:
                st.checkpoint()
    with IndexStore.open(str(tmp_path / "dirty")) as a, \
            IndexStore.open(str(tmp_path / "clean")) as b:
        assert a.stats()["replayed_records"] > 0
        # the clean store's WAL holds only the checkpoint-seeded residual
        # delta, fewer records than the dirty store's full op history
        assert 0 < b.stats()["replayed_records"] \
            < a.stats()["replayed_records"]
        _assert_same_result(a.index.search(batch, "prefilter"),
                            b.index.search(batch, "prefilter"))
        np.testing.assert_array_equal(a.index._keys, b.index._keys)


@pytest.mark.parametrize("pred", ALL_PREDS)
def test_recover_then_search_equals_live_oracle(tmp_path, tiny_ds,
                                               tiny_queries, rng, pred):
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    with IndexStore.create(str(tmp_path / "s"),
                           LiveFilteredIndex(tiny_ds)) as st:
        new_ids = _mixed_ops(st.index, tiny_ds, rng)
        snap = st.index.snapshot()
        rows_v = np.concatenate([tiny_ds.vectors,
                                 tiny_ds.vectors[:90] + np.float32(0.01)])
        rows_b = np.concatenate([tiny_ds.bitmaps, tiny_ds.bitmaps[:90]])
        tomb = snap.tombstones.copy()
        snap.release()
    with IndexStore.open(str(tmp_path / "s")) as st2:
        res = st2.index.search(batch, "prefilter")
        want = _live_oracle(rows_v, rows_b, tomb, qs.vectors, qs.bitmaps,
                            pred, 10)
        np.testing.assert_array_equal(res.ids, want)
        assert new_ids.size                            # ops really ran


def test_stable_keys_across_upsert_compact_reopen(tmp_path, tiny_ds,
                                                  tiny_queries, rng):
    """The PR-4 follow-up: client-visible keys survive compaction AND
    restart, while row ids get remapped underneath."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    with IndexStore.create(str(tmp_path / "s"),
                           LiveFilteredIndex(tiny_ds)) as st:
        _mixed_ops(st.index, tiny_ds, rng)
        before = st.index.search(batch, "prefilter")
        vec_of_key = {}                    # what each key pointed at
        for key, rid in zip(before.keys.ravel(), before.ids.ravel()):
            if rid >= 0:
                vec_of_key[int(key)] = st.index.fetch([rid])[0].copy()
        st.index.compact()
        after = st.index.search(batch, "prefilter")
        np.testing.assert_array_equal(after.keys, before.keys)
        assert not np.array_equal(after.ids, before.ids)   # ids remapped
    with IndexStore.open(str(tmp_path / "s")) as st2:
        again = st2.index.search(batch, "prefilter")
        np.testing.assert_array_equal(again.keys, before.keys)
        # and every key still resolves to the same vector
        for key, vec in vec_of_key.items():
            row = st2.index.rows_of([key])[0]
            assert row >= 0
            np.testing.assert_allclose(st2.index.fetch([row])[0], vec,
                                       rtol=1e-6)


def test_kill_mid_compaction_recovers_old_generation(tmp_path, tiny_ds,
                                                     tiny_queries, rng,
                                                     monkeypatch):
    """A crash after the new segment is written but before the manifest
    rename must leave the store serving the old generation (plus WAL),
    and `open()` sweeps the orphaned segment directory."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    # never-persisted reference that compacts the same state
    with LiveFilteredIndex(tiny_ds) as ref:
        _mixed_ops(ref, tiny_ds, rng)
        ref.compact()
        want = ref.search(batch, "prefilter")
    st = IndexStore.create(str(tmp_path / "s"), LiveFilteredIndex(tiny_ds))
    _mixed_ops(st.index, tiny_ds, rng)
    pre_crash = st.index.search(batch, "prefilter")

    class Boom(Exception):
        pass

    def crash(self, manifest):
        raise Boom()

    monkeypatch.setattr(IndexStore, "_commit_manifest", crash)
    with pytest.raises(Boom):
        st.compact()                       # live compact ok, commit "dies"
    monkeypatch.undo()
    seg_root = str(tmp_path / "s" / "segments")
    # an in-process failure cleans its own half-written files (no leak,
    # and the pinned snapshot was released)...
    assert len(os.listdir(seg_root)) == 1
    assert st._index.stats()["retired_generations"] == []
    st._wal.close()
    st._index.close()
    # ...while a hard kill leaves debris on disk — plant it and check
    # open() sweeps everything the manifest does not reference
    import shutil as _sh
    _sh.copytree(os.path.join(seg_root, os.listdir(seg_root)[0]),
                 os.path.join(seg_root, "gen-000099"))
    with open(str(tmp_path / "s" / "wal" / "wal-000099.log"), "wb") as f:
        f.write(b"debris")
    with IndexStore.open(str(tmp_path / "s")) as st2:
        assert len(os.listdir(seg_root)) == 1          # orphan swept
        assert os.listdir(str(tmp_path / "s" / "wal")) == \
            [os.path.basename(st2.manifest["wal"])]
        with open(str(tmp_path / "s" / "MANIFEST.json")) as f:
            assert json.load(f)["store_generation"] == 0
        # the WAL's compact barrier replays the compaction, so recovered
        # state is bit-identical to the reference that compacted the
        # same ops — and the stable keys match what clients saw before
        # the crash
        res = st2.index.search(batch, "prefilter")
        _assert_same_result(res, want)
        np.testing.assert_array_equal(res.keys, pre_crash.keys)
        assert st2.index.generation == 1


def test_replay_translates_deletes_of_rows_upserted_during_compaction(
        tmp_path, tiny_ds, tiny_queries):
    """Ops that raced a compaction: an upsert after the barrier's
    snapshot and a delete of that very row, both logged at the old
    generation. Replay must translate the delete to the row's new-delta
    id (it is past the remap's range), not crash or drop it."""
    from repro.ann.store import WriteAheadLog

    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    p = str(tmp_path / "s")
    st = IndexStore.create(p, LiveFilteredIndex(tiny_ds))
    n0 = st.index.n_total
    next_key = st.index.stats()["next_key"]
    wal_path = os.path.join(p, st.manifest["wal"])
    st.close()
    # splice the race into the log by hand (deterministic interleaving):
    # barrier, then a tail upsert and its delete, all at generation 0
    wal = WriteAheadLog.open_append(wal_path, dim=tiny_ds.dim,
                                    width=tiny_ds.bitmaps.shape[1])
    wal.log_compact(0)
    wal.log_upsert(0, np.array([next_key], np.int64),
                   tiny_ds.vectors[:1] + np.float32(0.5),
                   tiny_ds.bitmaps[:1])
    wal.log_delete(0, np.array([n0], np.int64))     # the tail row's id
    wal.close()
    with IndexStore.open(p) as st2:
        assert st2.index.generation == 1
        assert st2.index.n_live == tiny_ds.n        # tail row is dead
        res = st2.index.search(batch, "prefilter")
        want = FilteredIndex(tiny_ds).search(batch, "prefilter")
        np.testing.assert_array_equal(res.ids, want.ids)


def test_replay_tail_delete_when_compaction_collapses_below_shards(
        tmp_path, tiny_ds):
    """Degenerate sharded compaction: survivors < shard count, so the
    replayed compact puts them back as delta (base_n = 0). A raced
    delete of a tail row must still land on the tail row — not on a
    survivor (which would silently vanish a live vector)."""
    from repro.ann.store import WriteAheadLog

    p = str(tmp_path / "s")
    st = IndexStore.create(p, ShardedLiveIndex(tiny_ds, 2))
    st.index.delete(np.arange(tiny_ds.n - 1))     # one survivor: last row
    survivor_key = tiny_ds.n - 1
    next_key = st.index.stats()["next_key"]
    wal_path = os.path.join(p, st.manifest["wal"])
    st.close()
    wal = WriteAheadLog.open_append(wal_path, dim=tiny_ds.dim,
                                    width=tiny_ds.bitmaps.shape[1])
    wal.log_compact(0)                            # barrier at gen 0
    wal.log_upsert(0, np.array([next_key], np.int64),
                   tiny_ds.vectors[:1] + np.float32(0.5),
                   tiny_ds.bitmaps[:1])           # tail row, old-gen id n
    wal.log_delete(0, np.array([tiny_ds.n], np.int64))
    wal.close()
    with IndexStore.open(p) as st2:
        assert st2.index.generation == 1
        assert st2.index.n_live == 1              # survivor, not the tail
        assert st2.index.rows_of([survivor_key])[0] >= 0
        # the one live row must still be the survivor's vector
        probe = QueryBatch(tiny_ds.vectors[-1:], tiny_ds.bitmaps[-1:],
                           Predicate.AND, 1)
        res = st2.index.search(probe, "prefilter")
        assert res.keys[0, 0] == survivor_key
        np.testing.assert_allclose(res.distances[0, 0], 0.0, atol=1e-3)


def test_wal_midlog_corruption_refuses_truncation(tmp_path):
    p = str(tmp_path / "w.log")
    wal = WriteAheadLog.create(p, dim=2, width=1, generation=0)
    for i in range(3):
        wal.log_delete(0, np.array([i], np.int64))
    wal.close()
    size = os.path.getsize(p)
    with open(p, "r+b") as f:                     # flip a byte mid-log
        f.seek(24 + 21 + 4)                       # inside record 0 payload
        b = f.read(1)
        f.seek(24 + 21 + 4)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(ValueError, match="mid-log corruption"):
        WriteAheadLog.replay(p, dim=2, width=1)
    assert os.path.getsize(p) == size             # nothing was truncated


def test_router_content_swap_detected(tmp_path, tiny_ds, toy_router):
    """Same format versions but re-saved content (a re-trained router /
    swapped table) must also fail validation, naming the digests."""
    rdir = str(tmp_path / "router")
    toy_router.save(rdir)
    store_dir = str(tmp_path / "s")
    IndexStore.create(store_dir, LiveFilteredIndex(tiny_ds),
                      router_dir=rdir).close()
    # re-train: same artifact format, different weights/table content
    toy_router.table.add(tiny_ds.name, 0, toy_router.methods[0],
                         "swapped", recall=0.5, qps=1.0)
    toy_router.save(rdir)
    with pytest.raises(ValueError, match="content changed"):
        IndexStore.open(store_dir)
    with IndexStore.open(store_dir, router_dir=rdir) as st:   # re-link
        assert st.load_router() is not None


@pytest.mark.parametrize("n_shards", [2, 3])
def test_sharded_roundtrip_and_compact(tmp_path, tiny_ds, tiny_queries,
                                       rng, n_shards):
    qs = tiny_queries[Predicate.OR]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.OR, 10)
    with ShardedLiveIndex(tiny_ds, n_shards) as ref:
        _mixed_ops(ref, tiny_ds, rng)
        want = ref.search(batch, "prefilter")
        with IndexStore.create(str(tmp_path / "s"),
                               ShardedLiveIndex(tiny_ds, n_shards)) as st:
            _mixed_ops(st.index, tiny_ds, rng)
        with IndexStore.open(str(tmp_path / "s")) as st2:
            assert st2.index.n_shards == n_shards
            _assert_same_result(st2.index.search(batch, "prefilter"), want)
            ref.compact()
            st2.compact()
            want2 = ref.search(batch, "prefilter")
            _assert_same_result(st2.index.search(batch, "prefilter"),
                                want2)
        # reopen the compacted generation
        with IndexStore.open(str(tmp_path / "s")) as st3:
            _assert_same_result(st3.index.search(batch, "prefilter"),
                                want2)


# ---------------------------------------------------------------------------
# built indexes, router stamps, keys surface
# ---------------------------------------------------------------------------

def test_built_indexes_rebuilt_on_load(tmp_path, tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    with IndexStore.create(str(tmp_path / "s"),
                           LiveFilteredIndex(tiny_ds)) as st:
        want = st.index.search(batch, "ivf_gamma")
        st.index.search(batch, "labelnav")
        st.checkpoint()
        built = {b[0]: b[2] for b in st.manifest["built"]}
        assert built["ivf_gamma"] is not None          # persisted as npz
    with IndexStore.open(str(tmp_path / "s")) as st2:
        assert sorted(k[0] for k in st2.index.built_keys()) == \
            ["ivf_gamma", "labelnav"]
        _assert_same_result(st2.index.search(batch, "ivf_gamma"), want)


def test_sharded_built_indexes_restored_without_rebuild(tmp_path, tiny_ds,
                                                        tiny_queries,
                                                        monkeypatch):
    """PR-6: per-shard method indexes persist as one npz per shard and
    come back through `index_from_arrays` on open — zero offline builds
    — with search results identical to the pre-restart handle."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    with IndexStore.create(str(tmp_path / "s"),
                           ShardedLiveIndex(tiny_ds, 2)) as st:
        want = st.index.search(batch, "ivf_gamma")
        st.checkpoint()
        built = {b[0]: b[2] for b in st.manifest["built"]}
        files = built["ivf_gamma"]
        assert isinstance(files, list) and len(files) == 2
        assert all(files)                          # one npz per shard
    from repro.ann.methods.ivf_gamma import IVFGamma
    calls = []
    orig = IVFGamma.build
    monkeypatch.setattr(
        IVFGamma, "build",
        lambda self, ds, bp: (calls.append(1), orig(self, ds, bp))[1])
    with IndexStore.open(str(tmp_path / "s")) as st2:
        res = st2.index.search(batch, "ivf_gamma")
        assert not calls                           # restored, not rebuilt
        _assert_same_result(res, want)


def test_delta_chunk_indexes_persist_and_adopt(tmp_path, tiny_ds):
    """PR-6: sealed-chunk mini-IVFs are checkpointed and adopted on open
    (same `delta_chunk`, no compact barrier in the WAL); stale files are
    skipped when either condition breaks."""
    p = str(tmp_path / "s")
    with IndexStore.create(p, LiveFilteredIndex(tiny_ds, delta_chunk=64),
                           delta_chunk=64) as st:
        st.index.upsert(tiny_ds.vectors[:160] + np.float32(0.01),
                        tiny_ds.bitmaps[:160])
        built = st.index._delta.chunk_indexes(160)     # 2 sealed chunks
        assert len(built) == 2
        st.checkpoint()
        entry = st.manifest["delta_chunks"]
        assert entry["chunk"] == 64 and len(entry["files"]) == 2
        want = [ci.arrays() for ci in built]
    with IndexStore.open(p, delta_chunk=64) as st2:
        # adopted straight from the manifest — no search ran yet
        assert st2.index.stats()["delta_chunk_indexes"] == 2
        got = st2.index._delta.built_chunk_indexes()
        for w, g in zip(want, got):
            ga = g.arrays()
            for name in w:
                np.testing.assert_array_equal(w[name], ga[name])
    # a different delta_chunk moves the chunk boundaries: skip adoption
    with IndexStore.open(p) as st3:
        assert st3.index.stats()["delta_chunk_indexes"] == 0
        # replaying ops past a compact barrier rebuilds the delta, so
        # the checkpointed files go stale for the next open too
        st3.index.compact()
        st3.index.upsert(tiny_ds.vectors[:80] + np.float32(0.02),
                         tiny_ds.bitmaps[:80])
    with IndexStore.open(p, delta_chunk=64) as st4:
        assert st4.index.stats()["delta_chunk_indexes"] == 0
        assert st4.index.n_live == tiny_ds.n + 160 + 80


def test_router_version_stamp_validated(tmp_path, tiny_ds, toy_router):
    rdir = str(tmp_path / "router")
    toy_router.save(rdir)
    store_dir = str(tmp_path / "s")
    with IndexStore.create(store_dir, LiveFilteredIndex(tiny_ds),
                           router_dir=rdir) as st:
        assert st.manifest["router"]["router_version"] == 1
        assert st.manifest["router"]["table_version"] == 1
        assert st.load_router().methods == toy_router.methods
    IndexStore.open(store_dir).close()                 # stamps validate
    # re-stamp the artifact underneath the store -> open names both pairs
    rj = os.path.join(rdir, "router.json")
    with open(rj) as f:
        man = json.load(f)
    man["version"] = 0
    with open(rj, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match=r"router v0.*router v1"):
        IndexStore.open(store_dir)
    # explicit relink is the sanctioned migration path
    with IndexStore.open(store_dir, router_dir=rdir) as st:
        assert st.manifest["router"]["router_version"] == 0
    # a deleted artifact directory also fails with the migration hint
    for f_ in os.listdir(rdir):
        os.remove(os.path.join(rdir, f_))
    os.rmdir(rdir)
    with pytest.raises(ValueError, match="link_router"):
        IndexStore.open(store_dir)


def test_search_results_carry_stable_keys(tmp_path, tiny_ds, tiny_index,
                                          tiny_queries, toy_router):
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    # sealed index: keys are the row ids
    res = tiny_index.search(batch, "prefilter")
    np.testing.assert_array_equal(res.keys, res.ids.astype(np.int64))
    # routed serving surfaces keys end to end (live handle)
    with LiveFilteredIndex(tiny_ds) as live:
        svc = RouterService(live, toy_router, t=0.9)
        routed = svc.search(batch)
        np.testing.assert_array_equal(
            routed.keys, live.keys_of(routed.ids))
        assert routed.keys.dtype == np.int64


def test_key_api_rejects_duplicates_and_resolves(tiny_ds):
    with LiveFilteredIndex(tiny_ds) as live:
        ids = live.upsert(tiny_ds.vectors[:2], tiny_ds.bitmaps[:2],
                          keys=[1000, 1001])
        np.testing.assert_array_equal(live.rows_of([1000, 1001, 42]),
                                      [ids[0], ids[1], 42])
        with pytest.raises(ValueError, match="already names a live row"):
            live.upsert(tiny_ds.vectors[:1], tiny_ds.bitmaps[:1],
                        keys=[1000])
        assert live.delete_keys([1000]) == 1
        # a dead key may be re-pointed
        nid = live.upsert(tiny_ds.vectors[:1], tiny_ds.bitmaps[:1],
                          keys=[1000])
        assert live.rows_of([1000])[0] == nid[0]
        with pytest.raises(KeyError):
            live.delete_keys([999999])


def test_create_refuses_existing_store_and_open_refuses_nonstore(tmp_path,
                                                                 tiny_ds):
    p = str(tmp_path / "s")
    IndexStore.create(p, LiveFilteredIndex(tiny_ds)).close()
    with pytest.raises(ValueError, match="already an index store"):
        IndexStore.create(p, LiveFilteredIndex(tiny_ds))
    with pytest.raises(ValueError, match="not an index store"):
        IndexStore.open(str(tmp_path / "nope"))


def test_empty_store_grows_and_recovers(tmp_path, tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    p = str(tmp_path / "s")
    with IndexStore.create(p, name=tiny_ds.name, dim=tiny_ds.dim,
                           universe=tiny_ds.universe) as st:
        st.index.upsert(tiny_ds.vectors, tiny_ds.bitmaps)
    with IndexStore.open(p) as st2:
        assert st2.index.n_live == tiny_ds.n
        st2.index.compact()                # seals the delta into a base
        st2.checkpoint()
        want = st2.index.search(batch, "prefilter")
    with IndexStore.open(p) as st3:
        assert st3.index.base_n == tiny_ds.n
        assert st3.stats()["replayed_records"] == 0
        _assert_same_result(st3.index.search(batch, "prefilter"), want)


# ---------------------------------------------------------------------------
# segment bitmap compression (PR-7, segment v2)
# ---------------------------------------------------------------------------

def test_rle_roundtrip_bit_identical(rng):
    from repro.ann.dataset import rle_decode_words, rle_encode_words

    cases = [
        np.zeros((100, 3), np.uint32),                       # one giant run
        rng.integers(0, 2 ** 32, (64, 4)).astype(np.uint32),  # incompressible
        np.repeat(rng.integers(0, 2 ** 32, (7, 2)).astype(np.uint32),
                  137, axis=0),                               # group-like runs
        np.arange(12, dtype=np.uint32).reshape(6, 2),         # all runs len 1
        np.empty((0, 5), np.uint32),                          # empty
    ]
    for arr in cases:
        values, counts = rle_encode_words(arr)
        out = rle_decode_words(values, counts, arr.shape)
        assert out.dtype == np.uint32
        np.testing.assert_array_equal(out, arr)
    # counts land in the smallest sufficient dtype
    values, counts = rle_encode_words(np.zeros((1000, 1), np.uint32))
    assert counts.dtype == np.uint16
    values, counts = rle_encode_words(np.zeros((70000, 1), np.uint32))
    assert counts.dtype == np.uint32


def test_rle_decode_rejects_torn_stream():
    from repro.ann.dataset import rle_decode_words

    with pytest.raises(ValueError, match="decodes to"):
        rle_decode_words(np.array([1], np.uint32),
                         np.array([3], np.int64), (2, 2))


def test_segment_bitmaps_stored_rle_and_smaller(tmp_path, tiny_ds):
    """Group-sorted bitmaps compress on disk; the manifest records the
    encoding and the loaded array is bit-identical to the original."""
    seg = str(tmp_path / "seg")
    meta = tiny_ds.save_segment(seg)
    info = meta["files"]["bitmaps"]
    assert info["encoding"] == "rle-u32-colmajor"
    assert info["file"].endswith(".rle.npz")
    raw_bytes = int(np.prod(info["shape"])) * 4
    assert info["bytes"] < raw_bytes
    ds2 = ANNDataset.load_segment(seg, verify=True)
    np.testing.assert_array_equal(ds2.bitmaps, tiny_ds.bitmaps)
    assert ds2.bitmaps.dtype == np.uint32
    # non-RLE fields still memmap
    assert isinstance(ds2.vectors, np.memmap)
    assert not isinstance(ds2.bitmaps, np.memmap)


def test_segment_raw_fallback_for_incompressible_bitmaps(tmp_path, rng):
    """Adversarial (unsorted, high-entropy) bitmaps fall back to raw
    .npy — never worse than the v1 format."""
    from repro.data.ann_synth import DatasetSpec, synthesize

    ds = synthesize(DatasetSpec("rnd", 64, 8, 40, 6, 8,
                                1.3, 2.0, 0.5, 0.3, 3))
    # scramble: every row a unique random word pattern, no group runs
    bm = rng.integers(1, 2 ** 32, ds.bitmaps.shape).astype(np.uint32)
    ds = ds.__class__(**{**ds.__dict__, "bitmaps": bm})
    seg = str(tmp_path / "seg")
    meta = ds.save_segment(seg)
    info = meta["files"]["bitmaps"]
    assert info["encoding"] == "raw"
    assert info["file"].endswith(".npy")
    ds2 = ANNDataset.load_segment(seg)
    np.testing.assert_array_equal(ds2.bitmaps, bm)


def test_v1_raw_manifest_still_loads(tmp_path, tiny_ds):
    """A v1-era segment (all raw, no "encoding" keys) loads unchanged."""
    import json as _json

    seg = str(tmp_path / "seg")
    tiny_ds.save_segment(seg)
    meta_path = os.path.join(seg, "segment.json")
    with open(meta_path) as f:
        meta = _json.load(f)
    info = meta["files"]["bitmaps"]
    if info["encoding"] != "raw":          # rewrite the field as raw v1
        fpath = os.path.join(seg, info["file"])
        arr = np.ascontiguousarray(tiny_ds.bitmaps)
        np.save(os.path.join(seg, "bitmaps.npy"), arr)
        os.remove(fpath)
        from repro.ann.dataset import sha1_file
        npy = os.path.join(seg, "bitmaps.npy")
        meta["files"]["bitmaps"] = {
            "file": "bitmaps.npy", "sha1": sha1_file(npy),
            "bytes": os.path.getsize(npy), "shape": list(arr.shape),
            "dtype": str(arr.dtype)}        # note: no "encoding" key
    meta["version"] = 1
    with open(meta_path, "w") as f:
        _json.dump(meta, f)
    ds2 = ANNDataset.load_segment(seg, verify=True)
    np.testing.assert_array_equal(ds2.bitmaps, tiny_ds.bitmaps)


def test_segment_unknown_encoding_refused(tmp_path, tiny_ds):
    import json as _json

    seg = str(tmp_path / "seg")
    tiny_ds.save_segment(seg)
    meta_path = os.path.join(seg, "segment.json")
    with open(meta_path) as f:
        meta = _json.load(f)
    meta["files"]["bitmaps"]["encoding"] = "zstd-v9"
    with open(meta_path, "w") as f:
        _json.dump(meta, f)
    with pytest.raises(ValueError, match="unknown encoding"):
        ANNDataset.load_segment(seg)
