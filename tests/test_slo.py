"""PR-10 SLO engine: objective validation, sliding-window burn-rate
math, multi-window multi-burn-rate alerting semantics (both windows
must burn, rising-edge alerts, min-event cold-start guard), audit
ingestion, and the end-to-end acceptance path — an injected recall
regression (`DegradedMethod`) flows through the `RecallAuditor` into
the engine and fires within three evaluation passes with flight-
recorder trace ids and table-version provenance attached."""

import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.predicates import Predicate
from repro.ann.registry import candidate_methods
from repro.ann.service import RouterService
from repro.ann.slo import DEFAULT_WINDOWS, Objective, SLOEngine
from repro.ann.telemetry import (DegradedMethod, OnlineBenchmarkTable,
                                 RecallAuditor, TelemetrySink,
                                 constant_router)
from repro.ann.trace import Tracer
from repro.core import features as F
from repro.core.table import BenchmarkTable
from repro.data.ann_synth import make_queries


class FakeClock:
    def __init__(self, t: float = 1000.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, s: float) -> None:
        self.t += s


def _latency_engine(clock, *, target=0.9, threshold_us=1000.0,
                    windows=((10.0, 2.0, 2.0),), min_events=1, **kw):
    return SLOEngine([Objective(name="lat", kind="latency", target=target,
                                threshold_us=threshold_us)],
                     windows=windows, min_events=min_events,
                     clock=clock, **kw)


# ----------------------------------------------------------- objectives


def test_objective_validation():
    with pytest.raises(ValueError):
        Objective(name="x", kind="throughput", target=0.9)
    with pytest.raises(ValueError):
        Objective(name="x", kind="latency", target=0.9)   # no threshold
    with pytest.raises(ValueError):
        Objective(name="x", kind="recall", target=0.9)    # no floor
    with pytest.raises(ValueError):
        Objective(name="x", kind="availability", target=1.0)
    o = Objective(name="x", kind="latency", target=0.99,
                  threshold_us=500.0)
    assert o.budget == pytest.approx(0.01)


def test_engine_rejects_duplicate_names_and_bad_windows():
    o = Objective(name="a", kind="availability", target=0.99)
    with pytest.raises(ValueError):
        SLOEngine([o, o])
    with pytest.raises(ValueError):
        SLOEngine([o], windows=((5.0, 5.0, 2.0),))   # short >= long
    with pytest.raises(ValueError):
        SLOEngine([])


# ------------------------------------------------------- burn-rate math


def test_burn_rate_is_bad_fraction_over_budget():
    clk = FakeClock()
    eng = _latency_engine(clk, target=0.9)   # budget 0.1
    # 5 of 10 queries over threshold -> bad_frac 0.5 -> burn 5.0
    eng.observe_batch(5, per_query_us=2000.0)
    eng.observe_batch(5, per_query_us=100.0)
    st = eng.evaluate()
    win = st["lat"]["windows"][0]
    assert win["burn_long"] == pytest.approx(5.0)
    assert win["burn_short"] == pytest.approx(5.0)
    assert st["lat"]["firing"] is True       # 5.0 >= factor 2.0
    assert eng.state() == "firing:lat"


def test_alert_needs_both_windows_burning():
    clk = FakeClock()
    eng = _latency_engine(clk, windows=((10.0, 2.0, 2.0),))
    eng.observe_batch(8, per_query_us=5000.0)     # all bad
    clk.advance(3.0)                               # past the short window
    st = eng.evaluate()
    # long window still sees the burn; short window has no events
    assert st["lat"]["windows"][0]["burn_long"] > 2.0
    assert st["lat"]["firing"] is False
    eng.observe_batch(1, per_query_us=5000.0)     # confirm in short window
    assert eng.evaluate()["lat"]["firing"] is True


def test_min_events_guards_cold_start():
    clk = FakeClock()
    eng = _latency_engine(clk, min_events=10)
    eng.observe_batch(5, per_query_us=9000.0)     # 5 bad < min_events
    assert eng.evaluate()["lat"]["firing"] is False
    eng.observe_batch(5, per_query_us=9000.0)
    assert eng.evaluate()["lat"]["firing"] is True


def test_window_eviction_clears_firing():
    clk = FakeClock()
    eng = _latency_engine(clk, windows=((10.0, 2.0, 2.0),))
    eng.observe_batch(6, per_query_us=9000.0)
    assert eng.evaluate()["lat"]["firing"] is True
    clk.advance(30.0)                  # both windows age out entirely
    st = eng.evaluate()
    assert st["lat"]["firing"] is False
    assert eng.state() == "ok"


def test_alerts_fire_on_rising_edge_only():
    clk = FakeClock()
    eng = _latency_engine(clk, windows=((10.0, 2.0, 2.0),))
    eng.observe_batch(6, per_query_us=9000.0)
    eng.evaluate()
    eng.observe_batch(6, per_query_us=9000.0)
    eng.evaluate()                     # still firing: no second alert
    assert len(eng.alerts()) == 1
    clk.advance(30.0)
    eng.evaluate()                     # cleared
    eng.observe_batch(6, per_query_us=9000.0)
    eng.evaluate()                     # second rising edge
    assert len(eng.alerts()) == 2


def test_availability_and_pred_filter():
    clk = FakeClock()
    eng = SLOEngine(
        [Objective(name="avail", kind="availability", target=0.9),
         Objective(name="and_lat", kind="latency", target=0.9,
                   threshold_us=100.0, pred=int(Predicate.AND))],
        windows=((10.0, 2.0, 2.0),), min_events=1, clock=clk)
    eng.observe_batch(4, per_query_us=50.0, errors=4,
                      pred=int(Predicate.OR))
    st = eng.evaluate()
    assert st["avail"]["firing"] is True
    # the OR batch never reached the AND-scoped latency objective
    assert st["and_lat"]["observed"] == 0
    eng.observe_request(9000.0, pred=int(Predicate.AND))
    assert eng.evaluate()["and_lat"]["firing"] is True


def test_observe_recall_and_ingest_audit():
    clk = FakeClock()
    eng = SLOEngine([Objective(name="rec", kind="recall", target=0.9,
                               floor=0.8)],
                    windows=((10.0, 2.0, 2.0),), min_events=2, clock=clk)
    report = {"results": [(SimpleNamespace(pred=0), 0.5, None),
                          (SimpleNamespace(pred=1), 0.4, None),
                          (SimpleNamespace(pred=2), 0.95, None)]}
    eng.ingest_audit(report)
    st = eng.evaluate()
    assert st["rec"]["observed"] == 3
    assert st["rec"]["firing"] is True       # 2/3 bad, burn 6.7 >= 2


def test_alert_carries_trace_ids_and_provenance():
    clk = FakeClock()
    tracer = Tracer(slow_ms=0.0, sample=1.0, flight_capacity=8, seed=3)
    with tracer.trace("request"):
        pass
    eng = _latency_engine(clk, tracer=tracer,
                          provenance=lambda: {"generation": 4})
    eng.note_provenance(table_version=7)
    eng.observe_batch(6, per_query_us=9000.0)
    eng.evaluate()
    (alert,) = eng.alerts()
    assert alert.trace_ids, "flight-recorder evidence missing"
    assert all(t.startswith("t") for t in alert.trace_ids)
    assert alert.provenance == {"table_version": 7, "generation": 4}
    d = alert.to_dict()
    assert d["window"]["long_s"] == 10.0 and d["trace_ids"]


def test_status_and_stats_shapes():
    clk = FakeClock()
    eng = _latency_engine(clk)
    eng.observe_batch(4, per_query_us=10.0)
    st = eng.status()
    assert st["state"] == "ok" and st["objectives"]["lat"]["windows"]
    assert st["alerts"] == []
    assert eng.stats()["observed"]["lat"] == 4


def test_background_evaluator_thread_fires():
    eng = SLOEngine([Objective(name="lat", kind="latency", target=0.9,
                               threshold_us=100.0)],
                    windows=((60.0, 5.0, 2.0),), min_events=1)
    eng.observe_batch(8, per_query_us=9000.0)
    eng.start(interval_s=0.01)
    try:
        deadline = time.monotonic() + 2.0
        while eng.state() == "ok" and time.monotonic() < deadline:
            time.sleep(0.01)
    finally:
        eng.stop()
    assert eng.state() == "firing:lat"
    assert eng.alerts()


def test_default_windows_are_sre_shaped():
    for long_s, short_s, factor in DEFAULT_WINDOWS:
        assert short_s < long_s and factor > 1.0


# --------------------------------------------- e2e: degradation -> page


def _two_method_table(ds_name):
    cand = candidate_methods()
    table = BenchmarkTable.new()
    for pt in range(3):
        for s in cand["ivf_gamma"].param_settings():
            table.add(ds_name, pt, "ivf_gamma", s.ps_id, 0.97, 5000.0)
        for s in cand["postfilter"].param_settings():
            table.add(ds_name, pt, "postfilter", s.ps_id, 0.95, 500.0)
    return table


def test_degraded_method_fires_recall_slo_within_three_evals(tiny_ds):
    """Acceptance: inject a recall regression on the routed method; the
    auditor's exact-recall reports must trip the recall SLO within
    three evaluation windows, and the alert must carry trace ids and
    the online table version."""
    table = _two_method_table(tiny_ds.name)
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"], table)
    serving = dict(candidate_methods())
    serving["ivf_gamma"] = DegradedMethod(serving["ivf_gamma"], keep=1)
    tracer = Tracer(slow_ms=0.0, sample=1.0, flight_capacity=8, seed=1)
    slo = SLOEngine([Objective(name="recall_floor", kind="recall",
                               target=0.9, floor=0.8)],
                    windows=((60.0, 5.0, 2.0),), min_events=4,
                    tracer=tracer)
    with FilteredIndex(tiny_ds) as fx:
        sink = TelemetrySink(capacity=512, reservoir=64, seed=5)
        svc = RouterService(fx, router, t=0.9, methods=serving,
                            telemetry=sink, tracer=tracer, slo=slo)
        ot = OnlineBenchmarkTable(table)
        auditor = RecallAuditor(fx, sink, table=ot,
                                ds_name=tiny_ds.name, slo=slo)
        qs = make_queries(tiny_ds, Predicate.AND, 32, seed=3)
        batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
        fired_at = None
        for i in range(3):
            svc.search(batch)
            auditor.run_once()
            slo.evaluate()
            if slo.state() != "ok":
                fired_at = i
                break
        assert fired_at is not None, "recall SLO never fired"
        alerts = slo.alerts()
        assert alerts and alerts[0].objective == "recall_floor"
        assert alerts[0].kind == "recall"
        assert alerts[0].trace_ids, "alert lacks flight trace ids"
        assert alerts[0].provenance.get("table_version") is not None
        assert slo.stats()["observed"]["recall_floor"] >= 4
