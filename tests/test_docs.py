"""The docs check: every ```python block in docs/ must import-and-run
(they share one namespace per file, top to bottom); blocks tagged
```python skip`` must at least compile; the README's `>>>` quickstart
runs under doctest; README links to the docs pages."""

import doctest
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["docs/ARCHITECTURE.md", "docs/serving.md", "docs/persistence.md",
        "docs/observability.md"]
FENCE = re.compile(r"```([^\n`]*)\n(.*?)```", re.DOTALL)


def _blocks(path):
    with open(os.path.join(ROOT, path)) as f:
        text = f.read()
    return [(m.group(1).strip(), m.group(2)) for m in FENCE.finditer(text)]


@pytest.mark.parametrize("path", DOCS)
def test_docs_code_blocks_execute(path):
    ns = {}
    ran = checked = 0
    for i, (info, code) in enumerate(_blocks(path)):
        src = f"<{path} block {i}>"
        if info == "python":
            exec(compile(code, src, "exec"), ns)
            ran += 1
        elif info.startswith("python"):        # e.g. "python skip"
            compile(code, src, "exec")
            checked += 1
    assert ran >= 1, f"{path} has no executable ```python blocks"


def test_readme_links_docs():
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    for p in DOCS:
        assert p in readme, f"README.md does not link {p}"
        assert os.path.exists(os.path.join(ROOT, p))


def test_readme_doctest():
    results = doctest.testfile(
        os.path.join(ROOT, "README.md"), module_relative=False,
        optionflags=doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE)
    assert results.attempted >= 1, "README.md has no >>> examples"
    assert results.failed == 0
