"""The unified serving API: `FilteredIndex` ownership/lifecycle,
`QueryBatch` validation, `SearchResult` distances, the method registry,
the versioned router artifact, and `RouterService` dispatch."""

import os
import pickle

import numpy as np
import pytest

from repro.ann import engine
from repro.ann import registry as registry_mod
from repro.ann.dataset import ground_truth_topk
from repro.ann.index import (FilteredIndex, QueryBatch, RoutingDecision,
                             SearchResult, default_index)
from repro.ann.predicates import Predicate
from repro.ann.service import RouterService
from repro.core.router import MLRouter
from repro.data.ann_synth import DatasetSpec, make_queries, synthesize


# ---------------------------------------------------------------------------
# QueryBatch validation
# ---------------------------------------------------------------------------

def _batch_args(tiny_ds, q=4):
    return (tiny_ds.vectors[:q].copy(), tiny_ds.bitmaps[:q].copy())


def test_query_batch_validates_shapes(tiny_ds):
    vec, bm = _batch_args(tiny_ds)
    with pytest.raises(ValueError, match="disagree on Q"):
        QueryBatch(vec, bm[:2], Predicate.AND)
    with pytest.raises(ValueError, match="vectors must be"):
        QueryBatch(vec[0], bm, Predicate.AND)
    with pytest.raises(ValueError, match="bitmaps must be"):
        QueryBatch(vec, bm[0], Predicate.AND)
    with pytest.raises(ValueError, match="k must be"):
        QueryBatch(vec, bm, Predicate.AND, k=0)
    with pytest.raises(ValueError, match="at least one query"):
        QueryBatch(vec[:0], bm[:0], Predicate.AND)


def test_query_batch_coerces_dtypes_and_takes(tiny_ds):
    vec, bm = _batch_args(tiny_ds)
    b = QueryBatch(vec.astype(np.float64), bm.astype(np.int64),
                   int(Predicate.OR), k=3)
    assert b.vectors.dtype == np.float32
    assert b.bitmaps.dtype == np.uint32
    assert b.pred is Predicate.OR
    sub = b.take([0, 2])
    assert sub.q == 2 and sub.k == 3
    np.testing.assert_array_equal(sub.vectors, b.vectors[[0, 2]])


def test_search_rejects_mismatched_bitmap_width(tiny_index, tiny_ds):
    vec, bm = _batch_args(tiny_ds)
    wide = np.concatenate([bm, bm], axis=1)
    with pytest.raises(ValueError, match="bitmap width"):
        tiny_index.search(QueryBatch(vec, wide, Predicate.AND), "prefilter")
    # run_method is the choke point every serving path goes through
    m = registry_mod.get_method("prefilter")
    with pytest.raises(ValueError, match="bitmap width"):
        tiny_index.run_method(m, m.param_settings()[0],
                              QueryBatch(vec, wide, Predicate.AND))
    with pytest.raises(ValueError, match="vector dim"):
        tiny_index.run_method(m, m.param_settings()[0],
                              QueryBatch(vec[:, :-2], bm, Predicate.AND))


# ---------------------------------------------------------------------------
# FilteredIndex ownership + lifecycle
# ---------------------------------------------------------------------------

OTHER_SPEC = DatasetSpec("other", 500, 24, 40, 6, 8, 1.3, 2.0, 0.5, 0.3, 11)


def test_two_indexes_never_share_state(tiny_ds):
    other = synthesize(OTHER_SPEC)
    with FilteredIndex(tiny_ds) as fa, FilteredIndex(other) as fb:
        assert fa.device.vectors is not fb.device.vectors
        assert fa.device.bitmaps is not fb.device.bitmaps
        m = registry_mod.get_method("labelnav")
        ia = fa.get_index(m, m.param_settings()[0].build)
        ib = fb.get_index(m, m.param_settings()[0].build)
        assert ia == {"maxg": int(tiny_ds.group_size.max())}
        assert ib == {"maxg": int(other.group_size.max())}
        # same dataset, two handles: still no sharing (owned, not global)
        with FilteredIndex(tiny_ds) as fa2:
            assert fa2.device.vectors is not fa.device.vectors


def test_close_frees_and_blocks(tiny_ds):
    fx = FilteredIndex(tiny_ds)
    _ = fx.device
    fx.get_index("labelnav", ())
    fx.as_device(tiny_ds.norms_sq)
    assert fx.stats()["device_resident"]
    assert fx.stats()["built_indexes"] == ["labelnav"]
    assert fx.stats()["cached_uploads"] == 1
    fx.close()
    assert fx.closed
    assert fx._device is None and not fx._indexes and not fx._arrays
    with pytest.raises(RuntimeError, match="closed"):
        fx.device
    with pytest.raises(RuntimeError, match="closed"):
        fx.get_index("labelnav", ())


def test_evict_drops_built_indexes(tiny_index):
    m = registry_mod.get_method("labelnav")
    tiny_index.get_index(m, m.param_settings()[0].build)
    assert tiny_index.evict("labelnav") >= 1
    assert "labelnav" not in tiny_index.stats()["built_indexes"]


def test_default_pool_reuses_and_clears(tiny_ds):
    fa = default_index(tiny_ds)
    assert default_index(tiny_ds) is fa
    engine.clear_caches()          # shimmed onto the pool
    fb = default_index(tiny_ds)
    assert fb is not fa
    assert fa.closed
    fb.close()


# ---------------------------------------------------------------------------
# SearchResult distances
# ---------------------------------------------------------------------------

def test_distances_are_exact_squared_l2(tiny_ds, tiny_index, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    res = tiny_index.search(
        QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10), "prefilter")
    assert isinstance(res, SearchResult)
    gt = ground_truth_topk(tiny_ds, qs.vectors, qs.bitmaps,
                           Predicate.AND, 10)
    # same result *sets* as the host brute force (ranking ties aside)
    for qi in range(qs.q):
        assert set(res.ids[qi].tolist()) == set(gt[qi].tolist())
    for qi in range(qs.q):
        for j in range(10):
            vid = res.ids[qi, j]
            if vid < 0:
                assert np.isnan(res.distances[qi, j])
            else:
                want = ((tiny_ds.vectors[vid] - qs.vectors[qi]) ** 2).sum()
                assert res.distances[qi, j] == pytest.approx(want, rel=2e-3,
                                                             abs=1e-2)
        # exact distances must be sorted ascending over valid hits
        valid = res.distances[qi][res.ids[qi] >= 0]
        assert (np.diff(valid) >= -1e-4).all()


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

class _DummyMethod(engine.Method):
    name = "dummy"

    def param_settings(self):
        return [engine.ps("d1")]


def test_registry_register_overwrite_and_views():
    from repro.ann.methods import ALL_METHODS, CANDIDATE_METHODS

    m1, m2 = _DummyMethod(), _DummyMethod()
    try:
        registry_mod.register_method(m1, candidate=True)
        # live views reflect the registration without core edits
        assert CANDIDATE_METHODS["dummy"] is m1
        assert "dummy" in list(ALL_METHODS)
        with pytest.raises(ValueError, match="already registered"):
            registry_mod.register_method(m2)
        registry_mod.register_method(m2, overwrite=True, candidate=False)
        assert registry_mod.get_method("dummy") is m2
        assert "dummy" not in list(CANDIDATE_METHODS)   # demoted
        assert ALL_METHODS["dummy"] is m2
    finally:
        registry_mod.unregister_method("dummy")
    assert "dummy" not in list(ALL_METHODS)
    with pytest.raises(KeyError, match="unknown method"):
        registry_mod.get_method("dummy")


def test_registry_rejects_unnamed():
    with pytest.raises(ValueError, match="name"):
        registry_mod.register_method(engine.Method())


# ---------------------------------------------------------------------------
# versioned router artifact + service round-trip
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_identical_decisions(tmp_path, tiny_ds,
                                                tiny_index, tiny_queries,
                                                toy_router):
    router = toy_router
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    svc = RouterService(tiny_index, router, t=0.9)
    res = svc.search(batch)

    art = str(tmp_path / "router")
    router.save(art)
    assert sorted(os.listdir(art)) == ["router.json", "table.json",
                                       "weights.npz"]
    svc2 = RouterService(tiny_index, MLRouter.load(art), t=0.9)
    res2 = svc2.search(batch)
    assert res2.decisions == res.decisions
    assert all(isinstance(d, RoutingDecision) for d in res2.decisions)
    np.testing.assert_array_equal(res2.ids, res.ids)
    np.testing.assert_allclose(svc2.predict(batch), svc.predict(batch),
                               rtol=1e-6)

    # chunked serving path agrees with the one-shot path
    res3 = svc2.search_chunked(batch, chunk=7)
    np.testing.assert_array_equal(res3.ids, res.ids)
    assert res3.decisions == res.decisions

    # explain() is consistent with the decisions it explains
    exp = svc.explain(batch)
    assert [(e.method, e.ps_id) for e in exp] == res.decisions
    assert all(set(e.r_hat) == set(router.methods) for e in exp)


def test_artifact_rejects_foreign_and_future(tmp_path, toy_router):
    import json

    router = toy_router
    art = str(tmp_path / "router")
    router.save(art)
    manifest = json.load(open(os.path.join(art, "router.json")))
    manifest["version"] = 99
    json.dump(manifest, open(os.path.join(art, "router.json"), "w"))
    with pytest.raises(ValueError, match="newer"):
        MLRouter.load(art)
    manifest["version"] = 1
    manifest["format"] = "something.else"
    json.dump(manifest, open(os.path.join(art, "router.json"), "w"))
    with pytest.raises(ValueError, match="not a repro.router"):
        MLRouter.load(art)
    with pytest.raises(ValueError, match="existing file"):
        router.save(os.path.join(art, "router.json"))


def test_legacy_pickle_no_longer_loads(tmp_path, toy_router):
    """The one-PR-cycle pickle loader is gone: loading a pickle file (or
    any non-directory path) fails with a migration hint."""
    router = toy_router
    p = str(tmp_path / "router.pkl")
    with open(p, "wb") as f:
        pickle.dump({"methods": router.methods}, f)
    with pytest.raises(ValueError, match="no longer supported"):
        MLRouter.load(p)
    with pytest.raises(ValueError, match="no longer supported"):
        MLRouter.load(str(tmp_path / "never_written"))


def test_deprecation_shims_removed():
    """PR-2's one-PR-cycle shims are gone from the public surface."""
    assert not hasattr(engine, "device_data")
    assert not hasattr(engine, "as_device")
    assert not hasattr(engine, "get_index")
    assert not hasattr(MLRouter, "route_and_search")
    assert not hasattr(MLRouter, "_load_legacy_pickle")
    engine.clear_caches()          # the pool-evict helper stays


def test_feature_cache_owned_by_handle(tiny_ds):
    """Dataset-feature state lives on the handle and dies with close()."""
    from repro.core import features as F

    with FilteredIndex(tiny_ds) as fx:
        a = F.dataset_features(tiny_ds, fx=fx)
        assert F.dataset_features(tiny_ds, fx=fx) is a   # handle cache hit
        assert fx.stats()["features_cached"]
    assert fx._features is None                          # freed by close()
    # handle-less calls cache in the weak per-instance fallback map
    # (features._FALLBACK_FEATURES), living only as long as the dataset
    F.clear_feature_cache()
    b = F.dataset_features(tiny_ds)
    assert F.dataset_features(tiny_ds) is b
    assert b is not a
