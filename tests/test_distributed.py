"""Distribution tests: run in a subprocess with 8 forced host devices so the
main pytest process keeps its single-device view."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

out = {}

# ---- 1. sharded filtered ANN search == exact ground truth ----
from repro.data.ann_synth import DatasetSpec, synthesize, make_queries
from repro.ann import distributed
from repro.ann.predicates import Predicate
from repro.ann.dataset import ground_truth_topk
spec = DatasetSpec("t", 1600, 24, 40, 6, 8, 1.3, 2.0, 0.5, 0.3, 7)
ds = synthesize(spec)
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
fn = distributed.make_sharded_search(mesh, k=10, data_axes=("data",))
match = 0
for pred in (Predicate.EQUALITY, Predicate.AND, Predicate.OR):
    qs = make_queries(ds, pred, 8, seed=5)
    ids = np.asarray(fn(qs.vectors, qs.bitmaps, jnp.int32(int(pred)),
                        ds.vectors, ds.norms_sq, ds.bitmaps))
    for i in range(8):
        want = set(qs.ground_truth[i][qs.ground_truth[i] >= 0].tolist())
        got = set(ids[i][ids[i] >= 0].tolist())
        match += got == want
out["ann_match"] = match

# ---- 2. sharded train step runs and loss decreases ----
from repro.configs.base import get_smoke_config
from repro.launch import steps as ST
from repro.launch.mesh import mesh_axes
from repro.launch import specs as SP
from repro.models import lm, common
from repro.data.tokens import TokenStream
cfg = get_smoke_config("internlm2-1.8b")
axes = mesh_axes(mesh)
ctx = lm.ModelCtx(mesh=mesh, dp_axes=axes.dp_axes, tp_size=axes.tp_size,
                  dp_size=axes.dp_size, qc_train=32, gla_chunk=32)
params, opt = ST.init_train_state(cfg, jax.random.PRNGKey(0))
desc = lm.model_desc(cfg)
pspecs = SP.param_partition(desc, axes, fsdp=True)
params = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                      params, pspecs)
step = jax.jit(ST.make_train_step(cfg, ctx, accum=2))
stream = TokenStream(cfg.vocab, 32, 8, seed=1)
losses = []
with mesh:
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
out["loss_first"] = losses[0]
out["loss_last"] = losses[-1]

# ---- 3. elastic reshard: (4,2) -> (2,4) mesh ----
from repro.runtime import elastic_reshard
host = jax.tree.map(lambda x: np.asarray(x), params)
mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
axes2 = mesh_axes(mesh2)
pspecs2 = SP.param_partition(desc, axes2, fsdp=True)
params2 = elastic_reshard(host, pspecs2, mesh2)
ctx2 = lm.ModelCtx(mesh=mesh2, dp_axes=axes2.dp_axes, tp_size=axes2.tp_size,
                   dp_size=axes2.dp_size, qc_train=32, gla_chunk=32)
step2 = jax.jit(ST.make_train_step(cfg, ctx2, accum=2))
with mesh2:
    batch = {k: jnp.asarray(v) for k, v in stream.batch(100).items()}
    params2, opt2, m2 = step2(params2, jax.device_put(opt), batch)
out["elastic_loss"] = float(m2["loss"])

# ---- 4. MoE shard_map path on a real multi-device mesh ----
cfg_moe = get_smoke_config("grok-1-314b")
params_m, opt_m = ST.init_train_state(cfg_moe, jax.random.PRNGKey(0))
step_m = jax.jit(ST.make_train_step(cfg_moe, ctx, accum=1))
stream_m = TokenStream(cfg_moe.vocab, 32, 8, seed=2)
with mesh:
    batch = {k: jnp.asarray(v) for k, v in stream_m.batch(0).items()}
    _, _, mm = step_m(params_m, opt_m, batch)
out["moe_loss"] = float(mm["loss"])

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def subproc_results():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_sharded_ann_exact(subproc_results):
    assert subproc_results["ann_match"] == 24


def test_sharded_training_loss_decreases(subproc_results):
    assert subproc_results["loss_last"] < subproc_results["loss_first"]


def test_elastic_reshard_step(subproc_results):
    import math
    assert math.isfinite(subproc_results["elastic_loss"])


def test_moe_shard_map(subproc_results):
    import math
    assert math.isfinite(subproc_results["moe_loss"])
