"""PR-10 wide-event log: ring/writer round-trip and ordering, rotation,
overrun shedding (counted drops, never blocking), serialisation-error
isolation, per-request event construction, the parse-and-join
acceptance test against the flight recorder (every wide event's trace
id must resolve to a recorded span tree), and post-mortem dumps via
explicit call, SIGUSR2 and the atexit hook."""

import json
import os
import signal
import threading

import numpy as np
import pytest

from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.ledger import ResourceLedger
from repro.ann.obslog import (PostmortemDumper, WideEventLog,
                              install_postmortem, read_events,
                              request_events)
from repro.ann.predicates import Predicate
from repro.ann.registry import candidate_methods
from repro.ann.service import RouterService
from repro.ann.telemetry import TelemetrySink, constant_router
from repro.ann.trace import Tracer
from repro.core import features as F
from repro.core.table import BenchmarkTable
from repro.data.ann_synth import make_queries


# ------------------------------------------------------ ring + writer


def test_emit_flush_read_round_trip(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with WideEventLog(path, capacity=64, autostart=False) as log:
        for i in range(10):
            log.emit({"qi": i, "method": "m"})
        log.flush()
        s = log.stats()
        assert s["emitted"] == 10 and s["written"] == 10
        assert s["dropped"] == 0
    events = list(read_events(path))
    assert [e["qi"] for e in events] == list(range(10))


def test_background_writer_drains_without_flush(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with WideEventLog(path, capacity=64, flush_interval_s=0.01) as log:
        for i in range(5):
            log.emit({"qi": i})
        deadline = 200
        while log.stats()["written"] < 5 and deadline:
            deadline -= 1
            threading.Event().wait(0.01)
    assert len(list(read_events(path))) == 5


def test_overrun_sheds_oldest_and_counts_drops(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with WideEventLog(path, capacity=8, autostart=False) as log:
        for i in range(20):
            log.emit({"qi": i})
        log.flush()
        s = log.stats()
        assert s["emitted"] == 20
        assert s["dropped"] == 12 and s["written"] == 8
    # the survivors are the 8 newest, in order
    assert [e["qi"] for e in read_events(path)] == list(range(12, 20))


def test_rotation_keeps_bounded_generations(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with WideEventLog(path, capacity=64, rotate_bytes=120,
                      rotate_keep=2, autostart=False) as log:
        for i in range(30):
            log.emit({"qi": i, "pad": "x" * 40})
            log.flush()
        s = log.stats()
    assert s["rotations"] >= 3
    assert os.path.exists(f"{path}.1") and os.path.exists(f"{path}.2")
    assert not os.path.exists(f"{path}.3")        # older ones deleted
    seen = [e["qi"] for e in read_events(path)]
    assert seen == sorted(seen)                   # oldest -> newest
    assert seen[-1] == 29
    # the active file alone is just the newest tail
    active = [e["qi"] for e in read_events(path, include_rotated=False)]
    assert active == seen[len(seen) - len(active):]


def test_unserialisable_event_counts_error_not_crash(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    loop = {}
    loop["self"] = loop                           # circular: json raises
    with WideEventLog(path, capacity=8, autostart=False) as log:
        log.emit({"qi": 0})
        log.emit(loop)
        log.emit({"qi": 2})
        log.flush()
        s = log.stats()
    assert s["write_errors"] == 1
    assert [e["qi"] for e in read_events(path)] == [0, 2]


def test_read_events_skips_torn_tail_line(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"qi": 0}) + "\n")
        f.write('{"qi": 1, "meth')                # torn mid-crash write
    assert [e["qi"] for e in read_events(path)] == [0]


# -------------------------------------------------- event construction


def test_request_events_builds_one_row_per_query():
    from types import SimpleNamespace
    batch = SimpleNamespace(q=3, pred=Predicate.AND, k=5)
    decisions = [SimpleNamespace(method="sieve", ps_id="s1"),
                 SimpleNamespace(method="ivf_gamma", ps_id="g0"),
                 SimpleNamespace(method="sieve", ps_id="s1")]
    evs = request_events(batch, decisions, per_query_us=123.4,
                         trace_id="t1-abc",
                         timings={"search_s": 0.002, "total_s": 0.003,
                                  "queries": 3},
                         generation=2, table_version=5,
                         slo_state="firing:lat",
                         cache=[None, "exact", None])
    assert len(evs) == 3
    assert [e["qi"] for e in evs] == [0, 1, 2]
    for e in evs:
        assert e["trace"] == "t1-abc" and e["batch_q"] == 3
        assert e["generation"] == 2 and e["table_version"] == 5
        assert e["slo"] == "firing:lat"
        assert e["timings_ms"] == {"search": 2.0, "total": 3.0}
    assert evs[1]["method"] == "ivf_gamma" and evs[1]["cache"] == "exact"
    assert evs[0]["cache"] is None
    assert json.loads(json.dumps(evs[0]))["lat_us"] == 123.4


# ------------------------------------- acceptance: join against flight


def _two_method_table(ds_name):
    cand = candidate_methods()
    table = BenchmarkTable.new()
    for pt in range(3):
        for s in cand["ivf_gamma"].param_settings():
            table.add(ds_name, pt, "ivf_gamma", s.ps_id, 0.97, 5000.0)
        for s in cand["postfilter"].param_settings():
            table.add(ds_name, pt, "postfilter", s.ps_id, 0.95, 500.0)
    return table


def test_wide_events_join_flight_recorder_on_trace_id(tiny_ds, tmp_path):
    """Acceptance: serve through a traced service with the wide-event
    log attached, then parse the JSONL back and join every event to its
    flight-recorder span tree by trace id."""
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"],
                             _two_method_table(tiny_ds.name))
    tracer = Tracer(slow_ms=0.0, sample=1.0, flight_capacity=16, seed=7)
    path = str(tmp_path / "wide.jsonl")
    with FilteredIndex(tiny_ds) as fx, WideEventLog(path) as log:
        svc = RouterService(fx, router, t=0.9, tracer=tracer, obslog=log)
        qs = make_queries(tiny_ds, Predicate.AND, 12, seed=3)
        batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
        svc.search(batch)
        svc.search(batch)
        log.flush()
        events = list(read_events(path))
        assert len(events) == 24                  # one row per query
        flight = {r["trace_id"]: r for r in tracer.flight()}
        assert all(f is not None for f in flight)
        joined = 0
        for ev in events:
            assert ev["trace"], "wide event without trace id"
            rec = flight[ev["trace"]]             # KeyError = join broken
            assert rec["duration_ms"] > 0
            assert ev["method"] in {"ivf_gamma", "postfilter"}
            joined += 1
        assert joined == 24
        # both batches share per-batch rows but have distinct trace ids
        assert len({ev["trace"] for ev in events}) == 2


# ------------------------------------------------------- post-mortems


def _tiny_slo():
    from repro.ann.slo import Objective, SLOEngine
    eng = SLOEngine([Objective(name="lat", kind="latency", target=0.9,
                               threshold_us=1.0)], min_events=1)
    eng.observe_batch(4, per_query_us=100.0)
    return eng


def test_postmortem_dump_contains_all_sections(tmp_path):
    tracer = Tracer(slow_ms=0.0, sample=1.0, seed=1)
    with tracer.trace("request"):
        pass
    led = ResourceLedger()
    led.acquire("pin", "x")
    with WideEventLog(str(tmp_path / "ev.jsonl"), autostart=False) as log:
        log.emit({"qi": 0})
        dumper = PostmortemDumper(tracer=tracer, ledger=led,
                                  slo=_tiny_slo(), obslog=log,
                                  out_dir=str(tmp_path),
                                  extra=lambda: {"note": "hi"})
        path = dumper.dump("unit-test")
        with open(path) as f:
            d = json.load(f)
    assert d["reason"] == "unit-test"
    assert d["flight"] and d["flight"][0]["trace_id"]
    assert d["ledger"]["held"]["pin"]["x"]["leases"] == 1
    assert d["slo"]["state"].startswith("firing")
    assert d["obslog"]["written"] == 1
    assert d["extra"] == {"note": "hi"}


@pytest.mark.skipif(not hasattr(signal, "SIGUSR2"),
                    reason="platform has no SIGUSR2")
def test_sigusr2_writes_postmortem(tmp_path):
    dumper = install_postmortem(ledger=ResourceLedger(),
                                out_dir=str(tmp_path),
                                install_atexit=False)
    try:
        os.kill(os.getpid(), signal.SIGUSR2)
        files = [f for f in os.listdir(tmp_path)
                 if f.startswith("postmortem-")]
        assert len(files) == 1
        with open(tmp_path / files[0]) as f:
            assert json.load(f)["reason"] == "SIGUSR2"
    finally:
        dumper.uninstall()


def test_atexit_hook_dumps_once(tmp_path):
    dumper = PostmortemDumper(ledger=ResourceLedger(),
                              out_dir=str(tmp_path))
    dumper.install(install_signal=False, install_atexit=True)
    try:
        dumper._atexit_dump()
        dumper._atexit_dump()                    # second call is a no-op
        files = [f for f in os.listdir(tmp_path)
                 if f.startswith("postmortem-")]
        assert len(files) == 1
    finally:
        dumper.uninstall()
