"""End-to-end behaviour: the full offline-stage artifacts (benchmark table,
trained router) route real validation queries to near-oracle recall, and
the RAG-style serve path (LM embed → route → filtered search) runs."""

import os

import numpy as np
import pytest

from repro.ann.dataset import recall_at_k
from repro.ann.index import QueryBatch
from repro.ann.predicates import Predicate
from repro.ann.service import RouterService
from repro.core import training as T
from repro.core.oracle import oracle_recall


def _artifacts():
    p_train, p_val, p_router = T.default_paths()
    if not all(os.path.exists(p) for p in (p_train, p_val, p_router)):
        pytest.skip("offline artifacts not built (run benchmarks first)")
    from repro.core.router import MLRouter

    return (T.Collection.load(p_train), T.Collection.load(p_val),
            MLRouter.load(p_router))


def test_router_near_oracle_on_validation():
    _, coll_val, router = _artifacts()
    recs, oracles = [], []
    for (ds, pt), cell in coll_val.cells.items():
        x, y, _ = T.assemble_xy(
            T.Collection(cells={(ds, pt): cell}, table=coll_val.table),
            router.feature_names)
        r_hat = router.predict_recalls_from_features(x)
        dec = router.route_from_predictions(r_hat, ds, pt, 0.9)
        recs.extend(cell.recall[m][i] for i, (m, _) in enumerate(dec))
        oracles.append(oracle_recall(coll_val, ds, pt))
    agg = float(np.mean(recs))
    orc = float(np.concatenate(oracles).mean())
    # paper: router 0.986 aggregate, ≤0.9% behind oracle
    assert agg >= 0.95
    assert orc - agg <= 0.03


def test_router_pareto_dominates_single_methods():
    """No single method beats the router on BOTH recall and latency —
    the recall-QPS balance claim of §6.3 (a single max-budget method can
    match recall, but only at worse latency)."""
    _, coll_val, router = _artifacts()
    single = {m: {"rec": [], "time": 0.0} for m in T.METHOD_ORDER}
    routed_rec, routed_time = [], 0.0
    for (ds, pt), cell in coll_val.cells.items():
        x, _, _ = T.assemble_xy(
            T.Collection(cells={(ds, pt): cell}, table=coll_val.table),
            router.feature_names)
        dec = router.route_from_predictions(
            router.predict_recalls_from_features(x), ds, pt, 0.9)
        qps_of = {(m, ps): v["qps"]
                  for (d2, p2, m, ps), v in router.table.entries.items()
                  if (d2, p2) == (ds, pt)}
        for i, (m, ps) in enumerate(dec):
            routed_rec.append(cell.recall[m][i])
            routed_time += 1.0 / max(qps_of.get((m, ps), 1e-9), 1e-9)
        for m in T.METHOD_ORDER:
            single[m]["rec"].extend(cell.recall[m])
            best = max((s for s in cell.sweep if s[0] == m),
                       key=lambda s: (round(s[2], 3), s[3]))
            single[m]["time"] += len(cell.recall[m]) / max(best[3], 1e-9)
    r_rec = float(np.mean(routed_rec))
    assert r_rec >= 0.95
    for m, d in single.items():
        m_rec = float(np.mean(d["rec"]))
        # Pareto: anything matching the router's recall must be slower
        if m_rec >= r_rec - 0.002:
            assert d["time"] > routed_time, (m, m_rec, d["time"], routed_time)


def test_service_search_executes(tiny_index, tiny_queries):
    """Full dispatch path on fresh data with the shipped router."""
    _, _, router = _artifacts()
    qs = tiny_queries[Predicate.AND]
    svc = RouterService(tiny_index, router, t=0.9)
    res = svc.search(QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10))
    rec = recall_at_k(res.ids, qs.ground_truth).mean()
    assert rec > 0.6
    assert len(res.decisions) == qs.q


def test_rag_serve_path(tiny_ds, tiny_index):
    """LM produces the query embedding; the router picks the method; the
    engine searches — the end-to-end serving story."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_smoke_config
    from repro.models import common, lm
    from repro.ann import labels as lb

    from repro.launch.mesh import make_mesh_compat

    _, _, router = _artifacts()
    cfg = get_smoke_config("qwen2-0.5b")
    params = common.init_params(lm.model_desc(cfg), jax.random.PRNGKey(0))
    ctx = lm.ModelCtx(mesh=make_mesh_compat((1, 1), ("data", "model")),
                      qc_prefill=16, gla_chunk=16)
    toks = jnp.ones((2, 16), jnp.int32)
    with ctx.mesh:
        logits, cache = lm.forward_prefill(params, {"tokens": toks}, cfg, ctx)
    # embedding = final hidden state proxy: use logits slice projected down
    emb = np.asarray(logits[:, 0, :tiny_ds.dim], np.float32)
    qbms = np.stack([lb.pack_one([0], tiny_ds.universe)] * 2)
    svc = RouterService(tiny_index, router)
    res = svc.search_chunked(QueryBatch(emb, qbms, Predicate.OR, 5), t=0.5)
    assert res.ids.shape == (2, 5)
    assert len(res.decisions) == 2
    mask = tiny_ds.matching_mask(qbms[0], Predicate.OR)
    assert all(mask[i] for i in res.ids.ravel() if i >= 0)
