"""Feature extraction: the 22-feature set, LID estimator, feature matrix."""

import numpy as np
import pytest

from repro.ann.predicates import Predicate
from repro.core import features as F


def test_feature_inventory():
    assert len(F.QUERY_FEATURES) == 6
    assert len(F.DATASET_FEATURES) == 15
    assert len(F.ALL_FEATURES) == 22
    assert F.MINIMAL_FEATURES == ["selectivity", "lid_mean", "pred"]


def test_lid_mle_gaussian_scales_with_dim():
    rng = np.random.default_rng(0)
    lids = []
    for d in (4, 16):
        x = rng.normal(size=(4000, d)).astype(np.float32)
        r = F._knn_dists(x, x[:128], 20)
        lids.append(float(np.mean(F.lid_mle(r))))
    assert lids[1] > lids[0] > 1.0


def test_dataset_features_sane(tiny_ds):
    dsf = F.dataset_features(tiny_ds)
    v = dsf.values
    assert v["size"] == tiny_ds.n
    assert v["dim"] == tiny_ds.dim
    assert v["label_cardinality"] == tiny_ds.universe
    assert v["n_label_combinations"] == tiny_ds.n_groups
    assert v["lid_mean"] > 0 and np.isfinite(v["lid_mean"])
    assert v["rc_median"] >= 1.0
    assert v["label_entropy"] > 0
    assert 0 < v["avg_labels_per_vector"] < 10
    assert np.isfinite(v["distribution_factor"])
    assert (dsf.label_freq >= 0).all() and dsf.label_freq.max() <= 1.0


def test_query_features_selectivity(tiny_ds, tiny_queries):
    dsf = F.dataset_features(tiny_ds)
    qs = tiny_queries[Predicate.AND]
    for i in range(5):
        qf = F.query_features(tiny_ds, dsf, qs.bitmaps[i], Predicate.AND)
        assert qf["selectivity"] == pytest.approx(
            tiny_ds.selectivity(qs.bitmaps[i], Predicate.AND))
        assert qf["min_label_freq"] <= qf["mean_label_freq"] \
            <= qf["max_label_freq"]
        # co-occurrence == AND selectivity by definition
        assert qf["label_cooccurrence"] == pytest.approx(qf["selectivity"])


@pytest.mark.parametrize("pred", list(Predicate))
def test_feature_matrix_matches_per_query_reference(tiny_ds, tiny_queries,
                                                    pred):
    """The batched query_feature_arrays pass must be numerically identical
    to Q independent query_features calls, for every predicate type."""
    dsf = F.dataset_features(tiny_ds)
    qs = tiny_queries[pred]
    got = F.query_feature_arrays(tiny_ds, dsf, qs.bitmaps, pred)
    for i in range(qs.q):
        want = F.query_features(tiny_ds, dsf, qs.bitmaps[i], pred)
        for name in F.QUERY_FEATURES:
            assert got[name][i] == pytest.approx(want[name], rel=1e-12), \
                (name, i)


def test_feature_matrix_empty_label_query(tiny_ds):
    """All-zero query bitmap: freq stats are 0, selectivity matches the
    scalar path's empty-set semantics."""
    dsf = F.dataset_features(tiny_ds)
    qbms = np.zeros((2, tiny_ds.bitmaps.shape[1]), dtype=np.uint32)
    for pred in Predicate:
        got = F.query_feature_arrays(tiny_ds, dsf, qbms, pred)
        want = F.query_features(tiny_ds, dsf, qbms[0], pred)
        for name in F.QUERY_FEATURES:
            assert got[name][0] == pytest.approx(want[name]), name


def test_batch_selectivity_matches_dataset_scan(tiny_ds, tiny_queries):
    for pred, qs in tiny_queries.items():
        got = F.batch_selectivity(tiny_ds, qs.bitmaps, pred)
        for i in range(qs.q):
            assert got[i] == pytest.approx(
                tiny_ds.selectivity(qs.bitmaps[i], pred))


def test_feature_cache_keyed_by_identity(tiny_ds):
    F.clear_feature_cache()
    a = F.dataset_features(tiny_ds)
    assert F.dataset_features(tiny_ds) is a          # cache hit
    F.clear_feature_cache()
    assert F.dataset_features(tiny_ds) is not a      # evicted


def test_feature_cache_no_content_aliasing():
    """Same name/shape/universe but different content must not share a
    cache entry (metadata-only keys silently alias distinct datasets)."""
    from repro.ann.dataset import ANNDataset

    rng = np.random.default_rng(0)
    v = rng.normal(size=(50, 8)).astype(np.float32)
    d1 = ANNDataset.build("t", v, [[0], [1]] * 25, universe=10)
    d2 = ANNDataset.build("t", v + 1.0, [[2, 3], [4]] * 25, universe=10)
    assert d1.cache_key() != d2.cache_key()
    F.clear_feature_cache()
    f1 = F.dataset_features(d1)
    f2 = F.dataset_features(d2)
    assert f1 is not f2
    assert not np.array_equal(f1.label_freq, f2.label_freq)


def test_feature_matrix_shapes(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.OR]
    x = F.feature_matrix(tiny_ds, qs.bitmaps, Predicate.OR,
                         F.MINIMAL_FEATURES)
    # selectivity + lid_mean + 3-way one-hot
    assert x.shape == (qs.q, 5)
    assert (x[:, 2:5].sum(1) == 1).all()
    x_all = F.feature_matrix(tiny_ds, qs.bitmaps[:4], Predicate.OR,
                             F.NUMERIC_FEATURES)
    assert x_all.shape == (4, 21)
    assert np.isfinite(x_all).all()
