"""Feature extraction: the 22-feature set, LID estimator, feature matrix."""

import numpy as np
import pytest

from repro.ann.predicates import Predicate
from repro.core import features as F


def test_feature_inventory():
    assert len(F.QUERY_FEATURES) == 6
    assert len(F.DATASET_FEATURES) == 15
    assert len(F.ALL_FEATURES) == 22
    assert F.MINIMAL_FEATURES == ["selectivity", "lid_mean", "pred"]


def test_lid_mle_gaussian_scales_with_dim():
    rng = np.random.default_rng(0)
    lids = []
    for d in (4, 16):
        x = rng.normal(size=(4000, d)).astype(np.float32)
        r = F._knn_dists(x, x[:128], 20)
        lids.append(float(np.mean(F.lid_mle(r))))
    assert lids[1] > lids[0] > 1.0


def test_dataset_features_sane(tiny_ds):
    dsf = F.dataset_features(tiny_ds)
    v = dsf.values
    assert v["size"] == tiny_ds.n
    assert v["dim"] == tiny_ds.dim
    assert v["label_cardinality"] == tiny_ds.universe
    assert v["n_label_combinations"] == tiny_ds.n_groups
    assert v["lid_mean"] > 0 and np.isfinite(v["lid_mean"])
    assert v["rc_median"] >= 1.0
    assert v["label_entropy"] > 0
    assert 0 < v["avg_labels_per_vector"] < 10
    assert np.isfinite(v["distribution_factor"])
    assert (dsf.label_freq >= 0).all() and dsf.label_freq.max() <= 1.0


def test_query_features_selectivity(tiny_ds, tiny_queries):
    dsf = F.dataset_features(tiny_ds)
    qs = tiny_queries[Predicate.AND]
    for i in range(5):
        qf = F.query_features(tiny_ds, dsf, qs.bitmaps[i], Predicate.AND)
        assert qf["selectivity"] == pytest.approx(
            tiny_ds.selectivity(qs.bitmaps[i], Predicate.AND))
        assert qf["min_label_freq"] <= qf["mean_label_freq"] \
            <= qf["max_label_freq"]
        # co-occurrence == AND selectivity by definition
        assert qf["label_cooccurrence"] == pytest.approx(qf["selectivity"])


def test_feature_matrix_shapes(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.OR]
    x = F.feature_matrix(tiny_ds, qs.bitmaps, Predicate.OR,
                         F.MINIMAL_FEATURES)
    # selectivity + lid_mean + 3-way one-hot
    assert x.shape == (qs.q, 5)
    assert (x[:, 2:5].sum(1) == 1).all()
    x_all = F.feature_matrix(tiny_ds, qs.bitmaps[:4], Predicate.OR,
                             F.NUMERIC_FEATURES)
    assert x_all.shape == (4, 21)
    assert np.isfinite(x_all).all()
