import numpy as np
import pytest

from repro.ann.dataset import ANNDataset
from repro.data.ann_synth import DatasetSpec, synthesize, make_queries
from repro.ann.predicates import Predicate


TINY_SPEC = DatasetSpec("tiny", 600, 24, 40, 6, 8, 1.3, 2.0, 0.5, 0.3, 7)


@pytest.fixture(scope="session")
def tiny_ds() -> ANNDataset:
    return synthesize(TINY_SPEC)


@pytest.fixture(scope="session")
def tiny_index(tiny_ds):
    from repro.ann.index import FilteredIndex

    fx = FilteredIndex(tiny_ds)
    yield fx
    fx.close()


@pytest.fixture(scope="session")
def tiny_queries(tiny_ds):
    return {pred: make_queries(tiny_ds, pred, 25, seed=3)
            for pred in (Predicate.EQUALITY, Predicate.AND, Predicate.OR)}


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
