import numpy as np
import pytest

from repro.ann.dataset import ANNDataset
from repro.data.ann_synth import DatasetSpec, synthesize, make_queries
from repro.ann.predicates import Predicate


TINY_SPEC = DatasetSpec("tiny", 600, 24, 40, 6, 8, 1.3, 2.0, 0.5, 0.3, 7)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running sweeps (deselect with '-m \"not slow\"'; "
        "run with '-m slow')")


def pytest_collection_modifyitems(config, items):
    if config.getoption("-m"):
        return                        # explicit marker expression wins
    skip = pytest.mark.skip(reason="slow sweep; run with -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def tiny_ds() -> ANNDataset:
    return synthesize(TINY_SPEC)


@pytest.fixture(scope="session")
def tiny_index(tiny_ds):
    from repro.ann.index import FilteredIndex

    fx = FilteredIndex(tiny_ds)
    yield fx
    fx.close()


@pytest.fixture(scope="session")
def tiny_queries(tiny_ds):
    return {pred: make_queries(tiny_ds, pred, 25, seed=3)
            for pred in (Predicate.EQUALITY, Predicate.AND, Predicate.OR)}


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def toy_router(tiny_ds):
    """Randomly initialised MLRouter with a dense synthetic benchmark
    table over tiny_ds — routing exercises Algorithm 2 end to end without
    the offline collection sweep."""
    import jax

    from repro.ann import registry as registry_mod
    from repro.core import features as F
    from repro.core import mlp as mlp_mod
    from repro.core.router import MLRouter
    from repro.core.table import BenchmarkTable

    methods = list(registry_mod.candidate_methods())
    rand = np.random.default_rng(5)
    table = BenchmarkTable.new()
    for pt in range(3):
        for name, m in registry_mod.candidate_methods().items():
            for s in m.param_settings():
                table.add(tiny_ds.name, pt, name, s.ps_id,
                          recall=float(rand.uniform(0.7, 1.0)),
                          qps=float(rand.uniform(100, 2000)))
    models = {m: mlp_mod.params_to_numpy(
        mlp_mod.init_mlp((5, 16, 8, 1), jax.random.PRNGKey(j)))
        for j, m in enumerate(methods)}
    return MLRouter(feature_names=F.MINIMAL_FEATURES, methods=methods,
                    models=models,
                    scaler=mlp_mod.Scaler(np.zeros(5), np.ones(5)),
                    table=table)
