"""Oracle-backed staleness + concurrency suite for
`repro.ann.cache.SemanticResultCache`.

The contract under test: **a cache hit is always bit-identical to a
fresh search on the pinned snapshot** — same ids, same distances (to
float tolerance across a compaction's re-sort), same stable keys — and
a write that could change a cached answer always turns the next probe
into a miss. A stale hit is a hard failure here, never a recall delta.

Exact-key mode (`threshold=None`) is the bit-identity surface, so the
oracle suites run there; the semantic path has its own tests pinning
its weaker contract (neighbour's row set, exactly re-scored distances).
"""

import threading
import time

import numpy as np
import pytest

from repro.ann.cache import SemanticResultCache
from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.live import LiveFilteredIndex, ShardedLiveIndex
from repro.ann.predicates import Predicate
from repro.ann.sharded import ShardedFilteredIndex

ALL_PREDS = (Predicate.EQUALITY, Predicate.AND, Predicate.OR)
HANDLE_KINDS = ("sealed", "sharded", "live", "sharded_live")


def _make_handle(kind: str, tiny_ds):
    if kind == "sealed":
        return FilteredIndex(tiny_ds)
    if kind == "sharded":
        return ShardedFilteredIndex(tiny_ds, 2)
    if kind == "live":
        return LiveFilteredIndex(tiny_ds)
    live = ShardedLiveIndex(None, 2, name=tiny_ds.name, dim=tiny_ds.dim,
                            universe=tiny_ds.universe)
    live.upsert(tiny_ds.vectors, tiny_ds.bitmaps)
    return live


def _assert_same_result(res, want):
    np.testing.assert_array_equal(res.ids, want.ids)
    np.testing.assert_allclose(res.distances, want.distances,
                               rtol=1e-5, atol=1e-5, equal_nan=True)
    if want.keys is not None:
        np.testing.assert_array_equal(res.keys, want.keys)


# ---------------------------------------------------------------------------
# staleness oracle: every hit == fresh search, all predicates × handles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", HANDLE_KINDS)
@pytest.mark.parametrize("pred", ALL_PREDS)
def test_exact_hit_bit_identical_to_fresh_search(tiny_ds, tiny_queries,
                                                 pred, kind):
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors[:8], qs.bitmaps[:8], pred, 10)
    with _make_handle(kind, tiny_ds) as h:
        cache = SemanticResultCache(h, method="prefilter", threshold=None)
        first = cache.search(batch)
        assert first.cache == [None] * batch.q
        hit = cache.search(batch)
        assert hit.cache == ["exact"] * batch.q
        # the fill itself must already match the handle verbatim
        want = h.search(batch, "prefilter")
        _assert_same_result(first, want)
        np.testing.assert_array_equal(hit.ids, want.ids)
        np.testing.assert_array_equal(      # verbatim, not just close
            hit.distances, want.distances)
        np.testing.assert_array_equal(hit.keys, want.keys)
        st = cache.stats()
        assert st["hits_exact"] == batch.q and st["misses"] == batch.q
        cache.close()


@pytest.mark.parametrize("kind", HANDLE_KINDS)
def test_hit_path_runs_no_search(tiny_ds, tiny_queries, kind,
                                 monkeypatch):
    """The hit path must bypass routing and search *entirely*: poison
    the handle's search surface after the fill and hits must still
    serve."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors[:4], qs.bitmaps[:4], Predicate.AND, 5)
    with _make_handle(kind, tiny_ds) as h:
        cache = SemanticResultCache(h, method="prefilter", threshold=None)
        want = cache.search(batch)

        def boom(*a, **kw):
            raise AssertionError("cache hit touched the search path")

        monkeypatch.setattr(h, "search", boom)
        monkeypatch.setattr(h, "run_method", boom, raising=False)
        hit = cache.search(batch)
        assert hit.cache == ["exact"] * batch.q
        np.testing.assert_array_equal(hit.ids, want.ids)
        cache.close()


@pytest.mark.parametrize("pred", ALL_PREDS)
def test_delete_then_hit_is_stale_miss(tiny_ds, tiny_queries, pred):
    """Deleting a served row evicts the entry: the next probe misses and
    refills to the post-delete oracle — the dead row never surfaces."""
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors[:6], qs.bitmaps[:6], pred, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        cache = SemanticResultCache(live, method="prefilter",
                                    threshold=None)
        filled = cache.search(batch)
        victims = np.unique(filled.ids[filled.ids >= 0].ravel())[:3]
        assert victims.size
        live.delete(victims)
        res = cache.search(batch)
        for qi in range(batch.q):
            if np.intersect1d(filled.ids[qi], victims).size:
                assert res.cache[qi] is None, \
                    "served a cached result whose rows were deleted"
            assert not np.intersect1d(res.ids[qi], victims).size
        _assert_same_result(res, live.search(batch, "prefilter"))
        # and the refilled entries hit again, fresh
        again = cache.search(batch)
        assert again.cache == ["exact"] * batch.q
        _assert_same_result(again, live.search(batch, "prefilter"))
        cache.close()


def test_upsert_shifts_topk_evicts(tiny_ds, tiny_queries):
    """An upsert that would change the top-k (a row exactly at the query
    point, matching labels) must evict — the old top-k is never served
    once the better row exists."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors[:4], qs.bitmaps[:4], Predicate.AND, 5)
    with LiveFilteredIndex(tiny_ds) as live:
        cache = SemanticResultCache(live, method="prefilter",
                                    threshold=None)
        cache.search(batch)
        new = live.upsert(batch.vectors, batch.bitmaps)  # dist-0 rows
        res = cache.search(batch)
        assert res.cache == [None] * batch.q
        want = live.search(batch, "prefilter")
        _assert_same_result(res, want)
        for qi in range(batch.q):
            assert int(new[qi]) in res.ids[qi], \
                "the upserted exact-match row must enter the top-k"
        cache.close()


def test_compact_mid_ttl_hit_survives_and_matches(tiny_ds, tiny_queries):
    """Compaction remaps ids but never changes the live row set, so a
    mid-TTL entry *survives* it — and the hit re-resolves through stable
    keys to match a fresh post-compaction search."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors[:6], qs.bitmaps[:6], Predicate.AND, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        # deletes + deltas so compaction actually remaps rows
        live.delete(np.arange(0, 40))
        live.upsert(tiny_ds.vectors[:10] + np.float32(0.05),
                    tiny_ds.bitmaps[:10])
        cache = SemanticResultCache(live, method="prefilter",
                                    threshold=None, ttl_s=3600.0)
        pre = cache.search(batch)
        gen0 = live.generation
        assert live.compact() > gen0 - 1
        assert live.generation != gen0
        hit = cache.search(batch)
        assert hit.cache == ["exact"] * batch.q, \
            "compaction alone must not evict (row set unchanged)"
        want = live.search(batch, "prefilter")
        np.testing.assert_array_equal(hit.ids, want.ids)
        np.testing.assert_array_equal(hit.keys, want.keys)
        np.testing.assert_allclose(hit.distances, want.distances,
                                   rtol=1e-5, atol=1e-5, equal_nan=True)
        # same rows as before the compaction, under stable keys
        np.testing.assert_array_equal(np.sort(hit.keys, axis=1),
                                      np.sort(pre.keys, axis=1))
        cache.close()


def test_disjoint_label_writes_do_not_evict(tiny_ds):
    """Invalidation is per-label-set, not global: writes touching only
    labels outside a cached predicate's set keep the entry hot."""
    from repro.ann import labels as lb

    w = tiny_ds.bitmaps.shape[1]
    qb = lb.pack_one([0], tiny_ds.universe)
    other = lb.pack_one([tiny_ds.universe - 1], tiny_ds.universe)
    qv = tiny_ds.vectors[:2]
    batch = QueryBatch(qv, np.broadcast_to(qb, (2, w)).copy(),
                       Predicate.AND, 5)
    with LiveFilteredIndex(tiny_ds) as live:
        cache = SemanticResultCache(live, method="prefilter",
                                    threshold=None)
        cache.search(batch)
        new = live.upsert(qv + np.float32(0.01),
                          np.broadcast_to(other, (2, w)).copy())
        live.delete(new[:1])
        res = cache.search(batch)
        assert res.cache == ["exact"] * 2, \
            "a disjoint-label write evicted a cached entry"
        _assert_same_result(res, live.search(batch, "prefilter"))
        cache.close()


# ---------------------------------------------------------------------------
# semantic path: neighbour's rows, exactly re-scored
# ---------------------------------------------------------------------------

def test_semantic_hit_rescores_exactly(tiny_ds, tiny_queries, rng):
    qs = tiny_queries[Predicate.AND]
    base = qs.vectors[:4]
    batch = QueryBatch(base, qs.bitmaps[:4], Predicate.AND, 5)
    with FilteredIndex(tiny_ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=0.95)
        filled = cache.search(batch)
        near = (base + rng.normal(0, 1e-4, base.shape)
                .astype(np.float32)).astype(np.float32)
        res = cache.search(QueryBatch(near, qs.bitmaps[:4],
                                      Predicate.AND, 5))
        assert res.cache == ["semantic"] * 4
        for qi in range(4):
            ids = res.ids[qi]
            assert set(ids.tolist()) == set(filled.ids[qi].tolist()), \
                "semantic hit must serve the cached neighbour's rows"
            valid = ids >= 0
            diff = tiny_ds.vectors[ids[valid]].astype(np.float64) \
                - near[qi].astype(np.float64)
            want = (diff ** 2).sum(axis=1)
            np.testing.assert_allclose(res.distances[qi][valid], want,
                                       rtol=1e-5, atol=1e-5)
            d = res.distances[qi][valid]
            assert np.all(np.diff(d) >= -1e-6), "re-scored rows unsorted"
        cache.close()


def test_semantic_requires_identical_bitmap(tiny_ds):
    """Near-identical vector under a *disjoint* label set must miss —
    the subset/superset transfer rule only applies when one filter is
    provably looser than the other, never across unrelated sets."""
    from repro.ann import labels as lb

    w = tiny_ds.bitmaps.shape[1]
    bm_a = np.broadcast_to(lb.pack_one([0], tiny_ds.universe),
                           (1, w)).copy()
    bm_b = np.broadcast_to(lb.pack_one([1], tiny_ds.universe),
                           (1, w)).copy()
    qv = tiny_ds.vectors[:1]
    with FilteredIndex(tiny_ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=0.9, rebuild_every=1)
        cache.search(QueryBatch(qv, bm_a, Predicate.AND, 5))
        res = cache.search(QueryBatch(qv, bm_b, Predicate.AND, 5))
        assert res.cache == [None]
        cache.close()


def test_semantic_threshold_none_disables(tiny_ds, tiny_queries, rng):
    qs = tiny_queries[Predicate.AND]
    base = qs.vectors[:2]
    with FilteredIndex(tiny_ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=None)
        cache.search(QueryBatch(base, qs.bitmaps[:2], Predicate.AND, 5))
        near = base + rng.normal(0, 1e-5, base.shape).astype(np.float32)
        res = cache.search(QueryBatch(near.astype(np.float32),
                                      qs.bitmaps[:2], Predicate.AND, 5))
        assert res.cache == [None, None]
        cache.close()


# ---------------------------------------------------------------------------
# subset/superset transfer rule: serve across provably-looser filters
# ---------------------------------------------------------------------------
#
# Controlled geometry: cluster A (4 rows, labels {0,1}) hugs the anchor,
# cluster B (4 rows, labels {1}) sits farther out, 24 decoys (label {2})
# far away. Which cached rows survive a tighter filter is then exact.

def _transfer_ds():
    from repro.ann.dataset import ANNDataset

    rng = np.random.default_rng(11)
    anchor = np.ones(8, np.float32)
    a = anchor + rng.normal(0, 0.01, (4, 8)).astype(np.float32)
    b = anchor + np.float32(0.5) \
        + rng.normal(0, 0.02, (4, 8)).astype(np.float32)
    far = rng.normal(5.0, 1.0, (24, 8)).astype(np.float32)
    vecs = np.concatenate([a, b, far]).astype(np.float32)
    labels = [[0, 1]] * 4 + [[1]] * 4 + [[2]] * 24
    return ANNDataset.build("transfer", vecs, labels, 6), anchor


def _one(vec, label_list, pred, k, universe=6):
    from repro.ann import labels as lb

    bm = lb.pack_one(label_list, universe)[None].astype(np.uint32)
    return QueryBatch(vec[None], bm, pred, k)


def test_transfer_or_superset_serves_oracle_topk():
    """OR: a cached superset-label entry transfers when every cached
    row passes the tighter filter — and then equals the oracle top-k."""
    ds, anchor = _transfer_ds()
    with FilteredIndex(ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=0.95)
        # k=4: the cached rows are exactly cluster A (labels {0,1})
        cache.search(_one(anchor, [0, 1], Predicate.OR, 4))
        probe = _one(anchor, [0], Predicate.OR, 4)
        res = cache.search(probe)
        assert res.cache == ["transfer"]
        _assert_same_result(res, fx.search(probe, "prefilter"))
        st = cache.stats()
        assert st["hits_transfer"] == 1
        assert st["hit_rate"] == pytest.approx(0.5)   # 1 hit / 1 miss
        cache.close()


def test_transfer_or_row_recheck_blocks_partial_entry():
    """OR: k=6 caches 4×{0,1} + 2×{1}; probing OR {0} must MISS — two
    cached rows fail the tighter filter, so the cached top-k is not the
    query's top-k. The refill then matches the oracle."""
    ds, anchor = _transfer_ds()
    with FilteredIndex(ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=0.95)
        cache.search(_one(anchor, [0, 1], Predicate.OR, 6))
        probe = _one(anchor, [0], Predicate.OR, 6)
        res = cache.search(probe)
        assert res.cache == [None]
        assert cache.stats()["hits_transfer"] == 0
        _assert_same_result(res, fx.search(probe, "prefilter"))
        cache.close()


def test_transfer_and_subset_serves_oracle_topk():
    """AND: a cached subset-label entry (looser: fewer required labels)
    transfers to a tighter query when every cached row carries all the
    query labels."""
    ds, anchor = _transfer_ds()
    with FilteredIndex(ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=0.95)
        # AND {1} admits A u B; k=4 caches exactly cluster A
        cache.search(_one(anchor, [1], Predicate.AND, 4))
        probe = _one(anchor, [0, 1], Predicate.AND, 4)
        res = cache.search(probe)
        assert res.cache == ["transfer"]
        _assert_same_result(res, fx.search(probe, "prefilter"))
        assert cache.stats()["hits_transfer"] == 1
        cache.close()


def test_transfer_and_row_recheck_blocks_partial_entry():
    ds, anchor = _transfer_ds()
    with FilteredIndex(ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=0.95)
        cache.search(_one(anchor, [1], Predicate.AND, 6))  # 4xA + 2xB
        probe = _one(anchor, [0, 1], Predicate.AND, 6)
        res = cache.search(probe)                # B rows lack label 0
        assert res.cache == [None]
        assert cache.stats()["hits_transfer"] == 0
        _assert_same_result(res, fx.search(probe, "prefilter"))
        cache.close()


def test_transfer_and_empty_cached_labels_never_serves():
    """An empty AND filter matches everything but stamps no labels, so
    the write clock could never invalidate it — the transfer rule must
    refuse it outright."""
    ds, anchor = _transfer_ds()
    with FilteredIndex(ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=0.95)
        cache.search(_one(anchor, [], Predicate.AND, 4))
        res = cache.search(_one(anchor, [0, 1], Predicate.AND, 4))
        assert res.cache == [None]
        assert cache.stats()["hits_transfer"] == 0
        cache.close()


def test_transfer_staleness_oracle_under_writes():
    """Transfer hits obey the label write clock in the *cached* entry's
    label set: an upsert touching label 0 (in the cached {0,1}) makes
    the next tighter-filter probe miss and refill to the post-write
    oracle — the pre-write top-k is never served."""
    ds, anchor = _transfer_ds()
    with LiveFilteredIndex(ds) as live:
        cache = SemanticResultCache(live, method="prefilter",
                                    threshold=0.95)
        cache.search(_one(anchor, [0, 1], Predicate.OR, 4))
        probe = _one(anchor, [0], Predicate.OR, 4)
        assert cache.search(probe).cache == ["transfer"]
        # distance-0 row with label {0}: enters the oracle top-k
        from repro.ann import labels as lb
        new = live.upsert(anchor[None],
                          lb.pack_one([0], 6)[None].astype(np.uint32))
        res = cache.search(probe)
        assert res.cache == [None], \
            "transfer served a pre-write entry after a relevant write"
        assert int(new[0]) in res.ids[0]
        _assert_same_result(res, live.search(probe, "prefilter"))
        cache.close()


def test_transfer_never_crosses_predicates():
    """A cached OR entry never transfers to an AND probe (or vice
    versa), even over the same label sets."""
    ds, anchor = _transfer_ds()
    with FilteredIndex(ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=0.95)
        cache.search(_one(anchor, [0, 1], Predicate.OR, 4))
        res = cache.search(_one(anchor, [0, 1], Predicate.AND, 4))
        # identical bitmap + vector, different predicate: its own part
        assert res.cache in ([None], ["exact"]) and res.cache == [None]
        assert cache.stats()["hits_transfer"] == 0
        cache.close()


# ---------------------------------------------------------------------------
# lifecycle knobs: TTL, capacity LRU, admission doorkeeper
# ---------------------------------------------------------------------------

def test_ttl_expiry_evicts(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.OR]
    batch = QueryBatch(qs.vectors[:3], qs.bitmaps[:3], Predicate.OR, 5)
    with FilteredIndex(tiny_ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=None, ttl_s=0.02)
        cache.search(batch)
        assert cache.search(batch).cache == ["exact"] * 3
        time.sleep(0.05)
        res = cache.search(batch)
        assert res.cache == [None] * 3
        assert cache.stats()["evictions_ttl"] == 3
        cache.close()


def test_capacity_lru_eviction(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    with FilteredIndex(tiny_ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=None, capacity=4)
        for i in range(8):
            cache.search(QueryBatch(qs.vectors[i:i + 1],
                                    qs.bitmaps[i:i + 1],
                                    Predicate.AND, 5))
        st = cache.stats()
        assert st["entries"] == 4
        assert st["evictions_capacity"] == 4
        # oldest 4 evicted, newest 4 still hit
        for i, want in zip((0, 7), (None, "exact")):
            res = cache.search(QueryBatch(qs.vectors[i:i + 1],
                                          qs.bitmaps[i:i + 1],
                                          Predicate.AND, 5))
            assert res.cache == [want]
        cache.close()


def test_admission_doorkeeper(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors[:2], qs.bitmaps[:2], Predicate.AND, 5)
    with FilteredIndex(tiny_ds) as fx:
        cache = SemanticResultCache(fx, method="prefilter",
                                    threshold=None, admit_after=2)
        cache.search(batch)
        assert cache.stats()["insertions"] == 0     # first miss: counted
        assert cache.search(batch).cache == [None, None]
        assert cache.stats()["insertions"] == 2     # second miss: admitted
        assert cache.search(batch).cache == ["exact", "exact"]
        cache.close()


def test_constructor_validation(tiny_ds):
    with FilteredIndex(tiny_ds) as fx:
        with pytest.raises(ValueError):
            SemanticResultCache(fx, method="prefilter", capacity=0)
        with pytest.raises(ValueError):
            SemanticResultCache(fx, method="prefilter", threshold=1.5)
        with pytest.raises(ValueError):
            SemanticResultCache(fx, method="prefilter", admit_after=0)
        with pytest.raises(ValueError):
            SemanticResultCache(fx)    # no router surface, no method=


# ---------------------------------------------------------------------------
# routed service + async queue integration
# ---------------------------------------------------------------------------

def test_routed_service_and_queue_probe(tiny_ds, tiny_queries,
                                        toy_router):
    from repro.ann.service import AsyncBatchQueue, RouterService
    from repro.ann.telemetry import TelemetrySink

    qs = tiny_queries[Predicate.AND]
    sink = TelemetrySink(reservoir=16)
    with FilteredIndex(tiny_ds) as fx:
        svc = RouterService(fx, toy_router, t=0.5, telemetry=sink)
        cache = SemanticResultCache(svc, threshold=None)
        batch = QueryBatch(qs.vectors[:4], qs.bitmaps[:4],
                           Predicate.AND, 5)
        first = cache.search(batch)
        assert first.decisions is not None      # misses were routed
        hit = cache.search(batch)
        assert hit.cache == ["exact"] * 4
        np.testing.assert_array_equal(hit.ids, first.ids)
        with AsyncBatchQueue(cache, max_batch=4, max_wait_ms=2.0) as q:
            a = q.submit(qs.vectors[10], qs.bitmaps[10],
                         Predicate.AND, 5).result(30)
            assert a.cache is None
            b = q.submit(qs.vectors[10], qs.bitmaps[10],
                         Predicate.AND, 5).result(30)
            assert b.cache == "exact"
            np.testing.assert_array_equal(a.ids, b.ids)
            np.testing.assert_array_equal(a.distances, b.distances)
            st = q.stats()
            assert st["cache_hits"] == 1
        counters = sink.stats()["counters"]
        assert counters["cache_hits_exact"] >= 5
        assert counters["cache_insertions"] >= 5
        cache.close()


# ---------------------------------------------------------------------------
# concurrency: threaded writer vs cached readers (PR-4 harness shape)
# ---------------------------------------------------------------------------

def test_cached_reads_racing_delete_and_compact_never_stale(tiny_ds,
                                                            tiny_queries):
    """A writer deletes rows and compacts while readers serve through
    the cache: a cache *hit* must never contain a key whose delete
    completed before the probe — version-counter invalidation may not
    be lost under interleaving."""
    qs = tiny_queries[Predicate.AND]
    batches = [QueryBatch(qs.vectors[i:i + 4], qs.bitmaps[i:i + 4],
                          Predicate.AND, 10) for i in range(0, 16, 4)]
    live = LiveFilteredIndex(tiny_ds)
    try:
        new_keys = live.keys_of(
            live.upsert(tiny_ds.vectors + np.float32(0.01),
                        tiny_ds.bitmaps))
        cache = SemanticResultCache(live, method="prefilter",
                                    threshold=None)
        deleted_keys: list[int] = []
        stop = threading.Event()
        errors: list[BaseException] = []
        compactions: list = []

        def writer():
            rng = np.random.default_rng(11)
            order = rng.permutation(tiny_ds.n)
            try:
                for i in range(160):
                    if stop.is_set():
                        break
                    key = int(new_keys[order[i]])
                    if live.delete_keys([key]):
                        deleted_keys.append(key)  # before readers see it
                    if i == 80:
                        compactions.append(live.compact_async())
            except BaseException as e:           # pragma: no cover
                errors.append(e)
            finally:
                stop.set()

        def reader():
            rng = np.random.default_rng(threading.get_ident() % 2**31)
            try:
                while not stop.is_set():
                    known = set(deleted_keys)    # before the probe
                    batch = batches[int(rng.integers(len(batches)))]
                    res = cache.search(batch)
                    for qi in range(batch.q):
                        if res.cache[qi] is None:
                            continue
                        served = set(
                            int(x) for x in res.keys[qi] if x >= 0)
                        dead = served & known
                        assert not dead, \
                            f"stale hit served deleted keys {dead}"
            except BaseException as e:
                errors.append(e)
                stop.set()

        th_w = threading.Thread(target=writer)
        readers = [threading.Thread(target=reader) for _ in range(3)]
        th_w.start()
        for th in readers:
            th.start()
        th_w.join(timeout=120)
        for th in readers:
            th.join(timeout=120)
        assert not errors, errors[0]
        for fut in compactions:       # drain the racing compaction
            fut.result(timeout=120)
        # quiescent state: the cache agrees with the oracle end-state
        for batch in batches:
            _assert_same_result(cache.search(batch),
                                live.search(batch, "prefilter"))
        cache.close()
    finally:
        live.close()


# ---------------------------------------------------------------------------
# lifecycle fuzz: randomized interleavings vs the oracle, shrinkable by seed
# ---------------------------------------------------------------------------

def _fuzz_round(tiny_ds, tmp_path, seed: int, n_ops: int) -> dict:
    """One seeded interleaving of upsert/delete/search/checkpoint/
    compact/cache-probe on a durable live index; every cache hit is
    checked bit-identical to a fresh oracle search in the same
    (single-threaded) state. Returns op/hit counts for sanity."""
    from repro.ann.store import IndexStore

    rng = np.random.default_rng(seed)
    qpool = [(tiny_ds.vectors[i:i + 2].copy(),
              tiny_ds.bitmaps[i:i + 2].copy(),
              Predicate(int(rng.integers(3))))
             for i in rng.integers(0, tiny_ds.n, 6)]
    counts = {"hits": 0, "probes": 0, "writes": 0}
    with IndexStore.create(str(tmp_path / f"fuzz{seed}"),
                           LiveFilteredIndex(tiny_ds)) as st:
        live = st.index
        cache = SemanticResultCache(live, method="prefilter",
                                    threshold=None, capacity=64)
        for step in range(n_ops):
            op = rng.random()
            if op < 0.25:                                     # upsert
                take = rng.integers(0, tiny_ds.n, rng.integers(1, 5))
                live.upsert(tiny_ds.vectors[take]
                            + np.float32(rng.normal(0, 0.01)),
                            tiny_ds.bitmaps[take])
                counts["writes"] += 1
            elif op < 0.45:                                   # delete
                stats = live.live_stats()
                n_live = stats.n_live
                if n_live > tiny_ds.n // 2:
                    with live.snapshot() as snap:
                        pool = np.nonzero(
                            ~snap.tombstones[:snap.base_n])[0]
                    if pool.size:
                        live.delete(pool[rng.integers(
                            0, pool.size, rng.integers(1, 4))])
                        counts["writes"] += 1
            elif op < 0.55:                                   # compact
                if rng.random() < 0.5:
                    live.compact()
                else:
                    live.compact_async().result(60)
            elif op < 0.62:                                   # checkpoint
                st.checkpoint()
            else:                                             # probe
                qv, qb, pred = qpool[int(rng.integers(len(qpool)))]
                batch = QueryBatch(qv, qb, pred,
                                   int(rng.integers(3, 12)))
                res = cache.search(batch)
                want = live.search(batch, "prefilter")
                counts["probes"] += 1
                for qi in range(batch.q):
                    if res.cache[qi] is not None:
                        counts["hits"] += 1
                try:
                    _assert_same_result(res, want)
                except AssertionError as e:   # shrink handle: seed+step
                    raise AssertionError(
                        f"fuzz divergence at seed={seed} step={step}: "
                        f"{e}") from e
        cache.close()
    return counts


def test_lifecycle_fuzz_bounded(tiny_ds, tmp_path):
    counts = _fuzz_round(tiny_ds, tmp_path, seed=1234, n_ops=60)
    assert counts["probes"] > 0
    assert counts["hits"] > 0, "fuzz never exercised the hit path"


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_lifecycle_fuzz_sweep(tiny_ds, tmp_path, seed):
    counts = _fuzz_round(tiny_ds, tmp_path, seed=seed, n_ops=250)
    assert counts["probes"] > 0


# ---------------------------------------------------------------------------
# label write clock (the invalidation signal itself)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sharded", [False, True])
def test_label_clock_stamps_exactly_touched_labels(tiny_ds, sharded):
    from repro.ann import labels as lb

    w = tiny_ds.bitmaps.shape[1]
    if sharded:
        live = ShardedLiveIndex(None, 2, name=tiny_ds.name,
                                dim=tiny_ds.dim,
                                universe=tiny_ds.universe)
        live.upsert(tiny_ds.vectors, tiny_ds.bitmaps)
    else:
        live = LiveFilteredIndex(tiny_ds)
    with live:
        c0 = live.label_clock()       # sharded setup upsert advances it
        bm = np.broadcast_to(lb.pack_one([3, 5], tiny_ds.universe),
                             (1, w)).copy()
        new = live.upsert(tiny_ds.vectors[:1], bm)
        c1 = live.label_clock()
        assert c1 > c0
        assert live.label_clock([3]) == c1
        assert live.label_clock([5]) == c1
        assert live.label_clock([4]) < c1
        live.delete(new)
        c2 = live.label_clock()
        assert c2 > c1 and live.label_clock([3]) == c2
        # deleting an already-dead id must not advance any stamp
        live.delete(new)
        assert live.label_clock([3]) == live.label_clock()
        # sealed handles: constant clock
    with FilteredIndex(tiny_ds) as fx:
        assert fx.label_clock() == 0 and fx.label_clock([0]) == 0
