"""Filtered-ANN method invariants on the tiny dataset, run through the
owned `FilteredIndex` handle."""

import numpy as np
import pytest

from repro.ann import bench
from repro.ann.dataset import recall_at_k
from repro.ann.methods import ALL_METHODS, CANDIDATE_METHODS
from repro.ann.predicates import Predicate, PREDICATES


@pytest.mark.parametrize("pred", PREDICATES)
def test_prefilter_recall_is_one(tiny_index, tiny_queries, pred):
    m = ALL_METHODS["prefilter"]
    r = bench.run_method(tiny_index, m, m.param_settings()[0],
                         tiny_queries[pred])
    assert r.mean_recall == pytest.approx(1.0)


@pytest.mark.parametrize("name", list(CANDIDATE_METHODS))
@pytest.mark.parametrize("pred", PREDICATES)
def test_results_satisfy_predicate(tiny_ds, tiny_index, tiny_queries, name, pred):
    """Every returned id must satisfy the query predicate (no false hits)."""
    m = CANDIDATE_METHODS[name]
    qs = tiny_queries[pred]
    r = bench.run_method(tiny_index, m, m.param_settings()[-1], qs)
    for qi in range(qs.q):
        mask = tiny_ds.matching_mask(qs.bitmaps[qi], pred)
        for vid in r.ids[qi]:
            if vid >= 0:
                assert mask[vid], (name, pred, qi, vid)


@pytest.mark.parametrize("name", list(CANDIDATE_METHODS))
def test_no_duplicate_results(tiny_index, tiny_queries, name):
    m = CANDIDATE_METHODS[name]
    qs = tiny_queries[Predicate.OR]
    r = bench.run_method(tiny_index, m, m.param_settings()[-1], qs)
    for qi in range(qs.q):
        ids = r.ids[qi][r.ids[qi] >= 0]
        assert len(ids) == len(set(ids.tolist())), (name, qi)


def test_labelnav_equality_exact(tiny_index, tiny_queries):
    """The UNG analogue is exact on Equality (its structural sweet spot)."""
    m = CANDIDATE_METHODS["labelnav"]
    r = bench.run_method(tiny_index, m, m.param_settings()[0],
                         tiny_queries[Predicate.EQUALITY])
    assert r.mean_recall == pytest.approx(1.0)


def test_param_settings_monotone_recall(tiny_index, tiny_queries):
    """Bigger search budgets should not reduce recall materially."""
    qs = tiny_queries[Predicate.AND]
    for name in ("postfilter", "ivf_gamma", "fvamana"):
        m = CANDIDATE_METHODS[name]
        settings = m.param_settings()
        lo = bench.run_method(tiny_index, m, settings[0], qs).mean_recall
        hi = bench.run_method(tiny_index, m, settings[-1], qs).mean_recall
        assert hi >= lo - 0.05, (name, lo, hi)


def test_recall_at_k_contract():
    gt = np.array([[1, 2, -1, -1], [5, 6, 7, 8]], dtype=np.int32)
    res = np.array([[2, 9, 9, 9], [5, 6, 7, 8]], dtype=np.int32)
    rec = recall_at_k(res, gt)
    assert rec[0] == pytest.approx(0.5)   # 1 of min(k=4,|TopK|=2)
    assert rec[1] == pytest.approx(1.0)


def test_empty_result_query(tiny_ds, tiny_index):
    """A label set absent from the dataset gives zero Equality matches."""
    from repro.ann import labels as lb
    from repro.ann.dataset import QuerySet

    qbm = lb.pack_one([0, 1, 2, 3, 4, 5, 6, 7], tiny_ds.universe)[None, :]
    if tiny_ds.group_id_of_bitmap(qbm[0]) >= 0:
        pytest.skip("label set unexpectedly present")
    qs = QuerySet(dataset="tiny", pred=Predicate.EQUALITY,
                  vectors=tiny_ds.vectors[:1].copy(), bitmaps=qbm,
                  ground_truth=np.full((1, 10), -1, np.int32), k=10)
    m = CANDIDATE_METHODS["labelnav"]
    r = bench.run_method(tiny_index, m, m.param_settings()[0], qs)
    assert (r.ids == -1).all()
    assert np.isinf(r.dists).all()        # score contract: +inf at −1 pad
    assert r.mean_recall == pytest.approx(1.0)   # vacuous query


def test_prefilter_kernel_path_parity(tiny_index, tiny_queries):
    """`PreFilter(use_kernel=True)` (the TPU `ops.masked_topk` route, in
    interpret mode here) matches the jnp reference path exactly."""
    from repro.ann.methods.prefilter import PreFilter

    ref, kern = PreFilter(use_kernel=False), PreFilter(use_kernel=True)
    st = ref.param_settings()[0]
    for pred in PREDICATES:
        qs = tiny_queries[pred]
        # keep the interpret-mode kernel cheap: 8 queries
        sub_v, sub_b = qs.vectors[:8], qs.bitmaps[:8]
        ids_ref, d_ref = ref.search(tiny_index, None, sub_v, sub_b,
                                    pred, qs.k, {})
        ids_k, d_k = kern.search(tiny_index, None, sub_v, sub_b,
                                 pred, qs.k, {})
        np.testing.assert_array_equal(ids_ref, ids_k)
        np.testing.assert_allclose(d_ref, d_k, rtol=1e-5, atol=1e-4)
