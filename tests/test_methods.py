"""Filtered-ANN method invariants on the tiny dataset."""

import numpy as np
import pytest

from repro.ann import bench
from repro.ann.dataset import recall_at_k
from repro.ann.methods import ALL_METHODS, CANDIDATE_METHODS
from repro.ann.predicates import Predicate, PREDICATES


@pytest.mark.parametrize("pred", PREDICATES)
def test_prefilter_recall_is_one(tiny_ds, tiny_queries, pred):
    m = ALL_METHODS["prefilter"]
    r = bench.run_method(tiny_ds, m, m.param_settings()[0], tiny_queries[pred])
    assert r.mean_recall == pytest.approx(1.0)


@pytest.mark.parametrize("name", list(CANDIDATE_METHODS))
@pytest.mark.parametrize("pred", PREDICATES)
def test_results_satisfy_predicate(tiny_ds, tiny_queries, name, pred):
    """Every returned id must satisfy the query predicate (no false hits)."""
    m = CANDIDATE_METHODS[name]
    qs = tiny_queries[pred]
    r = bench.run_method(tiny_ds, m, m.param_settings()[-1], qs)
    for qi in range(qs.q):
        mask = tiny_ds.matching_mask(qs.bitmaps[qi], pred)
        for vid in r.ids[qi]:
            if vid >= 0:
                assert mask[vid], (name, pred, qi, vid)


@pytest.mark.parametrize("name", list(CANDIDATE_METHODS))
def test_no_duplicate_results(tiny_ds, tiny_queries, name):
    m = CANDIDATE_METHODS[name]
    qs = tiny_queries[Predicate.OR]
    r = bench.run_method(tiny_ds, m, m.param_settings()[-1], qs)
    for qi in range(qs.q):
        ids = r.ids[qi][r.ids[qi] >= 0]
        assert len(ids) == len(set(ids.tolist())), (name, qi)


def test_labelnav_equality_exact(tiny_ds, tiny_queries):
    """The UNG analogue is exact on Equality (its structural sweet spot)."""
    m = CANDIDATE_METHODS["labelnav"]
    r = bench.run_method(tiny_ds, m, m.param_settings()[0],
                         tiny_queries[Predicate.EQUALITY])
    assert r.mean_recall == pytest.approx(1.0)


def test_param_settings_monotone_recall(tiny_ds, tiny_queries):
    """Bigger search budgets should not reduce recall materially."""
    qs = tiny_queries[Predicate.AND]
    for name in ("postfilter", "ivf_gamma", "fvamana"):
        m = CANDIDATE_METHODS[name]
        settings = m.param_settings()
        lo = bench.run_method(tiny_ds, m, settings[0], qs).mean_recall
        hi = bench.run_method(tiny_ds, m, settings[-1], qs).mean_recall
        assert hi >= lo - 0.05, (name, lo, hi)


def test_recall_at_k_contract():
    gt = np.array([[1, 2, -1, -1], [5, 6, 7, 8]], dtype=np.int32)
    res = np.array([[2, 9, 9, 9], [5, 6, 7, 8]], dtype=np.int32)
    rec = recall_at_k(res, gt)
    assert rec[0] == pytest.approx(0.5)   # 1 of min(k=4,|TopK|=2)
    assert rec[1] == pytest.approx(1.0)


def test_empty_result_query(tiny_ds):
    """A label set absent from the dataset gives zero Equality matches."""
    from repro.ann import labels as lb
    from repro.ann.dataset import QuerySet

    qbm = lb.pack_one([0, 1, 2, 3, 4, 5, 6, 7], tiny_ds.universe)[None, :]
    if tiny_ds.group_id_of_bitmap(qbm[0]) >= 0:
        pytest.skip("label set unexpectedly present")
    qs = QuerySet(dataset="tiny", pred=Predicate.EQUALITY,
                  vectors=tiny_ds.vectors[:1].copy(), bitmaps=qbm,
                  ground_truth=np.full((1, 10), -1, np.int32), k=10)
    m = CANDIDATE_METHODS["labelnav"]
    r = bench.run_method(tiny_ds, m, m.param_settings()[0], qs)
    assert (r.ids == -1).all()
    assert r.mean_recall == pytest.approx(1.0)   # vacuous query
