"""PR-6 live read path: fused single-launch kernel parity (vs the staged
three-launch path and the brute-force oracle), delta mini-IVF pruning
exactness, graft compaction bit-identity, and the open-addressing key
table."""

import numpy as np
import pytest

from repro.ann import ivf as ivf_mod
from repro.ann import registry as registry_mod
from repro.ann.index import QueryBatch
from repro.ann.live import (ChunkIndex, KeyTable, LiveFilteredIndex,
                            ShardedLiveIndex, build_chunk_index)
from repro.ann.predicates import Predicate, eval_predicate_np

ALL_PREDS = (Predicate.EQUALITY, Predicate.AND, Predicate.OR)
DENSITIES = (0.0, 0.5, 1.0)


def _oracle_ids(vectors, bitmaps, tomb, qv, qb, pred, k):
    """Exact masked top-k ids over an explicit (rows, tombstones) state."""
    norms = np.sum(vectors.astype(np.float64) ** 2, axis=1)
    out = np.full((qv.shape[0], k), -1, np.int32)
    for qi in range(qv.shape[0]):
        ok = eval_predicate_np(bitmaps, qb[qi][None], pred) & ~tomb
        idx = np.nonzero(ok)[0]
        if not idx.size:
            continue
        d = norms[idx] - 2.0 * vectors[idx] @ qv[qi].astype(np.float64)
        o = np.argsort(d, kind="stable")[:k]
        out[qi, : o.size] = idx[o]
    return out


def _gid_state(live):
    """(vectors, bitmaps, tombstones) in global-id order, for any live
    handle kind."""
    if isinstance(live, LiveFilteredIndex):
        dvec, dbm, _ = live._delta.host_view(live._delta.rows)
        if live._base_fx is not None:
            vec = np.concatenate([live.ds.vectors, dvec])
            bm = np.concatenate([live.ds.bitmaps, dbm])
        else:
            vec, bm = dvec, dbm
        return vec, bm, live._tomb.copy()
    n = live.n_total
    vec = np.zeros((n, live._dim), np.float32)
    bm = np.zeros((n, live.shards[0]._width), np.uint32)
    tomb = np.zeros(n, bool)
    host = {}
    for s, sh in enumerate(live.shards):
        host[s] = sh._delta.host_view(sh._delta.rows)
    for gid in range(n):
        s, lid = live._shard_local(gid)
        sh = live.shards[s]
        if lid < sh.base_n:
            vec[gid] = sh.ds.vectors[lid]
            bm[gid] = sh.ds.bitmaps[lid]
        else:
            vec[gid] = host[s][0][lid - sh.base_n]
            bm[gid] = host[s][1][lid - sh.base_n]
        tomb[gid] = sh._tomb[lid]
    return vec, bm, tomb


def _both_paths(live, batch):
    """(fused result, staged result) from the same handle state."""
    live.fused = True
    fused = live.search(batch, "prefilter")
    live.fused = False
    staged = live.search(batch, "prefilter")
    live.fused = True
    return fused, staged


def _assert_matches_oracle(ids, want, vec, bm, tomb, qv, qb, pred):
    """ids must equal the f64 brute-force oracle except where the
    competing rows' true distances agree to f32 resolution: the kernel
    ranks in f32, so near-ties may legitimately swap order. Every
    swapped-in id must still be a live predicate match at essentially
    the same distance."""
    if np.array_equal(ids, want):
        return
    norms = np.sum(vec.astype(np.float64) ** 2, axis=1)
    for qi in range(ids.shape[0]):
        a, b = ids[qi], want[qi]
        d = a != b
        if not d.any():
            continue
        # same fill count (how many matches exist is unambiguous)
        np.testing.assert_array_equal(a >= 0, b >= 0)
        d &= a >= 0
        ok = eval_predicate_np(bm, qb[qi][None], pred) & ~tomb
        assert ok[a[d]].all(), "swapped-in id is not a live match"
        assert np.unique(a[a >= 0]).size == (a >= 0).sum()
        q = qv[qi].astype(np.float64)
        da = norms[a[d]] - 2.0 * vec[a[d]] @ q
        db = norms[b[d]] - 2.0 * vec[b[d]] @ q
        np.testing.assert_allclose(da, db, rtol=1e-5, atol=1e-3)


def _check_parity(live, qs, pred, density, rng):
    vec, bm, tomb = _gid_state(live)
    for q_take, k in ((1, 5), (7, 41), (25, 10)):
        batch = QueryBatch(qs.vectors[:q_take], qs.bitmaps[:q_take], pred, k)
        fused, staged = _both_paths(live, batch)
        # the acceptance bar: fused is bit-identical to staged —
        # ids, distances AND keys
        np.testing.assert_array_equal(fused.ids, staged.ids)
        np.testing.assert_array_equal(fused.distances, staged.distances)
        np.testing.assert_array_equal(fused.keys, staged.keys)
        want = _oracle_ids(vec, bm, tomb, batch.vectors, batch.bitmaps,
                           pred, k)
        _assert_matches_oracle(fused.ids, want, vec, bm, tomb,
                               batch.vectors, batch.bitmaps, pred)
        if density >= 1.0:
            assert (fused.ids == -1).all()


@pytest.mark.parametrize("density", DENSITIES)
@pytest.mark.parametrize("pred", ALL_PREDS)
def test_fused_parity_single(tiny_ds, tiny_queries, pred, density, rng):
    """Fused vs staged vs oracle over base + delta + tombstones, ragged
    Q × k>matches × tombstone density, single handle."""
    qs = tiny_queries[pred]
    extra_v = tiny_ds.vectors[:150] + np.float32(0.01)
    extra_b = tiny_ds.bitmaps[:150]
    with LiveFilteredIndex(tiny_ds, delta_chunk=64) as live:
        live.upsert(extra_v, extra_b)
        n_tot = live.n_total
        if density > 0:
            take = int(round(n_tot * density))
            dead = rng.choice(n_tot, size=take, replace=False)
            live.delete(dead)
        _check_parity(live, qs, pred, density, rng)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("pred", ALL_PREDS)
def test_fused_parity_sharded(tiny_ds, tiny_queries, pred, n_shards, rng):
    """ShardedLiveIndex inherits the fused path: parity across shard
    counts with a compacted base plus fresh delta and 50% tombstones."""
    qs = tiny_queries[pred]
    with ShardedLiveIndex(None, n_shards, name="tiny", dim=tiny_ds.dim,
                          universe=tiny_ds.universe,
                          delta_chunk=64) as live:
        live.upsert(tiny_ds.vectors[:400], tiny_ds.bitmaps[:400])
        live.compact()
        live.upsert(tiny_ds.vectors[400:], tiny_ds.bitmaps[400:])
        dead = rng.choice(live.n_total, size=live.n_total // 2,
                          replace=False)
        live.delete(dead)
        _check_parity(live, qs, pred, 0.5, rng)


# ---------------------------------------------------------------------------
# delta mini-IVF pruning
# ---------------------------------------------------------------------------

def test_delta_prune_engages_and_stays_exact(tiny_ds, tiny_queries):
    """Sealed-chunk mini-IVF pruning must fire (far-away delta clusters
    are provably outside every query's bound) without changing a single
    result bit."""
    pred = Predicate.AND
    k = 10
    qs = tiny_queries[pred]
    # pruning is provably impossible for a query with fewer than k live
    # base matches (every matching delta row belongs in its top-k), and
    # one such query disables the batch-wide cluster drop — so the
    # engagement check runs on queries with enough base matches
    n_match = np.array([eval_predicate_np(tiny_ds.bitmaps, qb[None],
                                          pred).sum()
                        for qb in qs.bitmaps])
    keep = n_match >= k
    assert keep.sum() >= 5, "tiny spec should give dense AND queries"
    batch = QueryBatch(qs.vectors[keep], qs.bitmaps[keep], pred, k)
    far_v = tiny_ds.vectors[:192] + np.float32(50.0)   # 3 sealed chunks
    far_b = tiny_ds.bitmaps[:192]
    with LiveFilteredIndex(tiny_ds, delta_chunk=64,
                           delta_prune_min_rows=0) as pruned, \
            LiveFilteredIndex(tiny_ds, delta_chunk=64) as plain:
        for live in (pruned, plain):
            live.upsert(far_v, far_b)
        res_p = pruned.search(batch, "prefilter")
        res_f = plain.search(batch, "prefilter")
        plain.fused = False
        res_s = plain.search(batch, "prefilter")
        np.testing.assert_array_equal(res_p.ids, res_f.ids)
        np.testing.assert_array_equal(res_p.distances, res_f.distances)
        np.testing.assert_array_equal(res_p.ids, res_s.ids)
        np.testing.assert_array_equal(res_p.distances, res_s.distances)
        stats = pruned.stats()
        assert stats["delta_chunk_indexes"] == 3
        assert stats["delta_prune"]["pruned"] > 0


def test_chunk_index_deterministic_and_covers_chunk(rng):
    v = rng.normal(size=(64, 8)).astype(np.float32)
    a = build_chunk_index(v, seed=3)
    b = build_chunk_index(v, seed=3)
    for f in ("centroids", "cnorms", "radius", "members", "starts"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert sorted(a.members.tolist()) == list(range(64))
    # every member sits inside its cluster's claimed radius
    for c in range(a.centroids.shape[0]):
        rows = a.members[a.starts[c]: a.starts[c + 1]]
        d = np.linalg.norm(v[rows].astype(np.float64)
                           - a.centroids[c].astype(np.float64), axis=1)
        assert (d <= a.radius[c]).all()
    rt = ChunkIndex.from_arrays(a.arrays())
    np.testing.assert_array_equal(rt.members, a.members)


# ---------------------------------------------------------------------------
# graft compaction
# ---------------------------------------------------------------------------

def test_graft_ivf_bit_identical_to_frozen_rebuild(rng):
    n, d = 2000, 16
    v = rng.normal(size=(n, d)).astype(np.float32)
    old = ivf_mod.build_ivf(v, 24, seed=13)
    dead = rng.choice(n, 200, replace=False)
    keep = np.setdiff1d(np.arange(n), dead)
    nv = np.concatenate([v[keep],
                         rng.normal(size=(300, d)).astype(np.float32)])
    o2n = np.full(n, -1, np.int64)
    o2n[keep] = np.arange(keep.size)
    grafted = ivf_mod.graft_ivf(old, nv, o2n)
    assign = ivf_mod.assign_to_centroids(nv, old.centroids)
    lists, fill = ivf_mod.pack_lists(assign, old.centroids.shape[0])
    np.testing.assert_array_equal(grafted.lists, lists)
    np.testing.assert_array_equal(grafted.list_len, fill)
    np.testing.assert_array_equal(grafted.centroids, old.centroids)


def test_identity_graft_compaction_bit_identical_to_fresh_build(tiny_ds,
                                                                tiny_queries):
    """Compacting with no deletes and no delta is an identity remap, so
    the grafted indexes must equal a fresh offline build bit for bit."""
    reg = registry_mod.default_registry()
    pred = Predicate.AND
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        for m_name in ("ivf_gamma", "fvamana"):
            live.search(batch, m_name)        # forces the offline build
        before = dict(live._base_fx._indexes)
        assert before
        live.compact()
        after = dict(live._base_fx._indexes)
        assert set(after) == set(before)
        for (m_name, bp), idx in after.items():
            fresh = reg.get(m_name).build(live.ds, dict(bp))
            if isinstance(idx, ivf_mod.IVFIndex):
                np.testing.assert_array_equal(idx.centroids, fresh.centroids)
                np.testing.assert_array_equal(idx.lists, fresh.lists)
            else:                              # VamanaGraph
                np.testing.assert_array_equal(idx.neighbors, fresh.neighbors)
                assert idx.medoid == fresh.medoid
                np.testing.assert_array_equal(idx.label_entry,
                                              fresh.label_entry)


def test_graft_compaction_reuses_frozen_centroids(tiny_ds, tiny_queries,
                                                  rng):
    """With deletes + delta the graft must keep the old IVF centroids
    (proof the splice ran, not a rebuild) and repack exactly as a
    frozen-centroid reassignment of the compacted dataset; prefilter
    results stay bit-identical to the oracle afterwards."""
    pred = Predicate.AND
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        live.search(batch, "ivf_gamma")
        (key, old_idx), = [(k, v) for k, v in live._base_fx._indexes.items()]
        old_cent = old_idx.centroids.copy()
        live.upsert(tiny_ds.vectors[:100] + np.float32(0.02),
                    tiny_ds.bitmaps[:100])
        live.delete(rng.choice(tiny_ds.n, 60, replace=False))
        live.compact()
        new_idx = live._base_fx._indexes[key]
        np.testing.assert_array_equal(new_idx.centroids, old_cent)
        assign = ivf_mod.assign_to_centroids(live.ds.vectors, old_cent)
        lists, fill = ivf_mod.pack_lists(assign, old_cent.shape[0])
        np.testing.assert_array_equal(new_idx.lists, lists)
        np.testing.assert_array_equal(new_idx.list_len, fill)
        vec, bm, tomb = _gid_state(live)
        res = live.search(batch, "prefilter")
        want = _oracle_ids(vec, bm, tomb, batch.vectors, batch.bitmaps,
                           pred, 10)
        np.testing.assert_array_equal(res.ids, want)


def test_graft_disabled_falls_back_to_rebuild(tiny_ds, tiny_queries, rng):
    pred = Predicate.AND
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    with LiveFilteredIndex(tiny_ds, graft=False) as live:
        live.search(batch, "ivf_gamma")
        live.delete(rng.choice(tiny_ds.n, 60, replace=False))
        live.compact()
        (key, new_idx), = [(k, v)
                           for k, v in live._base_fx._indexes.items()]
        fresh = registry_mod.default_registry().get(key[0]).build(
            live.ds, dict(key[1]))
        np.testing.assert_array_equal(new_idx.centroids, fresh.centroids)
        np.testing.assert_array_equal(new_idx.lists, fresh.lists)


# ---------------------------------------------------------------------------
# open-addressing key table
# ---------------------------------------------------------------------------

def test_key_table_insert_lookup_missing(rng):
    t = KeyTable()
    keys = rng.choice(10 ** 12, size=5000, replace=False).astype(np.int64)
    rows = np.arange(5000, dtype=np.int64) * 3
    t.insert(keys, rows)
    np.testing.assert_array_equal(t.lookup(keys), rows)
    missing = keys + 1
    missing = missing[~np.isin(missing, keys)]
    assert (t.lookup(missing) == -1).all()
    assert t.lookup(np.zeros(0, np.int64)).size == 0


def test_key_table_last_wins_and_overwrite(rng):
    t = KeyTable()
    keys = np.array([7, 7, 9, 7], np.int64)
    rows = np.array([1, 2, 3, 4], np.int64)
    t.insert(keys, rows)                       # duplicate in one batch
    assert t.lookup(np.array([7], np.int64))[0] == 4
    assert t.lookup(np.array([9], np.int64))[0] == 3
    t.insert(np.array([9], np.int64), np.array([99], np.int64))
    assert t.lookup(np.array([9], np.int64))[0] == 99


def test_key_table_growth_keeps_all_entries(rng):
    t = KeyTable()
    for s in range(0, 40000, 1000):            # force several rehashes
        ks = np.arange(s, s + 1000, dtype=np.int64) * 7 + 1
        t.insert(ks, ks * 2)
    all_ks = np.arange(0, 40000, dtype=np.int64) * 7 + 1
    np.testing.assert_array_equal(t.lookup(all_ks), all_ks * 2)


# ---------------------------------------------------------------------------
# label-aware delta pruning (PR-7)
# ---------------------------------------------------------------------------

def test_chunk_index_label_bounds_exact(tiny_ds, rng):
    """label_union / label_inter are the exact bitwise OR / AND of each
    cluster's member bitmaps, and they round-trip through arrays()."""
    v = tiny_ds.vectors[:128]
    bm = tiny_ds.bitmaps[:128]
    ci = build_chunk_index(v, bitmaps=bm, seed=2)
    W = bm.shape[1]
    assert ci.label_union.shape == ci.label_inter.shape \
        == (ci.radius.size, W)
    for c in range(ci.radius.size):
        rows = ci.members[ci.starts[c]: ci.starts[c + 1]]
        if rows.size == 0:        # empty cluster: identity elements
            assert (ci.label_union[c] == 0).all()
            assert (ci.label_inter[c] == np.uint32(0xFFFFFFFF)).all()
            continue
        np.testing.assert_array_equal(
            ci.label_union[c], np.bitwise_or.reduce(bm[rows], axis=0))
        np.testing.assert_array_equal(
            ci.label_inter[c], np.bitwise_and.reduce(bm[rows], axis=0))
    rt = ChunkIndex.from_arrays(ci.arrays())
    np.testing.assert_array_equal(rt.label_union, ci.label_union)
    np.testing.assert_array_equal(rt.label_inter, ci.label_inter)


def test_chunk_index_without_bitmaps_stays_legacy(rng):
    """No bitmaps at build time (or a legacy npz without the label
    fields) -> label fields stay None and _label_drop contributes
    all-False columns."""
    v = rng.normal(size=(96, 8)).astype(np.float32)
    ci = build_chunk_index(v, seed=1)
    assert ci.label_union is None and ci.label_inter is None
    arrays = ci.arrays()
    assert "label_union" not in arrays
    rt = ChunkIndex.from_arrays(arrays)
    assert rt.label_union is None
    qb = np.ones((3, 2), np.uint32)
    batch = QueryBatch(np.zeros((3, 8), np.float32), qb,
                       Predicate.AND, 5)
    drop = LiveFilteredIndex._label_drop([rt], batch)
    assert drop.shape == (3, rt.radius.size)
    assert not drop.any()


@pytest.mark.parametrize("pred", ALL_PREDS)
def test_label_prune_parity_under_churn(tiny_ds, tiny_queries, pred, rng):
    """Fused results with label bounds active are bit-identical to the
    staged path for every predicate."""
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors[:16], qs.bitmaps[:16], pred, 10)
    pick = rng.integers(0, tiny_ds.n, 512)
    with LiveFilteredIndex(tiny_ds, delta_chunk=64,
                           delta_prune_min_rows=0) as live:
        live.upsert(tiny_ds.vectors[pick] + np.float32(0.01),
                    tiny_ds.bitmaps[pick])
        r1 = live.search(batch, "prefilter")
        live.fused = False
        r2 = live.search(batch, "prefilter")
        np.testing.assert_array_equal(r1.ids, r2.ids)
        np.testing.assert_array_equal(r1.distances, r2.distances)
        np.testing.assert_array_equal(r1.keys, r2.keys)
        assert live.stats()["delta_prune"]["calls"] > 0


def test_label_prune_fires_where_distance_bound_cannot(tiny_ds):
    """An empty base gives every query an infinite distance bound — only
    the label bounds can prune. Small sealed chunks make per-cluster
    unions narrow enough that selective EQUALITY queries drop clusters,
    and the result must still match the staged path bit for bit."""
    from repro.data.ann_synth import make_queries

    qs = make_queries(tiny_ds, Predicate.EQUALITY, 8, seed=4)
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.EQUALITY, 5)
    rng = np.random.default_rng(9)
    pick = rng.integers(0, tiny_ds.n, 512)
    with LiveFilteredIndex.empty("lbl_e", tiny_ds.dim, tiny_ds.universe,
                                 delta_chunk=64,
                                 delta_prune_min_rows=0) as live:
        live.upsert(tiny_ds.vectors[pick], tiny_ds.bitmaps[pick])
        r1 = live.search(batch, "prefilter")
        live.fused = False
        r2 = live.search(batch, "prefilter")
        np.testing.assert_array_equal(r1.ids, r2.ids)
        np.testing.assert_array_equal(r1.keys, r2.keys)
        st = live.stats()["delta_prune"]
        assert st["label_pruned"] > 0, st


def test_label_prune_drop_rules_directly(tiny_ds):
    """_label_drop's three predicate rules on a handcrafted cluster:
    union=0b0011, inter=0b0001."""
    union = np.array([[0b0011]], np.uint32)
    inter = np.array([[0b0001]], np.uint32)
    ci = ChunkIndex(centroids=np.zeros((1, 4), np.float32),
                    cnorms=np.zeros(1, np.float32),
                    radius=np.zeros(1, np.float32),
                    members=np.arange(2, dtype=np.int32),
                    starts=np.array([0, 2], np.int32),
                    label_union=union, label_inter=inter)

    def drop(bits, pred):
        qb = np.array([[bits]], np.uint32)
        b = QueryBatch(np.zeros((1, 4), np.float32), qb, pred, 3)
        return bool(LiveFilteredIndex._label_drop([ci], b)[0, 0])

    # OR: prune iff union shares no bit with q
    assert drop(0b0100, Predicate.OR) is True
    assert drop(0b0010, Predicate.OR) is False
    # AND: prune iff some q-bit is missing from the union
    assert drop(0b0110, Predicate.AND) is True
    assert drop(0b0011, Predicate.AND) is False
    # EQ: AND rule, plus a bit every member carries that q lacks
    assert drop(0b0010, Predicate.EQUALITY) is True   # inter bit 0 missing
    assert drop(0b0011, Predicate.EQUALITY) is False
