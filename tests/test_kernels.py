"""Pallas kernel validation (interpret mode) against the jnp oracles and
the legacy multi-block-merge path: shape/dtype sweeps, seeded random
bitmaps, ragged blocking, and edge cases."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand_case(rng, q, n, d, w, dtype=np.float32, label_density=0.1):
    qv = rng.normal(size=(q, d)).astype(dtype)
    base = rng.normal(size=(n, d)).astype(dtype)
    norms = (base.astype(np.float64) ** 2).sum(1).astype(np.float32)
    bm = (rng.random((n, w, 32)) < label_density)
    bm = (bm * (1 << np.arange(32, dtype=np.uint64))).sum(-1).astype(np.uint32)
    qb = (rng.random((q, w, 32)) < 0.05)
    qb = (qb * (1 << np.arange(32, dtype=np.uint64))).sum(-1).astype(np.uint32)
    return (jnp.asarray(qv), jnp.asarray(qb), jnp.asarray(base),
            jnp.asarray(norms), jnp.asarray(bm))


def _same_sets(ids_a, ids_b):
    for i in range(ids_a.shape[0]):
        a = set(np.asarray(ids_a[i][ids_a[i] >= 0]).tolist())
        b = set(np.asarray(ids_b[i][ids_b[i] >= 0]).tolist())
        if a != b:
            return False
    return True


@pytest.mark.parametrize("q,n,d,w", [
    (8, 1000, 32, 1), (16, 2048, 64, 4), (4, 300, 96, 2), (32, 4096, 128, 8),
])
@pytest.mark.parametrize("pred", [0, 1, 2])
def test_masked_topk_shapes(q, n, d, w, pred, rng):
    case = _rand_case(rng, q, n, d, w)
    ids, dists = ops.masked_topk(*case, pred=pred, k=10)
    rids, rdists = ref.masked_topk_ref(*case, pred=pred, k=10)
    assert ids.shape == (q, 10)
    assert _same_sets(ids, rids)
    # distances of valid hits must match
    valid = np.asarray(ids) >= 0
    np.testing.assert_allclose(np.asarray(dists)[valid],
                               np.asarray(rdists)[np.asarray(rids) >= 0],
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_masked_topk_dtypes(dtype, rng):
    case = _rand_case(rng, 8, 1024, 64, 2, dtype=np.float32)
    if dtype == jnp.bfloat16:
        case = (case[0].astype(jnp.bfloat16), case[1],
                case[2].astype(jnp.bfloat16), case[3], case[4])
    ids, _ = ops.masked_topk(*case, pred=2, k=5)
    rids, _ = ref.masked_topk_ref(*case, pred=2, k=5)
    assert _same_sets(ids, rids)


def test_masked_topk_no_matches(rng):
    qv, qb, base, norms, bm = _rand_case(rng, 4, 512, 16, 1)
    bm = jnp.zeros_like(bm)          # nothing matches AND/OR
    qb = jnp.ones_like(qb)
    ids, dists = ops.masked_topk(qv, qb, base, norms, bm, pred=1, k=10)
    assert (np.asarray(ids) == -1).all()


def test_masked_topk_fewer_than_k(rng):
    qv, qb, base, norms, bm = _rand_case(rng, 4, 512, 16, 1)
    bm = jnp.zeros_like(bm).at[:3].set(jnp.asarray(qb[0])[None, :])
    qb = jnp.tile(qb[:1], (4, 1))
    ids, _ = ops.masked_topk(qv, qb, base, norms, bm, pred=0, k=10)
    assert ((np.asarray(ids) >= 0).sum(1) == 3).all()


@pytest.mark.parametrize("q,n", [(1, 50), (7, 131), (16, 256), (40, 400)])
@pytest.mark.parametrize("pred", [0, 1, 2])
def test_selectivity_matches_ref(q, n, pred):
    rng = np.random.default_rng(q * 1000 + n)
    _, qb, _, _, bm = _rand_case(rng, q, n, 8, 2)
    got = ops.selectivity(qb, bm, pred=pred)
    want = ref.selectivity_ref(qb, bm, pred=pred)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_selectivity_empty_query_equality(rng):
    _, _, _, _, bm = _rand_case(rng, 2, 256, 8, 2)
    qb = jnp.zeros((2, 2), jnp.uint32)
    got = ops.selectivity(qb, bm, pred=0)
    want = ref.selectivity_ref(qb, bm, pred=0)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_kernel_block_shape_sweep(rng):
    case = _rand_case(rng, 16, 2048, 64, 2)
    want, _ = ref.masked_topk_ref(*case, pred=1, k=10)
    for bq, bn in [(8, 256), (16, 1024), (16, 2048)]:
        ids, _ = ops.masked_topk(*case, pred=1, k=10, bq=bq, bn=bn)
        assert _same_sets(ids, want), (bq, bn)


# ---------------------------------------------------------------------------
# VMEM-accumulating kernel vs legacy multi-block merge (parity)
# ---------------------------------------------------------------------------

def _assert_topk_parity(case, pred, k, **kw):
    ids, dists = ops.masked_topk(*case, pred=pred, k=k, **kw)
    mids, mdists = ops.masked_topk_multiblock(*case, pred=pred, k=k, **kw)
    assert ids.shape == mids.shape
    assert _same_sets(ids, mids), pred
    a, b = np.asarray(dists), np.asarray(mdists)
    np.testing.assert_allclose(np.sort(np.where(np.isinf(a), 1e30, a), axis=1),
                               np.sort(np.where(np.isinf(b), 1e30, b), axis=1),
                               rtol=1e-6, atol=1e-6)
    # valid-hit counts per query must agree exactly
    assert ((np.asarray(ids) >= 0).sum(1) ==
            (np.asarray(mids) >= 0).sum(1)).all()


@pytest.mark.parametrize("pred", [0, 1, 2])
def test_accum_matches_multiblock(pred, rng):
    case = _rand_case(rng, 16, 3072, 32, 2)
    _assert_topk_parity(case, pred, k=10, bq=8, bn=1024)


@pytest.mark.parametrize("q,n", [(5, 777), (13, 1025), (3, 100)])
@pytest.mark.parametrize("pred", [0, 1, 2])
def test_accum_matches_multiblock_ragged(q, n, pred):
    """Q/N not multiples of bq/bn: padding + sentinel cleanup parity."""
    rng = np.random.default_rng(q * 7 + n)
    case = _rand_case(rng, q, n, 16, 2)
    _assert_topk_parity(case, pred, k=7, bq=8, bn=256)


@pytest.mark.parametrize("pred", [0, 1, 2])
def test_accum_k_exceeds_matches(pred, rng):
    """k larger than the number of predicate-passing candidates."""
    qv, qb, base, norms, bm = _rand_case(rng, 4, 700, 16, 1)
    bm = jnp.zeros_like(bm).at[:5].set(jnp.asarray(qb[0])[None, :])
    qb = jnp.tile(qb[:1], (4, 1))
    case = (qv, qb, base, norms, bm)
    _assert_topk_parity(case, pred, k=16, bq=8, bn=256)


@pytest.mark.parametrize("pred", [0, 1, 2])
def test_accum_empty_label_queries(pred, rng):
    """All-zero query bitmaps: EQUALITY/AND match empty-label base rows
    (incl. vacuous containment), OR matches nothing."""
    qv, qb, base, norms, bm = _rand_case(rng, 6, 515, 16, 2)
    qb = jnp.zeros_like(qb)
    bm = bm.at[:4].set(0)            # a few empty-label base rows
    case = (qv, qb, base, norms, bm)
    _assert_topk_parity(case, pred, k=10, bq=8, bn=256)
    ids, _ = ops.masked_topk(*case, pred=pred, k=10, bq=8, bn=256)
    rids, _ = ref.masked_topk_ref(*case, pred=pred, k=10)
    assert _same_sets(ids, rids)


@pytest.mark.parametrize("pred", [0, 1, 2])
def test_accum_single_block(pred, rng):
    """N below one block: the nb axis degenerates to a single step."""
    case = _rand_case(rng, 4, 200, 16, 1)
    _assert_topk_parity(case, pred, k=5, bq=8, bn=256)


# ---------------------------------------------------------------------------
# cross-shard merge kernel
# ---------------------------------------------------------------------------

def _merge_case(rng, s, q, k, frac_valid=0.7):
    """Per-shard sorted top-k candidates with disjoint global ids and a
    random invalid suffix per (shard, query) row."""
    d = np.sort(np.abs(rng.normal(size=(s, q, k))).astype(np.float32), -1)
    ids = np.arange(s * q * k, dtype=np.int32).reshape(s, q, k)
    nval = rng.binomial(k, frac_valid, size=(s, q))
    for si in range(s):
        for qi in range(q):
            d[si, qi, nval[si, qi]:] = np.inf
            ids[si, qi, nval[si, qi]:] = -1
    return jnp.asarray(ids), jnp.asarray(d)


@pytest.mark.parametrize("s,q,k", [(1, 8, 10), (2, 17, 10), (4, 33, 5),
                                   (8, 8, 16)])
def test_merge_topk_matches_ref(s, q, k):
    rng = np.random.default_rng(s * 100 + q)
    ids, d = _merge_case(rng, s, q, k)
    gi, gd = ops.merge_topk(ids, d)
    ri, rd = ref.merge_topk_ref(ids, d)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(rd))


def test_merge_topk_narrower_k(rng):
    ids, d = _merge_case(rng, 4, 12, 10)
    gi, gd = ops.merge_topk(ids, d, k=3)
    ri, rd = ref.merge_topk_ref(ids, d, k=3)
    assert gi.shape == (12, 3)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(rd))


def test_merge_topk_all_invalid_rows(rng):
    ids, d = _merge_case(rng, 3, 9, 8)
    ids = np.asarray(ids).copy()
    d = np.asarray(d).copy()
    ids[:, 4, :] = -1
    d[:, 4, :] = np.inf
    gi, gd = ops.merge_topk(jnp.asarray(ids), jnp.asarray(d))
    assert (np.asarray(gi)[4] == -1).all()
    assert np.isinf(np.asarray(gd)[4]).all()
    ri, _ = ref.merge_topk_ref(jnp.asarray(ids), jnp.asarray(d))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))


def test_merge_topk_fewer_than_k_global(rng):
    """Fewer valid candidates than k across *all* shards: trailing −1s."""
    ids, d = _merge_case(rng, 2, 6, 10, frac_valid=0.15)
    gi, gd = ops.merge_topk(ids, d)
    ri, rd = ref.merge_topk_ref(ids, d)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    nval = (np.asarray(ids) >= 0).sum(axis=(0, 2))
    got = (np.asarray(gi) >= 0).sum(1)
    np.testing.assert_array_equal(got, np.minimum(nval, 10))


# ---------------------------------------------------------------------------
# live-index (delta path) edge cases: S=1 pass-through, k wider than the
# candidate axis, all-tombstoned segments
# ---------------------------------------------------------------------------

def test_merge_topk_single_segment_pass_through(rng):
    """S=1 skips the Pallas fold; semantics must be unchanged even for
    *unsorted* inputs with interleaved invalid slots."""
    d = np.abs(rng.normal(size=(1, 11, 8))).astype(np.float32)
    ids = rng.permutation(11 * 8).astype(np.int32).reshape(1, 11, 8)
    d[0, :, 3] = np.inf                    # invalid mid-row slots
    ids[0, :, 5] = -1
    gi, gd = ops.merge_topk(jnp.asarray(ids), jnp.asarray(d))
    ri, rd = ref.merge_topk_ref(jnp.asarray(ids), jnp.asarray(d))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(rd))


@pytest.mark.parametrize("s", [1, 3])
def test_merge_topk_k_exceeds_candidate_width(s, rng):
    """k > K (the delta segment holds fewer surviving candidates than
    requested): the surplus must come back as −1 ids / +inf dists."""
    ids, d = _merge_case(rng, s, 9, 4)
    gi, gd = ops.merge_topk(ids, d, k=10)
    ri, rd = ref.merge_topk_ref(ids, d, k=10)
    assert gi.shape == (9, 10)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(rd))
    nval = (np.asarray(ids) >= 0).sum(axis=(0, 2))
    np.testing.assert_array_equal((np.asarray(gi) >= 0).sum(1),
                                  np.minimum(nval, 10))


def test_merge_topk_all_invalid_everywhere(rng):
    """An all-tombstoned segment set: every slot invalid -> all −1/+inf
    (the exact-distance layer then reports NaN at the −1 pad)."""
    ids = np.full((2, 7, 6), -1, np.int32)
    d = np.full((2, 7, 6), np.inf, np.float32)
    gi, gd = ops.merge_topk(jnp.asarray(ids), jnp.asarray(d), k=5)
    assert (np.asarray(gi) == -1).all()
    assert np.isinf(np.asarray(gd)).all()
    from repro.ann.index import exact_distances
    dist = exact_distances(np.asarray(gd), np.asarray(gi),
                           np.zeros((7, 4), np.float32))
    assert np.isnan(dist).all()


def test_masked_topk_k_exceeds_rows(rng):
    """k larger than the whole (padded) segment: parity with the padded
    reference oracle, trailing −1s."""
    case = _rand_case(rng, 4, 40, 8, 1)
    ids, d = ops.masked_topk(*case, pred=1, k=64, bq=8, bn=256)
    rids, rd = ref.masked_topk_ref(*case, pred=1, k=64)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(rids))
    valid = np.asarray(ids) >= 0
    np.testing.assert_allclose(np.asarray(d)[valid],
                               np.asarray(rd)[valid], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# XLA fast path vs Pallas kernel (bit-identity)
# ---------------------------------------------------------------------------
#
# Off TPU the ops dispatch to a pure-XLA formulation of the same fold
# (stable top_k over candidates in kernel fold order). These tests force
# score ties (duplicated rows, a coarse value grid) and assert the two
# paths agree bit for bit — ids, distances and fill pattern — so the
# dispatch can never change a result depending on backend.
#
# Vectors live on an integer grid (multiples of 1/4) so every product
# and partial sum in the score matmul is exactly representable: the two
# backends may reduce in different orders (gemm edge kernels differ per
# shape) but must land on the same bits, making the comparison test the
# fold semantics rather than matmul rounding.

def _tie_case(rng, q, n, d=24, w=2):
    qv = (rng.integers(-6, 7, (q, d)) / 4.0).astype(np.float32)
    base = (rng.integers(-6, 7, (n, d)) / 4.0).astype(np.float32)
    base[n // 2: n // 2 + n // 4] = base[: n // 4]   # exact duplicates
    norms = (base.astype(np.float64) ** 2).sum(1).astype(np.float32)
    qb = (rng.integers(0, 2, (q, w)) * rng.integers(1, 8, (q, w))
          ).astype(np.uint32)
    bm = (rng.integers(0, 2, (n, w)) * rng.integers(1, 8, (n, w))
          ).astype(np.uint32)
    return (jnp.asarray(qv), jnp.asarray(qb), jnp.asarray(base),
            jnp.asarray(norms), jnp.asarray(bm))


def _assert_bitwise(a, b):
    ai, ad = np.asarray(a[0]), np.asarray(a[1])
    bi, bd = np.asarray(b[0]), np.asarray(b[1])
    np.testing.assert_array_equal(ai, bi)
    np.testing.assert_array_equal(np.isfinite(ad), np.isfinite(bd))
    np.testing.assert_array_equal(ad[np.isfinite(ad)], bd[np.isfinite(bd)])


@pytest.mark.parametrize("pred", [0, 1, 2])
@pytest.mark.parametrize("q,n,k", [(1, 64, 5), (7, 256, 41), (25, 1024, 10)])
def test_masked_topk_xla_matches_kernel(pred, q, n, k, rng):
    case = _tie_case(rng, q, n)
    _assert_bitwise(ops.masked_topk(*case, pred=pred, k=k),
                    ops.masked_topk(*case, pred=pred, k=k, interpret=True))


@pytest.mark.parametrize("s,q,kk,k", [(2, 8, 10, 10), (3, 25, 41, 10),
                                      (5, 64, 10, 41)])
def test_merge_topk_xla_matches_kernel(s, q, kk, k, rng):
    d = np.round(rng.normal(size=(s, q, kk)).astype(np.float32) ** 2, 1)
    ids = rng.integers(0, 10, (s, q, kk)).astype(np.int32)  # heavy id ties
    ids[d > 2.0] = -1
    args = (jnp.asarray(ids), jnp.asarray(d))
    _assert_bitwise(ops.merge_topk(*args, k=k),
                    ops.merge_topk(*args, k=k, interpret=True))


@pytest.mark.parametrize("pred", [0, 1, 2])
@pytest.mark.parametrize("q,nd,kb,k", [(1, 64, 5, 5), (7, 192, 41, 41),
                                       (25, 512, 10, 10)])
def test_fused_live_xla_matches_kernel(pred, q, nd, kb, k, rng):
    qv, qb, dvec, dn, db = _tie_case(rng, q, nd)
    ci = rng.integers(0, 4096, (q, kb)).astype(np.int32)
    ci[rng.random((q, kb)) < 0.2] = -1
    cd = np.round(rng.normal(size=(q, kb)).astype(np.float32) ** 2, 1)
    n_pad = (4096 + nd + 4095) // 4096 * 4096
    tomb = rng.random(n_pad) < 0.3
    tw = jnp.asarray(np.packbits(tomb, bitorder="little").view(np.uint32))
    args = (qv, qb, jnp.asarray(ci), jnp.asarray(cd), dvec, dn, db,
            jnp.int32(4096), tw)
    _assert_bitwise(ops.fused_live_topk(*args, pred=pred, k=k),
                    ops.fused_live_topk(*args, pred=pred, k=k,
                                        interpret=True))
    sel = jnp.asarray(np.unique(
        rng.integers(0, nd, nd // 2)).astype(np.int32))
    argsel = (qv, qb, jnp.asarray(ci), jnp.asarray(cd), dvec, dn, db,
              sel, jnp.int32(4096), tw)
    _assert_bitwise(ops.fused_live_topk_select(*argsel, pred=pred, k=k),
                    ops.fused_live_topk_select(*argsel, pred=pred, k=k,
                                               interpret=True))
