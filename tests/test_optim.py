"""Optimizer: AdamW correctness, 8-bit compressed moments, clipping,
schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamConfig, adam_init, adam_update,
                         clip_by_global_norm, cosine_schedule,
                         linear_warmup_cosine)
from repro.optim.adam import _quantize, _dequantize, adam_state_desc
from repro.models.common import ParamDesc, shape_structs


def _rosenbrock_steps(cfg, steps=300):
    params = {"x": jnp.asarray([-1.5, 2.0])}
    state = adam_init(params, cfg)

    def loss_fn(p):
        x, y = p["x"][0], p["x"][1]
        return (1 - x) ** 2 + 5.0 * (y - x ** 2) ** 2

    for _ in range(steps):
        grads = jax.grad(loss_fn)(params)
        params, state = adam_update(grads, state, params, cfg)
    return float(loss_fn(params))


def test_adam_minimises():
    assert _rosenbrock_steps(AdamConfig(lr=2e-2)) < 0.2


def test_adam_compressed_minimises():
    loss = _rosenbrock_steps(AdamConfig(lr=2e-2, compress=True, block=2))
    assert loss < 0.5     # 8-bit moments: slightly looser


def test_quantize_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))
    q, s = _quantize(x, 256)
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert s.shape == (4, 2)
    err = np.abs(np.asarray(_dequantize(q, s, 256)) - np.asarray(x)).max()
    assert err < float(jnp.abs(x).max()) / 100


def test_quantize_ragged_last_dim():
    x = jnp.ones((3, 100))      # 100 % 256 != 0 -> whole-row blocks
    q, s = _quantize(x, 256)
    assert q.shape == (3, 100) and s.shape == (3, 1)


def test_adam_state_desc_shapes():
    desc = {"w": ParamDesc((8, 512), tp=1, fsdp=0)}
    st = adam_state_desc(desc, AdamConfig(compress=True))
    assert st["mu"]["w"]["q"].shape == (8, 512)
    assert st["mu"]["w"]["q"].tp == 1 and st["mu"]["w"]["q"].fsdp == 0
    assert st["mu"]["w"]["s"].shape == (8, 2)
    structs = shape_structs(st)
    assert structs["nu"]["w"]["q"].dtype == jnp.int8
    st2 = adam_state_desc(desc, AdamConfig(compress=False))
    assert st2["mu"]["w"].shape == (8, 512)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert norm == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    s0 = float(linear_warmup_cosine(jnp.asarray(0), 10, 100))
    s5 = float(linear_warmup_cosine(jnp.asarray(5), 10, 100))
    s10 = float(linear_warmup_cosine(jnp.asarray(10), 10, 100))
    assert s0 == 0.0 and 0 < s5 < s10 <= 1.0
    end = float(cosine_schedule(jnp.asarray(100), 100, floor=0.1))
    assert end == pytest.approx(0.1)
