"""PR-9 observability: request-scoped span trees (`repro.ann.trace`),
tail-based sampling + flight recorder, Perfetto export invariants, the
Prometheus exposition (`repro.ann.metrics`), and trace correctness under
the async queue's thread hops and live-index compaction.

Well-formedness here means: every kept tree has exactly one root, every
span is closed (`t1` set), children lie inside their parent's bounds on
the shared monotonic clock, and the stage spans the pipeline promises
(enqueue_wait -> batch_assembly -> route -> execute) are all present —
even when route and execute ran on different worker threads.
"""

import json
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.ann import trace
from repro.ann.index import QueryBatch
from repro.ann.live import LiveFilteredIndex
from repro.ann.metrics import MetricsServer, metrics_text
from repro.ann.predicates import Predicate
from repro.ann.service import AsyncBatchQueue, RouterService
from repro.ann.telemetry import TelemetrySink
from repro.ann.trace import (BUCKET_BOUNDS_US, LatencyHistogram, Span,
                             Tracer, bucket_index, perfetto_json)


def _assert_well_formed(root):
    """One closed tree: every span finished, non-negative duration,
    children inside the parent's [t0, t1] on the monotonic clock."""
    for s in root.walk():
        assert s.t1 is not None, f"span {s.name!r} left open"
        assert s.t1 >= s.t0, f"span {s.name!r} negative duration"
        for c in s.children:
            assert c.t0 >= s.t0 - 1e-9, \
                f"{c.name!r} starts before parent {s.name!r}"
            assert c.t1 <= s.t1 + 1e-9, \
                f"{c.name!r} ends after parent {s.name!r}"


# ---------------------------------------------------------------- spans


def test_span_is_noop_without_active_trace():
    with trace.span("anything", x=1) as s:
        assert s is None
        trace.annotate(y=2)         # all silently ignored
        trace.count("n")
    assert trace.current() is None


def test_span_tree_nesting_attrs_and_annotate():
    tr = Tracer(sample=1.0)
    with tr.trace("root", q=4) as root:
        with trace.span("a") as a:
            trace.annotate(k=10)
            with trace.span("a1"):
                trace.count("rows", 3)
                trace.count("rows", 2)
        with trace.span("b"):
            pass
    assert [c.name for c in root.children] == ["a", "b"]
    assert a.attrs["k"] == 10
    assert root.find("a1").attrs["rows"] == 5
    assert root.attrs["q"] == 4
    _assert_well_formed(root)
    assert tr.stats()["traces"] == 1 and tr.stats()["kept"] == 1


def test_span_records_exception_and_trace_is_kept():
    tr = Tracer(sample=0.0)          # head sampling would drop it...
    with pytest.raises(RuntimeError):
        with tr.trace("root"):
            with trace.span("inner"):
                raise RuntimeError("boom")
    assert tr.stats()["errors"] == 1
    flight = tr.flight()             # ...but errors are always kept
    assert len(flight) == 1 and flight[0]["reason"] == "error"
    assert "boom" in flight[0]["error"]
    _assert_well_formed(flight[0]["root"])


def test_attach_propagates_across_threads_and_none_is_inert():
    tr = Tracer(sample=1.0)
    root = tr.start("request")

    def worker():
        with trace.attach(root):
            with trace.span("work", thread=True):
                pass

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    tr.finish(root)
    assert [c.name for c in root.children] == ["work"]
    _assert_well_formed(root)
    with trace.attach(None) as s:    # optional-root call sites
        assert s is None


def test_maybe_trace_nests_instead_of_double_rooting():
    tr = Tracer(sample=1.0)
    with trace.maybe_trace(tr, "outer"):
        with trace.maybe_trace(tr, "inner"):   # ambient active: nests
            pass
    assert tr.stats()["traces"] == 1           # one root, not two
    root = tr.recent()[-1]
    assert root.name == "outer"
    assert [c.name for c in root.children] == ["inner"]
    with trace.maybe_trace(None, "off") as s:  # no tracer, no ambient
        assert s is None


# ----------------------------------------------------- sampling policy


def test_tail_sampling_keeps_slow_drops_fast_deterministically():
    tr = Tracer(slow_ms=5.0, sample=0.0, seed=0)
    with tr.trace("fast"):
        pass
    with tr.trace("slow"):
        time.sleep(0.01)
    s = tr.stats()
    assert s["traces"] == 2 and s["slow"] == 1
    assert s["kept"] == 1 and s["dropped"] == 1
    flight = tr.flight()
    assert len(flight) == 1 and flight[0]["root"].name == "slow"
    assert flight[0]["reason"] == "slow"


def test_histograms_update_even_for_dropped_traces():
    tr = Tracer(sample=0.0)
    for _ in range(4):
        with tr.trace("search"):
            with trace.span("route"):
                pass
    assert tr.stats()["dropped"] == 4
    h = tr.histograms()
    assert h["search"]["count"] == 4 and h["route"]["count"] == 4
    assert sum(h["route"]["counts"]) == 4


def test_flight_recorder_bounded_ring():
    tr = Tracer(slow_ms=0.0, flight_capacity=3)
    for i in range(7):
        with tr.trace("r", i=i):
            pass
    flight = tr.flight()
    assert len(flight) == 3
    assert [f["root"].attrs["i"] for f in flight] == [4, 5, 6]
    assert [f["seq"] for f in flight] == sorted(f["seq"] for f in flight)


def test_flight_dump_json_roundtrips():
    tr = Tracer(slow_ms=0.0)
    with tr.trace("req", q=2):
        with trace.span("route"):
            trace.annotate(decisions=["m/ps"], table_version=7)
    doc = json.loads(tr.dump_flight_json())
    assert len(doc["flight"]) == 1
    rec = doc["flight"][0]
    assert rec["annotations"] == {"decisions": ["m/ps"],
                                  "table_version": 7}
    assert rec["trace"]["name"] == "req"
    assert rec["trace"]["children"][0]["name"] == "route"


# -------------------------------------------------- histogram buckets


def test_bucket_index_fixed_log2_bounds():
    assert BUCKET_BOUNDS_US[0] == 1.0 and BUCKET_BOUNDS_US[-1] == float("inf")
    assert len(BUCKET_BOUNDS_US) == 26
    assert bucket_index(0.0) == 0
    assert bucket_index(1.0) == 0
    assert bucket_index(1.5) == 1       # first bound strictly above
    assert bucket_index(2.0) == 1
    assert bucket_index(2.1) == 2
    assert bucket_index(1 << 24) == 24
    assert bucket_index(1e18) == 25     # +Inf bucket, never out of range
    # every observation lands in the first bucket whose bound covers it
    for us in (0.5, 1, 3, 7, 100, 1e6):
        i = bucket_index(us)
        assert us <= BUCKET_BOUNDS_US[i]
        if i:
            assert us > BUCKET_BOUNDS_US[i - 1]


def test_latency_histogram_observe_and_quantile():
    h = LatencyHistogram()
    for us in (1, 2, 4, 8, 1000):
        h.observe(us)
    assert h.count == 5 and h.sum_us == 1015
    assert sum(h.counts) == 5
    assert h.quantile_us(0.5) == 4.0    # bucket upper bound
    assert h.quantile_us(1.0) == 1024.0
    assert LatencyHistogram().quantile_us(0.5) == 0.0


# ------------------------------------------------------ perfetto export


def _stack_discipline_ok(events, eps=0.01):
    """Per tid, 'X' intervals sorted by ts must nest or be disjoint."""
    by_tid: dict = {}
    for ev in events:
        by_tid.setdefault(ev["tid"], []).append(ev)
    for tid, evs in by_tid.items():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for ev in evs:
            end = ev["ts"] + ev["dur"]
            while stack and ev["ts"] >= stack[-1] - eps:
                stack.pop()
            if stack:
                assert end <= stack[-1] + eps, \
                    f"tid {tid}: event {ev['name']} overlaps its parent"
            stack.append(end)
    return True


def test_perfetto_export_parses_and_nests():
    tr = Tracer(slow_ms=0.0)
    with tr.trace("req"):
        with trace.span("route"):
            time.sleep(0.001)
        with trace.span("execute"):
            with trace.span("group"):
                time.sleep(0.001)
    doc = json.loads(tr.perfetto_json())
    evs = doc["traceEvents"]
    assert {e["name"] for e in evs} == {"req", "route", "execute", "group"}
    for e in evs:
        assert e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0
    assert _stack_discipline_ok(evs)
    # parent bounds contain (clamped) children
    req = next(e for e in evs if e["name"] == "req")
    for e in evs:
        assert e["ts"] >= req["ts"] - 0.01
        assert e["ts"] + e["dur"] <= req["ts"] + req["dur"] + 0.01


def test_perfetto_overlapping_siblings_get_own_lanes():
    """Parallel fan-out produces overlapping sibling spans; the export
    must move them to fresh tids so each lane still nests."""
    root = Span("parent")
    t0 = root.t0
    a = root.child("shard0", t0=t0 + 0.001)
    a.t1 = t0 + 0.005
    b = root.child("shard1", t0=t0 + 0.002)   # overlaps shard0
    b.t1 = t0 + 0.006
    root.finish(t0 + 0.01)
    evs = json.loads(perfetto_json(root))["traceEvents"]
    tids = {e["name"]: e["tid"] for e in evs}
    assert tids["shard0"] != tids["shard1"]
    assert _stack_discipline_ok(evs)


def test_perfetto_empty_and_attrs_serialised():
    assert json.loads(perfetto_json([]))["traceEvents"] == []
    root = Span("r", {"arr": np.int32(3), "s": {1, 2}})
    root.finish()
    ev = json.loads(perfetto_json(root))["traceEvents"][0]
    assert ev["args"]["arr"] == 3 and sorted(ev["args"]["s"]) == [1, 2]


# -------------------------------------------- service + queue end-to-end


def _routed(tiny_index, toy_router, tracer, sink=None):
    return RouterService(tiny_index, toy_router, t=0.9, telemetry=sink,
                         tracer=tracer)


def test_service_search_traces_route_and_execute(tiny_ds, tiny_index,
                                                 toy_router, tiny_queries):
    tracer = Tracer(slow_ms=0.0)     # force: every query is "slow"
    svc = _routed(tiny_index, toy_router, tracer)
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors[:6], qs.bitmaps[:6], Predicate.AND, 5)
    svc.search(batch)
    flight = tracer.flight()
    assert flight, "forced-slow query missing from the flight recorder"
    rec = flight[-1]
    root = rec["root"]
    assert root.name == "search"
    route, execute = root.find("route"), root.find("execute")
    assert route is not None and execute is not None
    assert route.t1 <= execute.t0 + 1e-9     # route precedes execute
    assert execute.find("group").attrs["method"]
    _assert_well_formed(root)
    # RoutingDecision + table/generation provenance on the record
    assert rec["annotations"]["decisions"]
    assert "generation" in rec["annotations"]


def test_queue_traces_well_formed_across_thread_hops(tiny_ds, tiny_index,
                                                     toy_router,
                                                     tiny_queries):
    """Concurrent submitters -> route worker -> exec worker: every kept
    tree must be one well-formed root with the full stage ladder, and
    the per-root q attributes must account for every request exactly."""
    tracer = Tracer(slow_ms=0.0, flight_capacity=256)
    svc = _routed(tiny_index, toy_router, tracer)
    preds = (Predicate.AND, Predicate.OR)
    n_threads, per_thread = 4, 6
    results = []
    lock = threading.Lock()

    with AsyncBatchQueue(svc, max_batch=8, max_wait_ms=5.0) as queue:
        def submitter(tid):
            qs = tiny_queries[preds[tid % len(preds)]]
            futs = [queue.submit(qs.vectors[i], qs.bitmaps[i],
                                 preds[tid % len(preds)], k=5)
                    for i in range(per_thread)]
            got = [f.result(timeout=120) for f in futs]
            with lock:
                results.extend(got)

        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert len(results) == n_threads * per_thread
    assert all(r.ids.shape == (5,) for r in results)
    roots = [f["root"] for f in tracer.flight()]
    assert roots and all(r.name == "request" for r in roots)
    for root in roots:
        _assert_well_formed(root)
        names = [c.name for c in root.children]
        assert "enqueue_wait" in names
        assert "batch_assembly" in names
        assert "route" in names
        assert "execute" in names
        # the retroactive enqueue_wait child still nests in the root
        ew = root.find("enqueue_wait")
        assert ew.t0 >= root.t0 - 1e-9 and ew.t1 <= root.t1 + 1e-9
    assert sum(r.attrs["q"] for r in roots) == n_threads * per_thread
    assert tracer.stats()["errors"] == 0


def test_queue_traces_survive_compaction_mid_batch(tiny_ds, toy_router,
                                                   tiny_queries):
    """A writer thread upserts + compacts while the queue serves: trees
    stay well-formed, carry the live stage spans, and record the pinned
    generation."""
    tracer = Tracer(slow_ms=0.0, flight_capacity=256)
    with LiveFilteredIndex(tiny_ds) as live:
        svc = RouterService(live, toy_router, t=0.9, tracer=tracer)
        qs = tiny_queries[Predicate.AND]
        stop = threading.Event()
        rng = np.random.default_rng(0)

        def churn():
            rounds = 0
            while not stop.is_set():
                pick = rng.integers(0, tiny_ds.n, 16)
                live.upsert(tiny_ds.vectors[pick], tiny_ds.bitmaps[pick])
                if rounds % 3 == 0:  # compaction must race some batch
                    live.compact()
                rounds += 1
                stop.wait(0.01)      # yield: queries must make progress

        w = threading.Thread(target=churn)
        w.start()
        try:
            with AsyncBatchQueue(svc, max_batch=4,
                                 max_wait_ms=5.0) as queue:
                for round_ in range(3):
                    futs = [queue.submit(qs.vectors[i], qs.bitmaps[i],
                                         Predicate.AND, k=5)
                            for i in range(8)]
                    for f in futs:
                        assert f.result(timeout=120).ids.shape == (5,)
        finally:
            stop.set()
            w.join()
    roots = [f["root"] for f in tracer.flight()]
    assert roots
    pinned = 0
    for root in roots:
        _assert_well_formed(root)
        assert root.find("execute") is not None
        if root.find("snapshot_pin") is not None:
            pinned += 1
    assert pinned == len(roots)      # live handle: every batch pins
    assert tracer.stats()["errors"] == 0
    # perfetto export of real concurrent trees stays viewer-valid
    evs = json.loads(tracer.perfetto_json())["traceEvents"]
    assert _stack_discipline_ok(evs)


def test_cache_facade_produces_single_tree(tiny_ds, tiny_index,
                                           toy_router, tiny_queries):
    from repro.ann.cache import SemanticResultCache

    tracer = Tracer(slow_ms=0.0)
    svc = _routed(tiny_index, toy_router, tracer)
    cache = SemanticResultCache(svc, threshold=None)
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors[:4], qs.bitmaps[:4], Predicate.AND, 5)
    cache.search(batch)              # miss -> routed fill
    roots = [f["root"] for f in tracer.flight()]
    fill = roots[-1]
    assert fill.name == "cache_search"
    assert fill.find("cache.probe") is not None
    assert fill.find("search") is not None      # nested, not a 2nd root
    assert fill.find("route") is not None
    assert fill.find("cache.admit") is not None
    _assert_well_formed(fill)
    n_before = tracer.stats()["traces"]
    cache.search(batch)              # exact hit: no search subtree
    hits = [f["root"] for f in tracer.flight()][-1]
    assert tracer.stats()["traces"] == n_before + 1
    assert hits.find("route") is None
    cache.close()


# ------------------------------------------------------------- metrics


def test_metrics_text_exposition_format():
    sink = TelemetrySink(capacity=16, reservoir=0)
    bm = np.zeros((3, 1), np.uint32)
    batch = QueryBatch(np.zeros((3, 4), np.float32), bm, Predicate.OR, 3)
    sink.record_batch(batch, ("m", "p"), search_s=3e-3, shard=1)
    sink.note_shard(1, "exec", 2e-3, 3)
    tracer = Tracer(slow_ms=0.0)
    with tracer.trace("search"):
        pass
    text = metrics_text(sink=sink, tracer=tracer)
    lines = text.splitlines()
    assert "# TYPE ann_queries_total counter" in lines
    assert "ann_queries_total 3" in lines
    assert 'ann_cell_queries_total{method="m",ps="p",pred="OR"} 3' in lines
    assert 'ann_shard_stage_seconds_total{shard="1",stage="exec"} 0.002' \
        in lines
    assert 'ann_traces_total{outcome="traces"} 1' in lines
    # histogram: cumulative buckets, +Inf bucket equals _count
    buckets = [ln for ln in lines
               if ln.startswith('ann_span_latency_us_bucket{span="search"')]
    assert len(buckets) == len(BUCKET_BOUNDS_US)
    counts = [float(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert counts == sorted(counts)            # cumulative
    inf_line = next(ln for ln in buckets if 'le="+Inf"' in ln)
    total = next(ln for ln in lines
                 if ln.startswith('ann_span_latency_us_count'))
    assert inf_line.rsplit(" ", 1)[1] == total.rsplit(" ", 1)[1]
    # every non-comment line is "name{labels} value" or "name value"
    for ln in lines:
        if ln.startswith("#"):
            continue
        name, val = ln.rsplit(" ", 1)
        float(val)
        assert name and " " not in name.split("{")[0]


def test_metrics_label_escaping():
    sink = TelemetrySink(capacity=8, reservoir=0)
    sink.note('we"ird\\stage_s', 1.0)
    text = metrics_text(sink=sink)
    assert 'name="we\\"ird\\\\stage_s"' in text


def test_metrics_text_empty_exporter_is_up():
    text = metrics_text()
    assert "ann_up 1" in text


def test_metrics_server_endpoints():
    tracer = Tracer(slow_ms=0.0)
    with tracer.trace("search"):
        pass
    srv = MetricsServer(lambda: metrics_text(tracer=tracer),
                        health=lambda: {"traces": tracer.stats()["traces"]})
    try:
        r = urllib.request.urlopen(srv.url + "/metrics", timeout=10)
        assert r.status == 200
        assert r.headers["Content-Type"].startswith(
            "text/plain; version=0.0.4")
        body = r.read().decode()
        assert 'ann_traces_total{outcome="traces"} 1' in body
        h = urllib.request.urlopen(srv.url + "/healthz", timeout=10)
        payload = json.loads(h.read())
        assert payload == {"status": "ok", "traces": 1}
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/nope", timeout=10)
        assert ei.value.code == 404
    finally:
        srv.close()


def test_metrics_server_render_error_surfaces_as_500():
    srv = MetricsServer(lambda: 1 / 0)
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/metrics", timeout=10)
        assert ei.value.code == 500
    finally:
        srv.close()
