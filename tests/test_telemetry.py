"""PR-7 serving telemetry + online adaptation: sink ring/counters/
reservoir semantics, exact-recall audits on pinned snapshots, EWMA
online table (versioning, drift, cache), and the end-to-end adaptation
loop — injected recall regression -> audits fold -> table-driven
re-route -> retrain -> shadow-eval promote/rollback through the
versioned-artifact store machinery."""

import os
import threading

import numpy as np
import pytest

from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.live import LiveFilteredIndex
from repro.ann.predicates import Predicate
from repro.ann.registry import candidate_methods
from repro.ann.service import RouterService
from repro.ann.store import IndexStore
from repro.ann.telemetry import (DegradedMethod, OnlineBenchmarkTable,
                                 OnlineRouterAdapter, RecallAuditor,
                                 TelemetrySink, _audit_recall,
                                 constant_router)
from repro.core import features as F
from repro.core.router import MLRouter, artifact_versions
from repro.core.table import BenchmarkTable
from repro.data.ann_synth import make_queries


def _batch(tiny_ds, pred=Predicate.AND, q=32, k=10, seed=3):
    qs = make_queries(tiny_ds, pred, q, seed=seed)
    return QueryBatch(qs.vectors, qs.bitmaps, pred, k)


def _two_method_table(ds_name, *, degraded="ivf_gamma", alt="postfilter",
                      degraded_qps=5000.0, alt_qps=500.0):
    """Both methods pass t=0.9 offline; the degraded one has the best
    QPS, so Algorithm 2 routes everything to it until audits say
    otherwise."""
    cand = candidate_methods()
    table = BenchmarkTable.new()
    for pt in range(3):
        for s in cand[degraded].param_settings():
            table.add(ds_name, pt, degraded, s.ps_id, 0.97, degraded_qps)
        for s in cand[alt].param_settings():
            table.add(ds_name, pt, alt, s.ps_id, 0.95, alt_qps)
    return table


# ------------------------------------------------------------------ sink


def test_sink_records_counters_cells_and_percentiles():
    sink = TelemetrySink(capacity=64, reservoir=0)
    bm = np.zeros((4, 2), np.uint32)
    vec = np.zeros((4, 8), np.float32)
    batch = QueryBatch(vec, bm, Predicate.OR, 5)
    sink.record_batch(batch, ("m1", "ps0"), search_s=4e-3)
    sink.record_batch(batch, ("m2", "ps1"), search_s=8e-3)
    s = sink.stats()
    assert s["queries"] == 8 and s["batches"] == 2
    assert s["ring_events"] == 8
    assert s["by_method"] == {"m1": 4, "m2": 4}
    # per-query share: 4ms/4 = 1000us and 8ms/4 = 2000us
    assert s["cells"]["m1/ps0/OR"] == {"queries": 4, "mean_us": 1000.0}
    assert s["cells"]["m2/ps1/OR"] == {"queries": 4, "mean_us": 2000.0}
    assert s["latency_us"]["p50"] == pytest.approx(1500.0)
    sink.note("queue_wait_s", 0.5)
    sink.note("queue_wait_s", 0.25)
    assert sink.stats()["counters"]["queue_wait_s"] == 0.75


def test_sink_ring_wraps_but_totals_are_monotone():
    sink = TelemetrySink(capacity=16, reservoir=0)
    bm = np.zeros((8, 1), np.uint32)
    batch = QueryBatch(np.zeros((8, 4), np.float32), bm, Predicate.AND, 3)
    for _ in range(10):
        sink.record_batch(batch, ("m", "p"), search_s=1e-3)
    s = sink.stats()
    assert s["ring_events"] == 16          # ring holds only the tail
    assert s["queries"] == 80              # totals keep counting
    assert sink.seen_events() == 80


def test_sink_per_query_decisions():
    sink = TelemetrySink(capacity=32, reservoir=0)
    bm = np.zeros((3, 1), np.uint32)
    batch = QueryBatch(np.zeros((3, 4), np.float32), bm, Predicate.AND, 3)
    decs = [("a", "p0"), ("b", "p1"), ("a", "p0")]
    sink.record_batch(batch, decs, search_s=3e-3)
    assert sink.stats()["by_method"] == {"a": 2, "b": 1}
    assert sink.stats()["batches"] == 1


def test_sink_reservoir_caps_drains_and_copies():
    sink = TelemetrySink(capacity=8, reservoir=10, seed=1)
    vec = np.arange(32 * 4, dtype=np.float32).reshape(32, 4)
    bm = np.ones((32, 1), np.uint32)
    keys = np.arange(32 * 3, dtype=np.int64).reshape(32, 3)
    batch = QueryBatch(vec, bm, Predicate.AND, 3)
    for _ in range(4):
        sink.record_batch(batch, ("m", "p"), search_s=1e-3, keys=keys)
    st = sink.stats()["reservoir"]
    assert st["size"] == 10 and st["seen"] == 128
    samples = sink.take_samples()
    assert len(samples) == 10
    for s in samples:
        assert s.vector.shape == (4,) and s.served_keys.shape == (3,)
        assert s.method == "m" and s.k == 3
        # copies, not views into the caller's batch
        assert not np.shares_memory(s.vector, vec)
    assert sink.take_samples() == []       # drained and reset
    assert sink.stats()["reservoir"]["seen"] == 0


def test_drain_cells_resets_fresh_but_stats_stay_cumulative():
    sink = TelemetrySink(capacity=8, reservoir=0)
    bm = np.zeros((4, 1), np.uint32)
    batch = QueryBatch(np.zeros((4, 4), np.float32), bm, Predicate.OR, 3)
    sink.record_batch(batch, ("m", "p"), search_s=4e-3)
    cells = sink.drain_cells()
    assert cells == {("m", "p", int(Predicate.OR)): (4, 1000.0)}
    assert sink.drain_cells() == {}            # drained
    assert sink.stats()["cells"]["m/p/OR"]["queries"] == 4   # cumulative
    sink.record_batch(batch, ("m", "p"), search_s=8e-3)
    assert sink.drain_cells()[("m", "p", int(Predicate.OR))] == (4, 2000.0)


def test_sink_concurrent_writers_keep_exact_totals():
    sink = TelemetrySink(capacity=256, reservoir=32, seed=0)
    bm = np.zeros((4, 1), np.uint32)
    batch = QueryBatch(np.zeros((4, 4), np.float32), bm, Predicate.AND, 3)

    def writer():
        for _ in range(50):
            sink.record_batch(batch, ("m", "p"), search_s=1e-3)

    threads = [threading.Thread(target=writer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    s = sink.stats()
    assert s["queries"] == 800 and s["batches"] == 200
    assert s["cells"]["m/p/AND"]["queries"] == 800


def test_sink_rejects_bad_sizes():
    with pytest.raises(ValueError):
        TelemetrySink(capacity=0)
    with pytest.raises(ValueError):
        TelemetrySink(reservoir=-1)


# ------------------------------------------------------- per-shard cells


def test_sink_shard_cells_fold_and_report():
    sink = TelemetrySink(capacity=16, reservoir=0)
    sink.note_shard(0, "exec", 2e-3, 4)
    sink.note_shard(0, "exec", 4e-3, 4)
    sink.note_shard(1, "exec", 1e-3, 4)
    agg = sink.shard_aggregates()
    assert agg[(0, "exec")] == (8, pytest.approx(6e-3))
    assert agg[(1, "exec")] == (4, pytest.approx(1e-3))
    s = sink.stats()["shards"]
    assert s["shard0/exec"]["calls"] == 8
    assert s["shard0/exec"]["total_s"] == pytest.approx(6e-3)
    assert s["shard0/exec"]["mean_us"] == pytest.approx(750.0)
    assert s["shard1/exec"]["mean_us"] == pytest.approx(250.0)


def test_sink_events_carry_shard_and_monotonic_clock():
    sink = TelemetrySink(capacity=16, reservoir=0)
    bm = np.zeros((3, 1), np.uint32)
    batch = QueryBatch(np.zeros((3, 4), np.float32), bm, Predicate.AND, 3)
    sink.record_batch(batch, ("m", "p"), search_s=1e-3, shard=2)
    sink.record_batch(batch, ("m", "p"), search_s=1e-3)
    evs = sink.recent()
    assert [e.shard for e in evs] == [2, 2, 2, -1, -1, -1]
    # monotonic stamps order the ring even if the wall clock steps
    monos = [e.t_mono for e in evs]
    assert all(m > 0 for m in monos)
    assert monos == sorted(monos)


def test_sharded_execute_folds_per_shard_exec_cells(tiny_ds, toy_router,
                                                    tiny_queries):
    """ShardedFilteredIndex execution reports shard{j}_s wall seconds;
    the service folds them into the sink's (shard, 'exec') cells and
    keeps the straggler visible as the shard_max_s counter."""
    from repro.ann.service import ShardedRouterService
    from repro.ann.sharded import ShardedFilteredIndex

    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 5)
    with ShardedFilteredIndex(tiny_ds, 2) as sfx:
        sink = TelemetrySink(capacity=64, reservoir=0)
        svc = ShardedRouterService(sfx, toy_router, t=0.9, telemetry=sink)
        res = svc.search(batch)
        assert {"shard0_s", "shard1_s", "shard_max_s",
                "merge_s"} <= res.timings.keys()
        assert res.timings["shard_max_s"] == pytest.approx(
            max(res.timings["shard0_s"], res.timings["shard1_s"]))
        agg = sink.shard_aggregates()
        assert (0, "exec") in agg and (1, "exec") in agg
        assert agg[(0, "exec")][0] == batch.q      # q queries folded
        assert agg[(0, "exec")][1] == pytest.approx(
            res.timings["shard0_s"])
        counters = sink.stats()["counters"]
        assert counters["shard_max_s"] == pytest.approx(
            res.timings["shard_max_s"])


# --------------------------------------------------------------- auditor


def test_audit_recall_helper():
    assert _audit_recall(np.array([1, 2, 3]), np.array([1, 2, 3]), 3) == 1.0
    assert _audit_recall(np.array([1, -1, -1]), np.array([1, 2, 3]), 3) \
        == pytest.approx(1 / 3)
    # vacuous predicate (no matching rows) counts as perfect
    assert _audit_recall(np.array([-1]), np.array([-1, -1]), 5) == 1.0
    # fewer exact matches than k: denominator is |exact|
    assert _audit_recall(np.array([7, 8, -1]), np.array([7, -1, -1]), 3) \
        == 1.0


def test_auditor_exact_recall_against_oracle(tiny_ds, tiny_index):
    """Served keys taken from the oracle itself audit at exactly 1.0;
    truncating them to 3 of k=10 audits at exactly 0.3 (selective
    predicates with < k matches stay 1.0 by the min(k, |exact|) rule)."""
    batch = _batch(tiny_ds, Predicate.AND, q=16)
    exact = tiny_index.search(batch, "prefilter")
    served = exact.keys if exact.keys is not None else exact.ids
    sink = TelemetrySink(capacity=64, reservoir=64)
    sink.record_batch(batch, ("prefilter", "full"), search_s=1e-3,
                      keys=served)
    auditor = RecallAuditor(tiny_index, sink)
    rep = auditor.run_once()
    assert rep["samples"] == 16
    assert all(r == 1.0 for _s, r, _e in rep["results"])

    truncated = np.array(served, copy=True)
    truncated[:, 3:] = -1
    sink.record_batch(batch, ("prefilter", "full"), search_s=1e-3,
                      keys=truncated)
    rep = auditor.run_once()
    n_exact = (np.asarray(served) >= 0).sum(axis=1)
    for (s, r, _e), ne in zip(rep["results"], n_exact):
        assert r == pytest.approx(min(3, ne) / min(batch.k, ne))
    assert auditor.runs == 2 and auditor.audits == 32


def test_auditor_folds_cells_into_table(tiny_ds, tiny_index):
    table = OnlineBenchmarkTable(
        _two_method_table(tiny_ds.name), alpha=0.5)
    batch = _batch(tiny_ds, Predicate.AND, q=8)
    exact = tiny_index.search(batch, "prefilter")
    served = np.array(exact.keys if exact.keys is not None else exact.ids,
                      copy=True)
    served[:, 2:] = -1            # serve 2 of k=10 -> low audited recall
    sink = TelemetrySink(capacity=64, reservoir=64)
    ps = candidate_methods()["ivf_gamma"].param_settings()[-1].ps_id
    sink.record_batch(batch, ("ivf_gamma", ps), search_s=1e-3, keys=served)
    v0 = table.version
    RecallAuditor(tiny_index, sink, table=table).run_once()
    key = (tiny_ds.name, int(Predicate.AND), "ivf_gamma", ps)
    assert table.version > v0
    audited = table.audited_cells()[key]
    exact_keys = exact.keys if exact.keys is not None else exact.ids
    want = np.mean([_audit_recall(served[j], exact_keys[j], batch.k)
                    for j in range(batch.q)])
    assert audited["n"] == 8
    assert audited["recall"] == pytest.approx(want)
    # EWMA(alpha=.5) pulled the published cell halfway toward measured
    assert table.entries[key]["recall"] == \
        pytest.approx(0.5 * 0.97 + 0.5 * want)


def test_audit_keys_stable_across_compaction(tiny_ds):
    """Serve exact results on a live index, then compact (rows remap)
    before auditing: stable external keys keep every audit at 1.0."""
    with LiveFilteredIndex(tiny_ds) as live:
        rng = np.random.default_rng(2)
        pick = rng.integers(0, tiny_ds.n, 200)
        live.upsert(tiny_ds.vectors[pick] + np.float32(0.01),
                    tiny_ds.bitmaps[pick])
        batch = _batch(tiny_ds, Predicate.AND, q=12)
        res = live.search(batch, "prefilter")
        sink = TelemetrySink(capacity=64, reservoir=64)
        sink.record_batch(batch, ("prefilter", "full"), search_s=1e-3,
                          keys=res.keys, generation=0)
        live.compact()                      # remaps delta rows into base
        rep = RecallAuditor(live, sink).run_once()
        assert rep["samples"] == 12
        assert all(r == 1.0 for _s, r, _e in rep["results"])


def test_audit_runs_during_concurrent_compaction(tiny_ds):
    """The auditor pins a snapshot per pass, so compactions racing the
    audit never corrupt the replay (recalls stay exact)."""
    with LiveFilteredIndex(tiny_ds) as live:
        rng = np.random.default_rng(3)
        batch = _batch(tiny_ds, Predicate.OR, q=8)
        sink = TelemetrySink(capacity=256, reservoir=128)
        auditor = RecallAuditor(live, sink)
        stop = threading.Event()

        def churn():
            while not stop.is_set():
                pick = rng.integers(0, tiny_ds.n, 64)
                live.upsert(tiny_ds.vectors[pick],
                            tiny_ds.bitmaps[pick])
                live.compact()

        t = threading.Thread(target=churn)
        t.start()
        try:
            for _ in range(5):
                res = live.search(batch, "prefilter")
                sink.record_batch(batch, ("prefilter", "full"),
                                  search_s=1e-3, keys=res.keys)
                rep = auditor.run_once()
                for _s, r, _e in rep["results"]:
                    assert 0.0 <= r <= 1.0
        finally:
            stop.set()
            t.join()
        assert auditor.last_error is None


# ---------------------------------------------------------- online table


def test_online_table_ewma_and_version():
    base = BenchmarkTable.new()
    base.add("d", 0, "m", "p", 0.8, 1000.0)
    ot = OnlineBenchmarkTable(base, alpha=0.5)
    key = ("d", 0, "m", "p")
    v0 = ot.version
    ot.observe("d", 0, "m", "p", recall=1.0)
    assert ot.entries[key]["recall"] == pytest.approx(0.9)
    assert ot.entries[key]["qps"] == 1000.0        # untouched field
    ot.observe("d", 0, "m", "p", qps=2000.0)
    assert ot.entries[key]["qps"] == pytest.approx(1500.0)
    assert ot.version == v0 + 2
    # base table is isolated from online updates
    assert base.entries[key]["recall"] == 0.8
    # unknown cell is seeded directly with the measurement
    ot.observe("d", 1, "m", "p", recall=0.7, qps=10.0)
    assert ot.entries[("d", 1, "m", "p")] == {"recall": 0.7, "qps": 10.0}
    with pytest.raises(ValueError):
        OnlineBenchmarkTable(base, alpha=0.0)


def test_online_table_drift_tracks_audits_not_qps():
    base = BenchmarkTable.new()
    base.add("d", 0, "m", "p", 0.9, 1000.0)
    ot = OnlineBenchmarkTable(base, alpha=1.0)
    ot.observe("d", 0, "m", "p", qps=50.0)     # QPS-only: no drift
    assert ot.max_drift() == 0.0
    ot.observe("d", 0, "m", "p", recall=0.4)
    assert ot.max_drift() == pytest.approx(0.5)
    d = ot.drift()
    assert d[("d", 0, "m", "p")] == pytest.approx(0.5)


def test_online_table_routing_arrays_cache_invalidation():
    base = _two_method_table("d")
    ot = OnlineBenchmarkTable(base, alpha=1.0)
    a1 = ot.routing_arrays("d", 0, ["ivf_gamma", "postfilter"], 0.9)
    a2 = ot.routing_arrays("d", 0, ["ivf_gamma", "postfilter"], 0.9)
    assert a1 is a2                     # version-stable reads hit cache
    ps = candidate_methods()["ivf_gamma"].param_settings()[0].ps_id
    ot.observe("d", 0, "ivf_gamma", ps, recall=0.1)
    a3 = ot.routing_arrays("d", 0, ["ivf_gamma", "postfilter"], 0.9)
    assert a3 is not a1                 # observe invalidates


def test_online_table_snapshot_is_frozen_plain_table():
    ot = OnlineBenchmarkTable(_two_method_table("d"))
    snap = ot.snapshot()
    assert type(snap) is BenchmarkTable
    ps = candidate_methods()["postfilter"].param_settings()[0].ps_id
    ot.observe("d", 0, "postfilter", ps, recall=0.1)
    assert snap.entries[("d", 0, "postfilter", ps)]["recall"] == 0.95


# -------------------------------------------------- constant router helper


def test_constant_router_predicts_exactly_value(tiny_ds):
    table = _two_method_table(tiny_ds.name)
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"], table,
                             value=0.93)
    qs = make_queries(tiny_ds, Predicate.AND, 6, seed=1)
    r_hat = router.predict_recalls(tiny_ds, qs.bitmaps, Predicate.AND)
    assert r_hat.shape == (6, 2)
    assert np.allclose(r_hat, 0.93, atol=1e-6)


# ------------------------------------------------------- e2e adaptation


def test_adaptation_reroutes_off_degraded_method(tiny_ds):
    """The paper's router never re-reads reality; here the audited EWMA
    drops the degraded method's cells below t and Algorithm 2 re-routes
    to the alternative — no retrain involved (threshold set above any
    possible drift)."""
    table = _two_method_table(tiny_ds.name)
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"], table)
    serving = dict(candidate_methods())
    serving["ivf_gamma"] = DegradedMethod(serving["ivf_gamma"], keep=2)
    with FilteredIndex(tiny_ds) as fx:
        sink = TelemetrySink(capacity=512, reservoir=64, seed=5)
        svc = RouterService(fx, router, t=0.9, methods=serving,
                            telemetry=sink)
        adapter = OnlineRouterAdapter(svc, sink, alpha=0.5,
                                      drift_threshold=2.0, seed=0)
        assert svc.router.table is adapter.table
        batch = _batch(tiny_ds, Predicate.AND, q=32)
        before = [d.method for d in svc.route(batch)]
        assert set(before) == {"ivf_gamma"}     # best QPS, passes t
        rerouted = False
        for _ in range(6):
            svc.search(batch)
            rep = adapter.step()
            assert rep["retrained"] is False
            after = [d.method for d in svc.route(batch)]
            if "ivf_gamma" not in after:
                rerouted = True
                break
        assert rerouted, adapter.history
        assert set(after) == {"postfilter"}
        assert adapter.table.max_drift() > 0.3
        # measured QPS folded from the sink's latency aggregates
        audited = adapter.table.audited_cells()
        assert any(k[2] == "ivf_gamma" for k in audited)


def test_adaptation_promote_then_rollback(tiny_ds, tmp_path):
    """Retrain fires on drift; a better candidate promotes (artifact
    saved, store-linked, reference swapped), a worse one rolls back."""
    table = _two_method_table(tiny_ds.name)
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"], table)
    serving = dict(candidate_methods())
    serving["ivf_gamma"] = DegradedMethod(serving["ivf_gamma"], keep=2)

    store = IndexStore.create(str(tmp_path / "store"),
                              LiveFilteredIndex(tiny_ds))
    try:
        sink = TelemetrySink(capacity=512, reservoir=96, seed=2)
        svc = RouterService(store.index, router, t=0.9, methods=serving,
                            telemetry=sink)

        # candidate A routes everything to the healthy alternative (its
        # own table fails ivf_gamma), candidate B back to the degraded
        # method — deterministic stand-ins for a real retrain
        good_table = _two_method_table(tiny_ds.name, degraded_qps=1.0)
        cand_good = constant_router(F.MINIMAL_FEATURES,
                                    ["ivf_gamma", "postfilter"],
                                    good_table)
        cand_bad = constant_router(F.MINIMAL_FEATURES,
                                   ["ivf_gamma", "postfilter"], table)
        plan = [cand_good, cand_bad]
        adapter = OnlineRouterAdapter(
            svc, sink, store=store, alpha=0.5, drift_threshold=0.05,
            min_samples=8, seed=4,
            retrain_fn=lambda ad: plan.pop(0))
        batch = _batch(tiny_ds, Predicate.AND, q=32)

        promoted = None
        for _ in range(8):
            svc.search(batch)
            rep = adapter.step()
            if rep.get("promoted"):
                promoted = rep
                break
        assert promoted is not None, adapter.history
        sh = promoted["shadow"]
        assert sh["candidate_recall"] > sh["incumbent_recall"]
        assert svc.router is cand_good
        assert svc.router.table is adapter.table   # live table re-attached
        assert adapter.promotions == 1

        # versioned artifact exists, validates, links, and round-trips
        path = promoted["artifact"]
        assert os.path.isdir(path)
        assert promoted["versions"] == artifact_versions(path)
        assert store.manifest["router"]["content_sha1"] == \
            promoted["versions"]["content_sha1"]
        loaded = MLRouter.load(path)
        assert loaded.methods == ["ivf_gamma", "postfilter"]
        assert store.load_router().methods == loaded.methods

        # cand_bad routes back to the degraded method -> shadow eval
        # rejects it and the old artifact keeps serving
        rolled = None
        for _ in range(8):
            svc.search(batch)
            rep = adapter.step()
            if rep.get("retrained"):
                rolled = rep
                break
        assert rolled is not None, adapter.history
        assert rolled["promoted"] is False
        assert rolled["action"] == "rollback"
        assert svc.router is cand_good            # unchanged
        assert adapter.promotions == 1
    finally:
        store.close()


def test_default_retrain_learns_from_audit_labels(tiny_ds):
    """The real retrain path: audit-derived per-method recall labels ->
    train_models_from_xy -> shadow eval. The incumbent routes everything
    to a degraded method, so the audit-trained candidate should beat it
    and promote."""
    table = _two_method_table(tiny_ds.name)
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"], table)
    serving = dict(candidate_methods())
    serving["ivf_gamma"] = DegradedMethod(serving["ivf_gamma"], keep=1)
    with FilteredIndex(tiny_ds) as fx:
        sink = TelemetrySink(capacity=512, reservoir=96, seed=6)
        svc = RouterService(fx, router, t=0.9, methods=serving,
                            telemetry=sink)
        adapter = OnlineRouterAdapter(svc, sink, alpha=0.5,
                                      drift_threshold=0.05,
                                      min_samples=8, retrain_epochs=30,
                                      retrain_hidden=(16,), seed=7)
        batch = _batch(tiny_ds, Predicate.AND, q=32)
        report = None
        for _ in range(8):
            svc.search(batch)
            rep = adapter.step()
            if rep.get("retrained"):
                report = rep
                break
        assert report is not None, adapter.history
        assert "shadow" in report
        if report["promoted"]:
            assert svc.router is not router
            assert report["shadow"]["candidate_recall"] > \
                report["shadow"]["incumbent_recall"]
        else:                       # rollback keeps the incumbent
            assert svc.router is router


def test_adapter_background_loop_and_stop(tiny_ds):
    table = _two_method_table(tiny_ds.name)
    router = constant_router(F.MINIMAL_FEATURES,
                             ["ivf_gamma", "postfilter"], table)
    with FilteredIndex(tiny_ds) as fx:
        sink = TelemetrySink(capacity=256, reservoir=32, seed=8)
        svc = RouterService(fx, router, t=0.9, telemetry=sink)
        adapter = OnlineRouterAdapter(svc, sink, drift_threshold=2.0)
        batch = _batch(tiny_ds, Predicate.OR, q=16)
        adapter.start(interval_s=0.05)
        try:
            deadline = 50
            while not adapter.history and deadline:
                svc.search(batch)
                deadline -= 1
        finally:
            adapter.stop()
        assert adapter.last_error is None
        assert adapter.history                  # loop audited something
        assert adapter._thread is None          # stopped cleanly


# ----------------------------------------------------- adaptive audit budget


def test_auditor_budget_curve():
    """Pin the budget curve: clip(ceil(throughput * sample_frac),
    min_budget, max_budget), unlimited when sample_frac is unset."""
    aud = RecallAuditor.__new__(RecallAuditor)   # curve is state-free
    aud.sample_frac, aud.min_budget, aud.max_budget = 0.1, 8, 64
    assert aud.budget_for(0) == 8        # floor on quiet traffic
    assert aud.budget_for(79) == 8       # ceil(7.9) == 8 == floor
    assert aud.budget_for(81) == 9       # linear region: ceil
    assert aud.budget_for(200) == 20
    assert aud.budget_for(640) == 64     # cap reached exactly
    assert aud.budget_for(100000) == 64  # hard cap on floods
    aud.sample_frac = None
    assert aud.budget_for(100000) is None   # default: audit everything


def test_auditor_budget_validation(tiny_ds, tiny_index):
    sink = TelemetrySink(capacity=16, reservoir=16)
    with pytest.raises(ValueError):
        RecallAuditor(tiny_index, sink, sample_frac=0.0)
    with pytest.raises(ValueError):
        RecallAuditor(tiny_index, sink, sample_frac=1.5)
    with pytest.raises(ValueError):
        RecallAuditor(tiny_index, sink, sample_frac=0.5, min_budget=0)
    with pytest.raises(ValueError):
        RecallAuditor(tiny_index, sink, sample_frac=0.5,
                      min_budget=9, max_budget=8)


def test_auditor_budget_scales_with_traffic(tiny_ds, tiny_index):
    """With sample_frac set, a pass audits at most the traffic-derived
    budget (uniform subsample of the drained reservoir); audited recall
    stays exact on the subsample."""
    batch = _batch(tiny_ds, Predicate.AND, q=32)
    exact = tiny_index.search(batch, "prefilter")
    served = exact.keys if exact.keys is not None else exact.ids
    sink = TelemetrySink(capacity=128, reservoir=128)
    sink.record_batch(batch, ("prefilter", "full"), search_s=1e-3,
                      keys=served)
    aud = RecallAuditor(tiny_index, sink, sample_frac=0.25,
                        min_budget=4, max_budget=16)
    rep = aud.run_once()
    assert rep["budget"] == 8            # ceil(32 * 0.25)
    assert rep["samples"] == 8
    assert aud.skipped == 32 - 8
    assert all(r == 1.0 for _s, r, _e in rep["results"])
    # default-configured auditor still audits everything it drains
    sink.record_batch(batch, ("prefilter", "full"), search_s=1e-3,
                      keys=served)
    rep2 = RecallAuditor(tiny_index, sink).run_once()
    assert rep2["budget"] is None and rep2["samples"] == 32
