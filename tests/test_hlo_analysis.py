"""HLO analyzer: loop-aware FLOP counting validated against known programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    s = analyze(_compiled_text(lambda x, y: x @ y, a, b))
    assert s.dot_flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_flops():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def ten_matmuls(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    s = analyze(_compiled_text(ten_matmuls, a))
    assert s.dot_flops == pytest.approx(10 * 2 * 64 ** 3, rel=0.05)


def test_nested_scan_multiplies():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def nested(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None

        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    s = analyze(_compiled_text(nested, a))
    assert s.dot_flops == pytest.approx(12 * 2 * 32 ** 3, rel=0.05)


def test_no_collectives_on_single_device():
    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    s = analyze(_compiled_text(lambda x: x @ x, a))
    assert s.coll_bytes == 0


def test_hbm_bytes_positive_and_reasonable():
    n = 512
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    s = analyze(_compiled_text(lambda x, y: x @ y, a, a))
    # at least the output must be written; inputs counted at parameter use
    assert s.hbm_bytes >= n * n * 4
    assert s.hbm_bytes < 50 * n * n * 4
