"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill↔decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config, SHAPES, shape_supported
from repro.models import common, lm


def _ctx(s=16):
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return lm.ModelCtx(mesh=mesh, qc_train=s, qc_prefill=s, gla_chunk=s)


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.full((b, s), 3, jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32)}
    if cfg.encoder_layers:
        batch["enc_inputs"] = 0.1 * jnp.ones(
            (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    ctx = _ctx()
    params = common.init_params(lm.model_desc(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return lm.forward_train(p, batch, cfg, ctx)

    with ctx.mesh:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) == batch["tokens"].size
    gnorms = [float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)
    assert any(g > 0 for g in gnorms)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    ctx = _ctx()
    params = common.init_params(lm.model_desc(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)
    with ctx.mesh:
        logits, cache = lm.forward_prefill(params, batch, cfg, ctx)
        assert logits.shape == (2, 1, cfg.vocab)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        lg2, cache2 = lm.forward_decode(params, cache, tok,
                                        jnp.int32(15), cfg, ctx)
    assert lg2.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg2)).all()


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "xlstm-125m", "hymba-1.5b",
                                  "deepseek-v2-236b"])
def test_prefill_decode_consistency(arch):
    """Prefill at length t must give the same next-token logits as prefill
    at t-1 followed by one decode step of token t."""
    cfg = get_smoke_config(arch)
    ctx = _ctx()
    params = common.init_params(lm.model_desc(cfg), jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    s = 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab, size=(2, s)), jnp.int32)
    with ctx.mesh:
        full, _ = lm.forward_prefill(params, {"tokens": toks}, cfg, ctx)
        # prefill the first s-1 tokens (padded batch, masked writes), then
        # one decode step of token s-1 must match prefill over all s.
        logits_a, cache = lm.forward_prefill(
            params, {"tokens": toks}, cfg, ctx, prompt_len=s - 1)
        lg_b, _ = lm.forward_decode(params, cache, toks[:, s - 1:s],
                                    jnp.int32(s - 1), cfg, ctx)
    a = np.asarray(full[:, -1], np.float32)
    b = np.asarray(lg_b[:, -1], np.float32)
    # same prediction and close logits (bf16 accumulation differences)
    assert (a.argmax(-1) == b.argmax(-1)).all()
    np.testing.assert_allclose(a, b, rtol=0.15, atol=0.15)


def test_param_counts_match_configs():
    """Full configs instantiate descriptor trees with plausible sizes."""
    expect = {"qwen2-0.5b": (0.3e9, 1.0e9),
              "internlm2-1.8b": (1.5e9, 2.5e9),
              "internlm2-20b": (17e9, 23e9),
              "codeqwen1.5-7b": (6e9, 8.5e9),
              "chameleon-34b": (30e9, 38e9),
              "deepseek-v2-236b": (200e9, 260e9),
              "grok-1-314b": (280e9, 340e9),
              "xlstm-125m": (0.08e9, 0.2e9),
              "hymba-1.5b": (1.2e9, 2.2e9),
              "whisper-medium": (0.6e9, 1.0e9)}
    for arch, (lo, hi) in expect.items():
        n = common.count_params(lm.model_desc(get_config(arch)))
        assert lo <= n <= hi, (arch, n)


def test_shape_support_matrix():
    skips = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            ok, why = shape_supported(cfg, s)
            if not ok:
                skips.append((arch, s.name))
    # exactly the 8 full-attention archs skip long_500k
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    assert ("xlstm-125m", "long_500k") not in skips
    assert ("hymba-1.5b", "long_500k") not in skips


def test_gla_chunk_matches_recurrent():
    """Chunkwise GLA == step-by-step recurrence (the SSD duality)."""
    from repro.models import ssm

    rng = np.random.default_rng(0)
    b, s, h, dk, dv = 2, 32, 3, 8, 5
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)), jnp.float32)
    log_f = jnp.asarray(-np.abs(rng.normal(size=(b, s, h))) * 0.1)
    y_chunk, st_chunk = ssm.gla_chunk_scan(q, k, v, log_f, chunk=8,
                                           normalize=False)
    state = jnp.zeros((b, h, dk, dv))
    ys = []
    for t in range(s):
        y1, state = ssm.gla_decode_step(
            q[:, t:t+1], k[:, t:t+1], v[:, t:t+1], log_f[:, t:t+1],
            state, normalize=False)
        ys.append(y1)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(state),
                               rtol=2e-3, atol=2e-3)
