"""PR-10 resource ledger: lease accounting (acquire/release, context
manager, per-owner rollups), leak detection with caller stacks and
trace ids, pull-time gauge collectors (including failure isolation),
the scoped-ledger test harness, and the real registrations — a
deliberately unreleased `LiveFilteredIndex` snapshot pin must show up
as a leak, and the WAL's fsync backlog must surface as a collector
gauge."""

import os

import numpy as np
import pytest

from repro.ann.ledger import ResourceLedger, get_ledger, scoped, set_ledger
from repro.ann.live import LiveFilteredIndex
from repro.ann.store import WriteAheadLog
from repro.ann.trace import Tracer


# ------------------------------------------------------------- leases


def test_acquire_release_accounting():
    led = ResourceLedger()
    a = led.acquire("pin", "ds0", count=2, bytes=100)
    b = led.acquire("pin", "ds1", count=1, bytes=50)
    c = led.acquire("cache", "ds0", bytes=7)
    acc = led.accounting()
    assert acc["pin"]["ds0"] == {"leases": 1, "count": 2, "bytes": 100}
    assert acc["pin"]["ds1"] == {"leases": 1, "count": 1, "bytes": 50}
    assert acc["cache"]["ds0"]["bytes"] == 7
    b.release()
    assert "ds1" not in led.accounting()["pin"]
    assert led.counters()["pin"] == {"acquired": 2, "released": 1}
    a.release()
    c.release()
    assert led.accounting() == {}
    assert led.counters()["pin"] == {"acquired": 2, "released": 2}


def test_lease_release_is_idempotent_and_scope_bound():
    led = ResourceLedger()
    with led.acquire("pin", "x") as lease:
        assert led.leases("pin")
    assert not led.leases("pin")
    lease.release()                     # double release: no underflow
    assert led.counters()["pin"] == {"acquired": 1, "released": 1}


def test_leak_detection_carries_stack_and_trace_id():
    led = ResourceLedger(leak_age_s=30.0)
    tracer = Tracer(slow_ms=0.0, sample=1.0, seed=2)
    with tracer.trace("request"):
        led.acquire("pin", "ds0", meta={"generation": 3})
    assert led.leaks() == []            # 30s default: nothing old yet
    leaks = led.leaks(max_age_s=0.0)
    assert len(leaks) == 1
    (leak,) = leaks
    assert leak["kind"] == "pin" and leak["meta"] == {"generation": 3}
    # the acquiring call site is in this test file
    assert any("test_ledger.py" in fr for fr in leak["stack"])
    assert leak["trace_id"] and leak["trace_id"].startswith("t")


def test_stack_capture_can_be_disabled():
    led = ResourceLedger(capture_stacks=False)
    led.acquire("pin", "x")
    assert led.leaks(max_age_s=0.0)[0]["stack"] == []


# ---------------------------------------------------------- collectors


def test_collectors_pull_gauges_and_isolate_failures():
    led = ResourceLedger()
    led.register_collector("wal:a", lambda: {"records": 3, "bytes": 99})
    led.register_collector("boom", lambda: 1 / 0)
    g = led.gauges()
    assert g["wal:a"] == {"records": 3.0, "bytes": 99.0}
    assert g["boom"]["error"] == 1.0 and "_error_msg" in g["boom"]
    snap = led.snapshot()
    assert "boom" in snap["collector_errors"]
    assert snap["gauges"]["wal:a"]["records"] == 3.0
    led.deregister_collector("boom")
    assert "boom" not in led.gauges()


def test_snapshot_shape():
    led = ResourceLedger()
    led.acquire("pin", "x", bytes=10)
    snap = led.snapshot(leak_age_s=0.0)
    assert set(snap) >= {"t_wall", "held", "counters", "gauges", "leaks"}
    assert snap["held"]["pin"]["x"]["bytes"] == 10
    assert len(snap["leaks"]) == 1


def test_scoped_ledger_isolates_and_restores():
    outer = get_ledger()
    with scoped() as led:
        assert get_ledger() is led and led is not outer
        led.acquire("pin", "x")
        assert led.leases("pin")
    assert get_ledger() is outer
    assert not outer.leases("pin")
    # explicit install/restore path
    mine = ResourceLedger()
    prev = set_ledger(mine)
    try:
        assert get_ledger() is mine
    finally:
        set_ledger(prev)


# -------------------------------------- real registrations: live + WAL


def test_unreleased_snapshot_pin_is_reported_as_leak(tiny_ds):
    """Acceptance: a snapshot pin that is never released must show up
    in the leak report, attributed to its acquiring call site."""
    with scoped() as led:
        lfx = LiveFilteredIndex(tiny_ds)
        try:
            snap = lfx.snapshot()            # deliberately not released
            held = led.leases("snapshot_pin")
            assert len(held) == 1
            leaks = led.leaks(max_age_s=0.0)
            assert len(leaks) == 1
            (leak,) = leaks
            assert leak["kind"] == "snapshot_pin"
            assert leak["meta"]["generation"] == 0
            assert any("live.py" in fr or "test_ledger.py" in fr
                       for fr in leak["stack"])
            snap.release()                   # the fix the leak points to
            assert led.leaks(max_age_s=0.0) == []
            assert led.counters()["snapshot_pin"]["released"] == 1
        finally:
            lfx.close()


def test_live_index_registers_resource_collector(tiny_ds):
    with scoped() as led:
        lfx = LiveFilteredIndex(tiny_ds)
        try:
            sources = [s for s in led.gauges() if s.startswith("live:")]
            assert len(sources) == 1
            g = led.gauges()[sources[0]]
            assert g["generation"] == 0.0 and g["pinned_readers"] == 0.0
            assert "delta_host_bytes" in g and "retired_generations" in g
            snap = lfx.snapshot()
            assert led.gauges()[sources[0]]["pinned_readers"] == 1.0
            snap.release()
        finally:
            lfx.close()
        assert sources[0] not in led.gauges()   # close deregisters


def test_wal_backlog_surfaces_through_ledger(tmp_path):
    with scoped() as led:
        wal = WriteAheadLog.create(str(tmp_path / "ops.wal"), dim=4,
                                   width=1, generation=0, sync_every=100)
        try:
            keys = np.arange(3, dtype=np.int64)
            vecs = np.zeros((3, 4), np.float32)
            bms = np.zeros((3, 1), np.uint32)
            wal.log_upsert(0, keys, vecs, bms)
            bl = wal.backlog()
            assert bl["records"] == 1 and bl["bytes"] > 0
            (src,) = [s for s in led.gauges() if s.startswith("wal:")]
            assert led.gauges()[src]["records"] == 1.0
            wal.sync()
            assert wal.backlog() == {"records": 0, "bytes": 0}
            assert led.gauges()[src]["records"] == 0.0
        finally:
            wal.close()
        assert not [s for s in led.gauges() if s.startswith("wal:")]
