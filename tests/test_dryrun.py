"""Dry-run integration: one real (arch × shape × mesh) cell lowered AND
compiled on the 512-device production mesh, in a subprocess (the forced
device count must not leak into this pytest process)."""

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, mesh):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", mesh],
        cwd=ROOT, capture_output=True, text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    mesh_name = {"pod": "16x16", "multipod": "2x16x16"}[mesh]
    path = os.path.join(ROOT, "artifacts", "dryrun",
                        f"{arch}_{shape}_{mesh_name}.json")
    with open(path) as f:
        return json.load(f)


def test_dryrun_cell_compiles_single_pod():
    res = _run_cell("qwen2-0.5b", "decode_32k", "pod")
    assert res["status"] == "ok", res
    assert res["collective_bytes"] > 0          # TP logits all-reduce etc.
    assert res["memory"]["argument_size_in_bytes"] > 0


def test_dryrun_cell_compiles_multipod():
    res = _run_cell("qwen2-0.5b", "decode_32k", "multipod")
    assert res["status"] == "ok", res


def test_dryrun_skip_matrix_is_recorded():
    res = _run_cell("internlm2-20b", "long_500k", "pod")
    assert res["status"] == "skipped"
    assert "attention" in res["reason"]
