"""The sharded/async serving layer: `ShardedFilteredIndex` equivalence
with the single-index path, the `merge_topk` kernel, `ShardedRouterService`
routing, and `AsyncBatchQueue` flush behaviour."""

import numpy as np
import pytest

from repro.ann import registry as registry_mod
from repro.ann.distributed import shard_bounds
from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.predicates import Predicate
from repro.ann.service import (AsyncBatchQueue, RouterService,
                               ShardedRouterService)
from repro.ann.sharded import ShardedFilteredIndex

ALL_PREDS = (Predicate.EQUALITY, Predicate.AND, Predicate.OR)


def _assert_same_result(res, want):
    np.testing.assert_array_equal(res.ids, want.ids)
    np.testing.assert_allclose(res.distances, want.distances,
                               rtol=1e-5, atol=1e-5, equal_nan=True)


# ---------------------------------------------------------------------------
# sharded == single-index equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("pred", ALL_PREDS)
def test_sharded_matches_single_index(tiny_ds, tiny_index, tiny_queries,
                                      n_shards, pred):
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    want = tiny_index.search(batch, "prefilter")
    with ShardedFilteredIndex(tiny_ds, n_shards) as sfx:
        _assert_same_result(sfx.search(batch, "prefilter"), want)


@pytest.mark.parametrize("pred", ALL_PREDS)
def test_sharded_ragged_bounds(tiny_ds, tiny_index, tiny_queries, pred):
    """Deliberately unbalanced shards (97/203/150/150) stay exact."""
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    want = tiny_index.search(batch, "prefilter")
    with ShardedFilteredIndex(tiny_ds, bounds=[0, 97, 300, 450, 600]) as sfx:
        assert sfx.stats()["shard_rows"] == [97, 203, 150, 150]
        _assert_same_result(sfx.search(batch, "prefilter"), want)


@pytest.mark.parametrize("pred", ALL_PREDS)
def test_sharded_k_exceeds_per_shard_matches(tiny_ds, tiny_index,
                                             tiny_queries, pred):
    """k larger than any single shard's match count: the merge must pull
    from several shards and pad with −1 only when the *global* match
    count runs out."""
    qs = tiny_queries[pred]
    k = 40
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, k)
    want = tiny_index.search(batch, "prefilter")
    with ShardedFilteredIndex(tiny_ds, 4) as sfx:
        res = sfx.search(batch, "prefilter")
    _assert_same_result(res, want)
    # sanity: EQUALITY queries really do have < k matches per shard
    if pred == Predicate.EQUALITY:
        assert (np.asarray(want.ids) < 0).any()


def test_sharded_serial_matches_parallel(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    with ShardedFilteredIndex(tiny_ds, 3, parallel=False) as ser, \
            ShardedFilteredIndex(tiny_ds, 3, parallel=True) as par:
        _assert_same_result(par.search(batch, "prefilter"),
                            ser.search(batch, "prefilter"))


def test_sharded_lifecycle_and_validation(tiny_ds):
    sfx = ShardedFilteredIndex(tiny_ds, 2)
    assert sfx.n_shards == 2
    assert [s["dataset"] for s in sfx.stats()["shards"]] == \
        ["tiny/shard0", "tiny/shard1"]
    sfx.close()
    assert sfx.closed and all(fx.closed for fx in sfx.shards)
    sfx.close()                                       # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        sfx.search(QueryBatch(tiny_ds.vectors[:2], tiny_ds.bitmaps[:2],
                              Predicate.AND, 5), "prefilter")
    with pytest.raises(ValueError, match="strictly increase"):
        ShardedFilteredIndex(tiny_ds, bounds=[0, 300, 200, 600])
    with pytest.raises(ValueError, match="n_shards"):
        ShardedFilteredIndex(tiny_ds, 0)


def test_row_slice_preserves_row_order(tiny_ds):
    sub = tiny_ds.row_slice(100, 350)
    np.testing.assert_array_equal(sub.vectors, tiny_ds.vectors[100:350])
    np.testing.assert_array_equal(sub.bitmaps, tiny_ds.bitmaps[100:350])
    # group tables describe exactly the slice
    assert sub.group_size.sum() == 250
    for j in range(sub.n_groups):
        s, l = int(sub.group_start[j]), int(sub.group_size[j])
        assert (sub.group_of[s:s + l] == j).all()
        np.testing.assert_array_equal(
            sub.bitmaps[s], sub.group_bitmaps[j])
    with pytest.raises(ValueError, match="out of range"):
        tiny_ds.row_slice(0, tiny_ds.n + 1)


def test_shard_bounds_balanced_and_ragged():
    np.testing.assert_array_equal(shard_bounds(10, 3), [0, 4, 7, 10])
    np.testing.assert_array_equal(shard_bounds(8, 4), [0, 2, 4, 6, 8])
    with pytest.raises(ValueError):
        shard_bounds(3, 5)


# ---------------------------------------------------------------------------
# ShardedRouterService
# ---------------------------------------------------------------------------

def test_sharded_router_service_matches_decisions(tiny_ds, tiny_index,
                                                  tiny_queries, toy_router):
    router = toy_router
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    want = RouterService(tiny_index, router, t=0.9).search(batch)
    with ShardedFilteredIndex(tiny_ds, 3) as sfx:
        svc = ShardedRouterService(sfx, router, t=0.9)
        res = svc.search(batch)
    # routing is computed once on full-dataset features: identical
    assert res.decisions == want.decisions
    # result well-formedness (approximate methods may legitimately
    # return different candidates than the single-index execution)
    assert res.ids.shape == (qs.q, 10)
    for qi in range(qs.q):
        valid = res.distances[qi][res.ids[qi] >= 0]
        assert (np.diff(valid) >= -1e-4).all()
        assert np.isnan(res.distances[qi][res.ids[qi] < 0]).all()
        assert (res.ids[qi] < tiny_ds.n).all()


def test_sharded_router_service_exact_for_prefilter(tiny_ds, tiny_index,
                                                    tiny_queries,
                                                    toy_router):
    """Routed through an exact-only pool, sharded == single end to end."""
    router = toy_router
    qs = tiny_queries[Predicate.OR]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.OR, 10)
    pool = {m: registry_mod.get_method("prefilter") for m in router.methods}
    want = RouterService(tiny_index, router, t=0.9, methods=pool).search(batch)
    with ShardedFilteredIndex(tiny_ds, 2) as sfx:
        res = ShardedRouterService(sfx, router, t=0.9,
                                   methods=pool).search(batch)
    assert res.decisions == want.decisions
    _assert_same_result(res, want)


def test_sharded_router_service_rejects_plain_index(tiny_index, toy_router):
    with pytest.raises(TypeError, match="ShardedFilteredIndex"):
        ShardedRouterService(tiny_index, toy_router)


# ---------------------------------------------------------------------------
# AsyncBatchQueue
# ---------------------------------------------------------------------------

def test_queue_flush_on_max_batch(tiny_ds, tiny_index, tiny_queries):
    """With an effectively infinite wait, only the max_batch knob can
    trigger the flush."""
    qs = tiny_queries[Predicate.AND]
    want = tiny_index.search(
        QueryBatch(qs.vectors[:8], qs.bitmaps[:8], Predicate.AND, 10),
        "prefilter")
    with AsyncBatchQueue(tiny_index, max_batch=8, max_wait_ms=60_000,
                         method="prefilter") as q:
        futs = [q.submit(qs.vectors[i], qs.bitmaps[i], Predicate.AND)
                for i in range(8)]
        results = [f.result(timeout=60) for f in futs]
        stats = q.stats()
    assert stats["flush_reasons"] == {"max_batch": 1}
    assert stats["queries"] == 8 and stats["max_batch_seen"] == 8
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r.ids, want.ids[i])
        np.testing.assert_allclose(r.distances, want.distances[i],
                                   equal_nan=True)
        assert r.decision is None                  # direct method, no router


def test_queue_flush_on_max_wait(tiny_ds, tiny_index, tiny_queries):
    """Fewer requests than max_batch: the age knob must flush them."""
    qs = tiny_queries[Predicate.OR]
    with AsyncBatchQueue(tiny_index, max_batch=64, max_wait_ms=40,
                         method="prefilter") as q:
        futs = [q.submit(qs.vectors[i], qs.bitmaps[i], Predicate.OR)
                for i in range(3)]
        results = [f.result(timeout=60) for f in futs]
        stats = q.stats()
    assert all(r.ids.shape == (10,) for r in results)
    assert stats["flush_reasons"].get("max_wait", 0) >= 1
    assert "max_batch" not in stats["flush_reasons"]
    assert stats["queries"] == 3


def test_queue_groups_mixed_predicates(tiny_ds, tiny_index, tiny_queries):
    """One flush serves mixed-predicate traffic correctly (grouped into
    per-(pred, k) sub-batches)."""
    subs = []
    for pred in ALL_PREDS:
        qs = tiny_queries[pred]
        subs += [(pred, qs.vectors[i], qs.bitmaps[i]) for i in range(4)]
    with AsyncBatchQueue(tiny_index, max_batch=len(subs),
                         max_wait_ms=60_000, method="prefilter") as q:
        futs = [q.submit(v, b, pred, k=7) for pred, v, b in subs]
        results = [f.result(timeout=60) for f in futs]
    for (pred, v, b), r in zip(subs, results):
        want = tiny_index.search(
            QueryBatch(v[None], b[None], pred, 7), "prefilter")
        np.testing.assert_array_equal(r.ids, want.ids[0])


def test_queue_routed_service_carries_decisions(tiny_ds, tiny_index,
                                                tiny_queries, toy_router):
    svc = RouterService(tiny_index, toy_router, t=0.9)
    qs = tiny_queries[Predicate.AND]
    want = svc.search(QueryBatch(qs.vectors[:4], qs.bitmaps[:4],
                                 Predicate.AND, 10))
    with AsyncBatchQueue(svc, max_batch=4, max_wait_ms=60_000) as q:
        futs = [q.submit(qs.vectors[i], qs.bitmaps[i], Predicate.AND)
                for i in range(4)]
        results = [f.result(timeout=60) for f in futs]
    assert [r.decision for r in results] == want.decisions
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r.ids, want.ids[i])


def test_queue_flush_waits_for_inflight(tiny_ds, tiny_index, tiny_queries):
    """flush() must cover the batch the worker already dequeued, not just
    what is still pending."""
    qs = tiny_queries[Predicate.AND]
    with AsyncBatchQueue(tiny_index, max_batch=1, max_wait_ms=0,
                         method="prefilter") as q:
        futs = [q.submit(qs.vectors[i], qs.bitmaps[i], Predicate.AND)
                for i in range(3)]
        q.flush(timeout=120)
        assert all(f.done() for f in futs)


def test_queue_close_drains_and_rejects(tiny_ds, tiny_index, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    q = AsyncBatchQueue(tiny_index, max_batch=64, max_wait_ms=60_000,
                        method="prefilter")
    fut = q.submit(qs.vectors[0], qs.bitmaps[0], Predicate.AND)
    q.close()                                  # drains the pending query
    assert fut.result(timeout=60).ids.shape == (10,)
    with pytest.raises(RuntimeError, match="closed"):
        q.submit(qs.vectors[0], qs.bitmaps[0], Predicate.AND)
    q.close()                                  # idempotent


def test_queue_validates(tiny_index, tiny_ds):
    with pytest.raises(ValueError, match="max_batch"):
        AsyncBatchQueue(tiny_index, max_batch=0, method="prefilter")
    with pytest.raises(ValueError, match="max_wait_ms"):
        AsyncBatchQueue(tiny_index, max_wait_ms=-1, method="prefilter")
    with AsyncBatchQueue(tiny_index, method="prefilter") as q:
        with pytest.raises(ValueError, match="one query"):
            q.submit(tiny_ds.vectors[:2], tiny_ds.bitmaps[:2],
                     Predicate.AND)
        # dim mismatches are rejected per caller at submit() — inside the
        # worker they would fail the whole co-batched group
        with pytest.raises(ValueError, match="vector dim"):
            q.submit(tiny_ds.vectors[0, :-2], tiny_ds.bitmaps[0],
                     Predicate.AND)
        with pytest.raises(ValueError, match="bitmap width"):
            q.submit(tiny_ds.vectors[0],
                     np.concatenate([tiny_ds.bitmaps[0]] * 2),
                     Predicate.AND)


def test_queue_propagates_backend_errors(tiny_index, tiny_ds):
    """A failing batch rejects exactly its own futures."""
    with AsyncBatchQueue(tiny_index, max_batch=2, max_wait_ms=60_000,
                         method="no_such_method") as q:
        futs = [q.submit(tiny_ds.vectors[i], tiny_ds.bitmaps[i],
                         Predicate.AND) for i in range(2)]
        for f in futs:
            with pytest.raises(KeyError, match="unknown method"):
                f.result(timeout=60)
