"""Bitmap packing + predicate semantics (seeded randomized sweeps — the
deterministic stand-in for the original hypothesis property tests, which
needed a package the image doesn't ship)."""

import numpy as np
import pytest

from repro.ann import labels as lb
from repro.ann.predicates import Predicate, eval_predicate, eval_predicate_np


def _rand_label_set(rng, max_size=8, universe=100):
    k = int(rng.integers(0, max_size + 1))
    return set(int(l) for l in rng.choice(universe, size=k, replace=False))


@pytest.mark.parametrize("seed", range(30))
def test_pack_unpack_roundtrip(seed):
    ls = _rand_label_set(np.random.default_rng(seed))
    bm = lb.pack_one(ls, 100)
    assert lb.unpack_one(bm) == frozenset(ls)


@pytest.mark.parametrize("seed", range(30))
def test_predicate_semantics(seed):
    rng = np.random.default_rng(1000 + seed)
    li, lq = _rand_label_set(rng), _rand_label_set(rng)
    if seed % 5 == 0:       # exercise equal and empty sets too
        lq = set(li)
    if seed % 7 == 0:
        lq = set()
    bi = lb.pack_one(li, 100)[None, :]
    bq = lb.pack_one(lq, 100)[None, :]
    eq = bool(eval_predicate_np(bi, bq, Predicate.EQUALITY)[0])
    an = bool(eval_predicate_np(bi, bq, Predicate.AND)[0])
    orr = bool(eval_predicate_np(bi, bq, Predicate.OR)[0])
    assert eq == (set(li) == set(lq))
    assert an == set(lq).issubset(set(li))
    assert orr == bool(set(lq) & set(li))
    # equality implies containment; containment of nonempty implies overlap
    if eq:
        assert an
    if an and lq:
        assert orr


@pytest.mark.parametrize("seed", range(15))
def test_jnp_matches_np(seed):
    import jax.numpy as jnp

    rng = np.random.default_rng(2000 + seed)
    sets = [_rand_label_set(rng) for _ in range(int(rng.integers(1, 11)))]
    lq = _rand_label_set(rng)
    base = lb.pack_label_sets(sets, 100)
    q = lb.pack_one(lq, 100)
    for pred in Predicate:
        a = eval_predicate_np(base, q[None, :], pred)
        b = np.asarray(eval_predicate(jnp.asarray(base), jnp.asarray(q), pred))
        assert (a == b).all()


def test_popcount():
    import jax.numpy as jnp

    bm = lb.pack_label_sets([{1, 2, 3}, set(), {0, 99}], 100)
    counts = np.asarray(lb.popcount(jnp.asarray(bm)))
    assert counts.tolist() == [3, 0, 2]


def test_pack_out_of_range():
    with pytest.raises(ValueError):
        lb.pack_one([100], 100)


def test_group_structure(tiny_ds):
    # group-sorted layout: every vector's bitmap equals its group's bitmap
    for g in range(tiny_ds.n_groups):
        s, l = int(tiny_ds.group_start[g]), int(tiny_ds.group_size[g])
        assert (tiny_ds.bitmaps[s:s + l] == tiny_ds.group_bitmaps[g]).all()
    assert int(tiny_ds.group_size.sum()) == tiny_ds.n


def test_selectivity_matches_bruteforce(tiny_ds, tiny_queries):
    from repro.ann.predicates import Predicate

    for pred, qs in tiny_queries.items():
        for i in range(5):
            sel = tiny_ds.selectivity(qs.bitmaps[i], pred)
            mask = tiny_ds.matching_mask(qs.bitmaps[i], pred)
            assert sel == pytest.approx(mask.mean())
