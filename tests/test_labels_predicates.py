"""Bitmap packing + predicate semantics (hypothesis property tests)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ann import labels as lb
from repro.ann.predicates import Predicate, eval_predicate, eval_predicate_np

label_sets = st.sets(st.integers(0, 99), max_size=8)


@settings(max_examples=30, deadline=None)
@given(label_sets)
def test_pack_unpack_roundtrip(ls):
    bm = lb.pack_one(ls, 100)
    assert lb.unpack_one(bm) == frozenset(ls)


@settings(max_examples=30, deadline=None)
@given(label_sets, label_sets)
def test_predicate_semantics(li, lq):
    bi = lb.pack_one(li, 100)[None, :]
    bq = lb.pack_one(lq, 100)[None, :]
    eq = bool(eval_predicate_np(bi, bq, Predicate.EQUALITY)[0])
    an = bool(eval_predicate_np(bi, bq, Predicate.AND)[0])
    orr = bool(eval_predicate_np(bi, bq, Predicate.OR)[0])
    assert eq == (set(li) == set(lq))
    assert an == set(lq).issubset(set(li))
    assert orr == bool(set(lq) & set(li))
    # equality implies containment; containment of nonempty implies overlap
    if eq:
        assert an
    if an and lq:
        assert orr


@settings(max_examples=15, deadline=None)
@given(st.lists(label_sets, min_size=1, max_size=10), label_sets)
def test_jnp_matches_np(sets, lq):
    import jax.numpy as jnp

    base = lb.pack_label_sets(sets, 100)
    q = lb.pack_one(lq, 100)
    for pred in Predicate:
        a = eval_predicate_np(base, q[None, :], pred)
        b = np.asarray(eval_predicate(jnp.asarray(base), jnp.asarray(q), pred))
        assert (a == b).all()


def test_popcount():
    import jax.numpy as jnp

    bm = lb.pack_label_sets([{1, 2, 3}, set(), {0, 99}], 100)
    counts = np.asarray(lb.popcount(jnp.asarray(bm)))
    assert counts.tolist() == [3, 0, 2]


def test_pack_out_of_range():
    with pytest.raises(ValueError):
        lb.pack_one([100], 100)


def test_group_structure(tiny_ds):
    # group-sorted layout: every vector's bitmap equals its group's bitmap
    for g in range(tiny_ds.n_groups):
        s, l = int(tiny_ds.group_start[g]), int(tiny_ds.group_size[g])
        assert (tiny_ds.bitmaps[s:s + l] == tiny_ds.group_bitmaps[g]).all()
    assert int(tiny_ds.group_size.sum()) == tiny_ds.n


def test_selectivity_matches_bruteforce(tiny_ds, tiny_queries):
    from repro.ann.predicates import Predicate

    for pred, qs in tiny_queries.items():
        for i in range(5):
            sel = tiny_ds.selectivity(qs.bitmaps[i], pred)
            mask = tiny_ds.matching_mask(qs.bitmaps[i], pred)
            assert sel == pytest.approx(mask.mean())
