"""The live index subsystem: streaming upserts/deletes, tombstone-aware
search, snapshot epochs, background compaction, sharded live indexes,
routing-feature freshness, and the double-buffered async queue."""

import threading
import time

import numpy as np
import pytest

from repro.ann.index import FilteredIndex, QueryBatch
from repro.ann.live import DeltaSegment, LiveFilteredIndex, ShardedLiveIndex
from repro.ann.predicates import Predicate, eval_predicate_np
from repro.ann.service import AsyncBatchQueue, RouterService, \
    ShardedRouterService
from repro.core import features as F

ALL_PREDS = (Predicate.EQUALITY, Predicate.AND, Predicate.OR)


def _assert_same_result(res, want):
    np.testing.assert_array_equal(res.ids, want.ids)
    np.testing.assert_allclose(res.distances, want.distances,
                               rtol=1e-5, atol=1e-5, equal_nan=True)


def _live_oracle(vectors, bitmaps, tomb, qv, qb, pred, k):
    """Exact masked top-k ids over an explicit (rows, tombstones) state."""
    norms = np.sum(vectors.astype(np.float64) ** 2, axis=1)
    out = np.full((qv.shape[0], k), -1, np.int32)
    for qi in range(qv.shape[0]):
        ok = eval_predicate_np(bitmaps, qb[qi][None], pred) & ~tomb
        idx = np.nonzero(ok)[0]
        if not idx.size:
            continue
        d = norms[idx] - 2.0 * vectors[idx] @ qv[qi].astype(np.float64)
        o = np.argsort(d, kind="stable")[:k]
        out[qi, : o.size] = idx[o]
    return out


# ---------------------------------------------------------------------------
# sealed/live equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pred", ALL_PREDS)
def test_live_equals_sealed_before_writes(tiny_ds, tiny_index, tiny_queries,
                                          pred):
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    want = tiny_index.search(batch, "prefilter")
    with LiveFilteredIndex(tiny_ds) as live:
        res = live.search(batch, "prefilter")
    _assert_same_result(res, want)
    assert {"base_s", "delta_s", "merge_s"} <= res.timings.keys()


@pytest.mark.parametrize("pred", ALL_PREDS)
def test_upsert_all_matches_sealed_pre_compact(tiny_ds, tiny_index,
                                               tiny_queries, pred):
    """Everything in the delta (no base at all): the brute-force delta
    path must already be exact."""
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    want = tiny_index.search(batch, "prefilter")
    with LiveFilteredIndex.empty("tiny", tiny_ds.dim,
                                 tiny_ds.universe) as live:
        for s in range(0, tiny_ds.n, 150):
            live.upsert(tiny_ds.vectors[s: s + 150],
                        tiny_ds.bitmaps[s: s + 150])
        _assert_same_result(live.search(batch, "prefilter"), want)


@pytest.mark.parametrize("pred", ALL_PREDS)
@pytest.mark.parametrize("q_take,k", [(25, 10), (1, 10), (7, 40)])
def test_upsert_all_then_compact_matches_fresh(tiny_ds, tiny_index,
                                               tiny_queries, pred,
                                               q_take, k):
    """The acceptance bar: empty live + upsert-everything + compact is
    bit-identical (ids AND distances) to a FilteredIndex built directly,
    across predicates, ragged Q, and k > matches."""
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors[:q_take], qs.bitmaps[:q_take], pred, k)
    want = tiny_index.search(batch, "prefilter")
    with LiveFilteredIndex.empty("tiny", tiny_ds.dim,
                                 tiny_ds.universe) as live:
        live.upsert(tiny_ds.vectors, tiny_ds.bitmaps)
        gen = live.compact()
        assert gen == 1 and live.stats()["delta_rows"] == 0
        # the rebuilt base is bit-identical to the original dataset
        np.testing.assert_array_equal(live.ds.vectors, tiny_ds.vectors)
        np.testing.assert_array_equal(live.ds.bitmaps, tiny_ds.bitmaps)
        _assert_same_result(live.search(batch, "prefilter"), want)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("pred", ALL_PREDS)
def test_sharded_upsert_all_then_compact_matches_fresh(tiny_ds, tiny_index,
                                                       tiny_queries,
                                                       n_shards, pred):
    qs = tiny_queries[pred]
    batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
    want = tiny_index.search(batch, "prefilter")
    with ShardedLiveIndex(None, n_shards, name="tiny", dim=tiny_ds.dim,
                          universe=tiny_ds.universe) as live:
        live.upsert(tiny_ds.vectors, tiny_ds.bitmaps)
        _assert_same_result(live.search(batch, "prefilter"), want)
        live.compact()
        assert live.generation == 1
        _assert_same_result(live.search(batch, "prefilter"), want)


def test_mixed_base_plus_delta_is_exact(tiny_ds, tiny_queries, rng):
    """Sealed base + live delta + tombstones in both segments must match
    the brute-force oracle over the merged live state, and never surface
    a deleted id."""
    extra_v = tiny_ds.vectors[:80] + np.float32(0.01)
    extra_b = tiny_ds.bitmaps[:80]
    with LiveFilteredIndex(tiny_ds) as live:
        new_ids = live.upsert(extra_v, extra_b)
        dele = np.concatenate([np.arange(10, 40), new_ids[5:20]])
        assert live.delete(dele) == 45
        assert live.delete(dele[:3]) == 0            # idempotent
        all_v = np.concatenate([tiny_ds.vectors, extra_v])
        all_b = np.concatenate([tiny_ds.bitmaps, extra_b])
        tomb = np.zeros(all_v.shape[0], bool)
        tomb[dele] = True
        for pred in ALL_PREDS:
            qs = tiny_queries[pred]
            res = live.search(
                QueryBatch(qs.vectors, qs.bitmaps, pred, 10), "prefilter")
            want = _live_oracle(all_v, all_b, tomb, qs.vectors,
                                qs.bitmaps, pred, 10)
            np.testing.assert_array_equal(res.ids, want)
            assert not np.isin(res.ids[res.ids >= 0], dele).any()


def test_all_tombstoned_yields_padded_results(tiny_ds, tiny_queries):
    """Deleting every row (base and delta) must produce −1 ids with NaN
    distances everywhere — the all-tombstoned edge case."""
    qs = tiny_queries[Predicate.OR]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.OR, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        live.upsert(tiny_ds.vectors[:30], tiny_ds.bitmaps[:30])
        live.delete(np.arange(live.n_total))
        assert live.n_live == 0
        res = live.search(batch, "prefilter")
        assert (res.ids == -1).all()
        assert np.isnan(res.distances).all()


def test_empty_live_index_searches(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 5)
    with LiveFilteredIndex.empty("void", tiny_ds.dim,
                                 tiny_ds.universe) as live:
        res = live.search(batch, "prefilter")
        assert (res.ids == -1).all() and np.isnan(res.distances).all()


def test_compact_preserves_results_and_remaps_ids(tiny_ds, tiny_queries):
    """Pre/post-compact results agree on distances and on the vectors
    behind the ids (the ids themselves are remapped)."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        ids = live.upsert(tiny_ds.vectors[:60] + np.float32(0.02),
                          tiny_ds.bitmaps[:60])
        live.delete(np.concatenate([np.arange(0, 20), ids[:10]]))
        before = live.search(batch, "prefilter")
        vec_before = live.fetch(before.ids.ravel())
        gen = live.compact()
        assert gen == 1
        after = live.search(batch, "prefilter")
        np.testing.assert_allclose(after.distances, before.distances,
                                   rtol=1e-5, atol=1e-5, equal_nan=True)
        vec_after = live.fetch(after.ids.ravel())
        np.testing.assert_allclose(vec_after, vec_before,
                                   rtol=0, atol=0, equal_nan=True)


# ---------------------------------------------------------------------------
# snapshots / epochs
# ---------------------------------------------------------------------------

def test_snapshot_isolates_from_writes(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        want = live.search(batch, "prefilter")
        with live.snapshot() as snap:
            live.upsert(tiny_ds.vectors[:40] + np.float32(0.5),
                        tiny_ds.bitmaps[:40])
            live.delete(np.arange(0, 50))
            # the pinned epoch still sees the pre-write state
            _assert_same_result(
                live.search(batch, "prefilter", snapshot=snap), want)
            # a fresh search sees the writes
            fresh = live.search(batch, "prefilter")
            assert not np.array_equal(fresh.ids, want.ids)


def test_snapshot_survives_compaction(tiny_ds, tiny_queries):
    """An old-epoch reader drains cleanly: its base stays open across a
    compact() and is freed on release."""
    qs = tiny_queries[Predicate.OR]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.OR, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        live.upsert(tiny_ds.vectors[:20] + np.float32(0.1),
                    tiny_ds.bitmaps[:20])
        snap = live.snapshot()
        want = live.search(batch, "prefilter", snapshot=snap)
        live.compact()
        assert live.generation == 1
        assert live.stats()["retired_generations"] == [0]
        # the old epoch still reads its own (pre-compact) id space
        _assert_same_result(
            live.search(batch, "prefilter", snapshot=snap), want)
        snap.release()
        assert live.stats()["retired_generations"] == []
        with pytest.raises(RuntimeError, match="released"):
            live.search(batch, "prefilter", snapshot=snap)


def test_snapshot_of_empty_base_generation_survives_compact(tiny_ds):
    """A pinned generation-0 snapshot must stay resolvable across a
    compact even when generation 0 had no base at all."""
    with LiveFilteredIndex.empty("tiny", tiny_ds.dim,
                                 tiny_ds.universe) as live:
        ids = live.upsert(tiny_ds.vectors[:50], tiny_ds.bitmaps[:50])
        with live.snapshot() as snap:
            live.compact()
            assert live.generation == 1
            vecs = live.fetch(ids, snapshot=snap)   # old-epoch delta ids
            np.testing.assert_array_equal(vecs, tiny_ds.vectors[:50])


def test_last_remap_translates_ids(tiny_ds):
    with LiveFilteredIndex(tiny_ds) as live:
        ids = live.upsert(tiny_ds.vectors[:20] + np.float32(0.01),
                          tiny_ds.bitmaps[:20])
        live.delete([0, 1, int(ids[0])])
        assert live.last_remap() is None
        live.compact()
        remap = live.last_remap()
        assert remap is not None and remap.shape == (tiny_ds.n + 20,)
        assert remap[0] == remap[1] == remap[int(ids[0])] == -1
        survivors = remap[remap >= 0]
        assert survivors.size == tiny_ds.n + 20 - 3
        # a surviving row's new id resolves to the same vector
        np.testing.assert_array_equal(live.ds.vectors[remap[5]],
                                      tiny_ds.vectors[5])


def test_search_racing_delete_and_compact_never_surfaces(tiny_ds,
                                                         tiny_queries):
    """The acceptance race: a writer deletes rows and compacts while a
    reader searches; a result observed under a snapshot must never
    contain a row whose delete completed before the snapshot."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    live = LiveFilteredIndex(tiny_ds)
    try:
        new_ids = live.upsert(tiny_ds.vectors + np.float32(0.01),
                              tiny_ds.bitmaps)
        deleted_vecs: list[np.ndarray] = []
        stop = threading.Event()

        def writer():
            rng = np.random.default_rng(11)
            order = rng.permutation(tiny_ds.n)
            i = 0
            while not stop.is_set() and i < 220:
                gid = int(new_ids[order[i]])
                vec = (tiny_ds.vectors[order[i]] + np.float32(0.01)).copy()
                g0 = live.generation
                try:
                    live.delete([gid])
                except IndexError:
                    break     # ids are per-generation: stale after a swap
                if live.generation == g0:   # certainly applied this epoch
                    deleted_vecs.append(vec)   # happens-after the delete
                i += 1
                if i == 120:
                    live.compact_async()       # race a compaction too
            stop.set()

        th = threading.Thread(target=writer)
        th.start()
        checked = 0
        while not stop.is_set() or checked == 0:
            known = list(deleted_vecs)          # before the snapshot
            with live.snapshot() as snap:
                res = live.search(batch, "prefilter", snapshot=snap)
                got = live.fetch(res.ids[res.ids >= 0].ravel(),
                                 snapshot=snap)
            if known:
                dead = np.stack(known)
                for v in got:
                    assert not (np.abs(dead - v).max(1) < 1e-12).any(), \
                        "a deleted row surfaced in a post-delete snapshot"
                checked += 1
        th.join(timeout=60)
        assert checked >= 1
    finally:
        live.close()


# ---------------------------------------------------------------------------
# sharded live: round-robin, global ids, delete routing
# ---------------------------------------------------------------------------

def test_sharded_live_matches_single_live(tiny_ds, tiny_queries):
    extra_v = tiny_ds.vectors[:90] + np.float32(0.03)
    extra_b = tiny_ds.bitmaps[:90]
    with LiveFilteredIndex(tiny_ds) as single, \
            ShardedLiveIndex(tiny_ds, 3) as sharded:
        ids_s = single.upsert(extra_v, extra_b)
        ids_h = sharded.upsert(extra_v, extra_b)
        np.testing.assert_array_equal(ids_s, ids_h)   # same global id space
        dele = np.concatenate([np.arange(25, 55), ids_s[10:30]])
        assert single.delete(dele) == sharded.delete(dele) == 50
        for pred in ALL_PREDS:
            qs = tiny_queries[pred]
            batch = QueryBatch(qs.vectors, qs.bitmaps, pred, 10)
            _assert_same_result(sharded.search(batch, "prefilter"),
                                single.search(batch, "prefilter"))


def test_sharded_live_compact_with_deletes(tiny_ds, tiny_queries):
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    with ShardedLiveIndex(tiny_ds, 2) as live:
        ids = live.upsert(tiny_ds.vectors[:50] + np.float32(0.02),
                          tiny_ds.bitmaps[:50])
        live.delete(np.concatenate([np.arange(5, 30), ids[:10]]))
        before = live.search(batch, "prefilter")
        live.compact()
        assert live.generation == 1
        st = live.stats()
        assert st["base_n"] == tiny_ds.n + 50 - 35
        assert st["delta_rows"] == 0
        after = live.search(batch, "prefilter")
        np.testing.assert_allclose(after.distances, before.distances,
                                   rtol=1e-5, atol=1e-5, equal_nan=True)


def test_sharded_live_writes_during_compaction_carry_over(tiny_ds,
                                                          tiny_queries):
    """Rows upserted while a compaction is rebuilding must survive the
    swap (as the new delta)."""
    qs = tiny_queries[Predicate.OR]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.OR, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        fut = live.compact_async()
        live.upsert(tiny_ds.vectors[:15] + np.float32(0.25),
                    tiny_ds.bitmaps[:15])
        fut.result(timeout=120)
        st = live.stats()
        assert st["generation"] == 1
        assert st["n_live"] == tiny_ds.n + 15
        all_v = np.concatenate([tiny_ds.vectors,
                                tiny_ds.vectors[:15] + np.float32(0.25)])
        all_b = np.concatenate([tiny_ds.bitmaps, tiny_ds.bitmaps[:15]])
        res = live.search(batch, "prefilter")
        want = _live_oracle(all_v, all_b, np.zeros(all_v.shape[0], bool),
                            qs.vectors, qs.bitmaps, Predicate.OR, 10)
        got_vecs = live.fetch(res.ids.ravel())
        want_vecs = np.where((want >= 0).ravel()[:, None],
                             all_v[np.clip(want.ravel(), 0, None)], np.nan)
        np.testing.assert_allclose(got_vecs, want_vecs, equal_nan=True,
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# routing-feature freshness
# ---------------------------------------------------------------------------

def test_live_selectivity_matches_oracle(tiny_ds, tiny_queries):
    extra_v = tiny_ds.vectors[:70] + np.float32(0.04)
    extra_b = tiny_ds.bitmaps[200:270]
    with LiveFilteredIndex(tiny_ds) as live:
        ids = live.upsert(extra_v, extra_b)
        live.delete(np.concatenate([np.arange(40, 90), ids[:15]]))
        all_b = np.concatenate([tiny_ds.bitmaps, extra_b])
        tomb = np.zeros(all_b.shape[0], bool)
        tomb[40:90] = True
        tomb[ids[:15]] = True
        n_live = int((~tomb).sum())
        for pred in ALL_PREDS:
            qb = tiny_queries[pred].bitmaps
            got = F.batch_selectivity(tiny_ds, qb, pred, fx=live)
            want = np.array([
                float((eval_predicate_np(all_b, qb[i][None], pred)
                       & ~tomb).sum()) / n_live
                for i in range(qb.shape[0])])
            np.testing.assert_allclose(got, want, atol=1e-12)
        stats = live.live_stats()
        assert stats.n_live == n_live
        # label carrier fractions over the live rows, exactly
        shifts = np.arange(32, dtype=np.uint32)
        bits = ((all_b[~tomb][:, :, None] >> shifts) & np.uint32(1))
        bits = bits.reshape(n_live, -1)[:, : tiny_ds.universe]
        np.testing.assert_allclose(stats.label_freq,
                                   bits.sum(0) / n_live, atol=1e-12)


def test_feature_matrix_uses_live_size(tiny_ds, tiny_queries):
    qb = tiny_queries[Predicate.AND].bitmaps
    with LiveFilteredIndex(tiny_ds) as live:
        live.upsert(tiny_ds.vectors[:25], tiny_ds.bitmaps[:25])
        live.delete([0, 1, 2])
        x = F.feature_matrix(tiny_ds, qb, Predicate.AND,
                             ["size", "selectivity"], fx=live)
        assert (x[:, 0] == tiny_ds.n + 25 - 3).all()


def test_router_service_serves_live_index(tiny_ds, tiny_index,
                                          tiny_queries, toy_router):
    """RouterService over a live handle: same decisions as over the
    sealed handle while untouched; stage timings appear after writes."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    want = RouterService(tiny_index, toy_router, t=0.9).search(batch)
    with LiveFilteredIndex(tiny_ds) as live:
        svc = RouterService(live, toy_router, t=0.9)
        res = svc.search(batch)
        assert res.decisions == want.decisions
        assert {"route_s", "search_s", "base_s", "delta_s",
                "merge_s"} <= res.timings.keys()
        live.upsert(tiny_ds.vectors[:30] + np.float32(0.01),
                    tiny_ds.bitmaps[:30])
        res2 = svc.search(batch)
        assert res2.ids.shape == (qs.q, 10)
        assert res2.timings["delta_s"] > 0


def test_router_service_search_chunked_over_live(tiny_ds, tiny_queries,
                                                 toy_router):
    """search_chunked must fold the live stage-timing keys it has not
    pre-seeded (regression: KeyError 'base_s')."""
    qs = tiny_queries[Predicate.AND]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10)
    with LiveFilteredIndex(tiny_ds) as live:
        live.upsert(tiny_ds.vectors[:20] + np.float32(0.01),
                    tiny_ds.bitmaps[:20])
        svc = RouterService(live, toy_router, t=0.9)
        want = svc.search(batch)
        res = svc.search_chunked(batch, chunk=8)
        np.testing.assert_array_equal(res.ids, want.ids)
        assert res.decisions == want.decisions
        assert res.timings["delta_s"] > 0


def test_sharded_router_service_accepts_live(tiny_ds, tiny_queries,
                                             toy_router):
    qs = tiny_queries[Predicate.OR]
    batch = QueryBatch(qs.vectors, qs.bitmaps, Predicate.OR, 10)
    with ShardedLiveIndex(tiny_ds, 2) as live:
        svc = ShardedRouterService(live, toy_router, t=0.9)
        res = svc.search(batch)
        assert res.ids.shape == (qs.q, 10)
        assert len(res.decisions) == qs.q


# ---------------------------------------------------------------------------
# delta segment mechanics + validation
# ---------------------------------------------------------------------------

def test_delta_segment_growth_and_mirror(tiny_ds):
    import contextlib

    seg = DeltaSegment(tiny_ds.dim, tiny_ds.bitmaps.shape[1], chunk=16)
    for s in range(0, 40, 8):
        seg.append(tiny_ds.vectors[s: s + 8], tiny_ds.bitmaps[s: s + 8])
    assert seg.rows == 40
    vec, norms, bm = seg.device_view(40, contextlib.nullcontext)
    # 32 mirrored rows (two sealed chunks) + one padded tail chunk
    assert vec.shape[0] == 48 and seg.device_rows() == 32
    np.testing.assert_allclose(np.asarray(vec)[:40], tiny_ds.vectors[:40])
    from repro.kernels import masked_topk as mk
    assert (np.asarray(norms)[40:] >= mk.PAD_SCORE).all()
    hv, hb, hn = seg.host_view(40)
    np.testing.assert_array_equal(hb, tiny_ds.bitmaps[:40])
    # the mirror never re-uploads sealed chunks
    seg.append(tiny_ds.vectors[40:41], tiny_ds.bitmaps[40:41])
    vec2, _, _ = seg.device_view(41, contextlib.nullcontext)
    assert seg.device_rows() == 32 and vec2.shape[0] == 48


def test_live_validation_and_lifecycle(tiny_ds):
    live = LiveFilteredIndex(tiny_ds)
    with pytest.raises(ValueError, match="vectors"):
        live.upsert(tiny_ds.vectors[:2, :-3], tiny_ds.bitmaps[:2])
    with pytest.raises(ValueError, match="bitmaps"):
        live.upsert(tiny_ds.vectors[:2],
                    np.concatenate([tiny_ds.bitmaps[:2]] * 2, axis=1))
    with pytest.raises(IndexError, match="delete ids"):
        live.delete([tiny_ds.n + 5])
    live.close()
    live.close()                                  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        live.upsert(tiny_ds.vectors[:1], tiny_ds.bitmaps[:1])
    with pytest.raises(RuntimeError, match="closed"):
        live.snapshot()
    with pytest.raises(ValueError, match="needs name"):
        LiveFilteredIndex()
    with pytest.raises(ValueError, match="n_shards"):
        ShardedLiveIndex(tiny_ds, 0)


# ---------------------------------------------------------------------------
# async queue: double-buffered pipeline
# ---------------------------------------------------------------------------

def test_queue_pipeline_matches_unpipelined(tiny_ds, tiny_index,
                                            tiny_queries, toy_router):
    """The two-stage worker must produce exactly the same results and
    decisions as a direct routed search."""
    svc = RouterService(tiny_index, toy_router, t=0.9)
    qs = tiny_queries[Predicate.AND]
    want = svc.search(QueryBatch(qs.vectors, qs.bitmaps, Predicate.AND, 10))
    with AsyncBatchQueue(svc, max_batch=8, max_wait_ms=10) as q:
        assert q._pipelined
        futs = [q.submit(qs.vectors[i], qs.bitmaps[i], Predicate.AND)
                for i in range(qs.q)]
        results = [f.result(timeout=120) for f in futs]
        stats = q.stats()
    assert [r.decision for r in results] == want.decisions
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r.ids, want.ids[i])
    assert stats["batches"] >= 2                 # pipelined across batches
    assert stats["max_queue_depth"] >= 1


def test_queue_depth_high_water_mark(tiny_ds, tiny_index, tiny_queries):
    qs = tiny_queries[Predicate.OR]
    with AsyncBatchQueue(tiny_index, max_batch=64, max_wait_ms=60_000,
                         method="prefilter") as q:
        futs = [q.submit(qs.vectors[i], qs.bitmaps[i], Predicate.OR)
                for i in range(6)]
        q.flush(timeout=120)
        stats = q.stats()
        [f.result(timeout=60) for f in futs]
    assert stats["max_queue_depth"] >= 1
    assert stats["max_queue_depth"] <= 6


def test_queue_serves_live_index_under_writes(tiny_ds, tiny_queries):
    """Concurrent callers + a live writer thread: every result is
    well-formed and never contains a pre-deleted id."""
    qs = tiny_queries[Predicate.AND]
    with LiveFilteredIndex(tiny_ds) as live:
        ids = live.upsert(tiny_ds.vectors[:60] + np.float32(0.01),
                          tiny_ds.bitmaps[:60])
        live.delete(ids[:20])                     # dead before any search
        with AsyncBatchQueue(live, max_batch=8, max_wait_ms=5,
                             method="prefilter") as q:
            stop = threading.Event()

            def writer():
                i = 0
                while not stop.is_set() and i < 40:
                    live.upsert(tiny_ds.vectors[i: i + 1] + np.float32(0.2),
                                tiny_ds.bitmaps[i: i + 1])
                    i += 1
                    time.sleep(0.001)

            th = threading.Thread(target=writer)
            th.start()
            futs = [q.submit(qs.vectors[i % qs.q], qs.bitmaps[i % qs.q],
                             Predicate.AND) for i in range(24)]
            results = [f.result(timeout=120) for f in futs]
            stop.set()
            th.join(timeout=60)
        for r in results:
            assert r.ids.shape == (10,)
            assert not np.isin(r.ids[r.ids >= 0], ids[:20]).any()
