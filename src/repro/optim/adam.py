"""AdamW on arbitrary pytrees — shared by the LM trainer and the router MLPs.

Supports optional 8-bit moment quantization (`compress=True`): moments are
stored as int8 **in the parameter's own shape** with per-block f32 scales
along the last axis, so the optimizer state inherits the parameter's
TP/FSDP sharding exactly — an 8×(+scales) optimizer-memory saving that is
one of the framework's distributed-optimization tricks (DESIGN.md §5).
Dequantize → update → requantize happens inside the jitted step.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ParamDesc, is_desc, map_descs


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    compress: bool = False       # 8-bit moment storage
    block: int = 256             # quantization block size (last axis)


def _block_of(n: int, block: int) -> int:
    return block if (n % block == 0 and n >= block) else n


def _quantize(x: jax.Array, block: int):
    """x [*, n] -> (q int8 [*, n], scale f32 [*, n/blk])."""
    n = x.shape[-1]
    blk = _block_of(n, block)
    nb = n // blk
    xb = x.reshape(x.shape[:-1] + (nb, blk))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale[..., 0].astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, block: int):
    n = q.shape[-1]
    blk = _block_of(n, block)
    nb = n // blk
    qb = q.reshape(q.shape[:-1] + (nb, blk)).astype(jnp.float32)
    return (qb * scale[..., None]).reshape(q.shape)


# ---- state ------------------------------------------------------------------

def adam_init(params: Any, cfg: AdamConfig):
    def zeros_like(p):
        if cfg.compress:
            q, s = _quantize(jnp.zeros(p.shape, jnp.float32), cfg.block)
            return {"q": q, "s": s}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros_like, params),
        "nu": jax.tree.map(zeros_like, params),
    }


def adam_state_desc(param_desc: Any, cfg: AdamConfig, param_dtype=None):
    """ParamDesc tree for the optimizer state (for dry-run specs)."""
    del param_dtype

    def moment(d: ParamDesc):
        if not cfg.compress:
            return ParamDesc(d.shape, jnp.float32, tp=d.tp, fsdp=d.fsdp)
        n = d.shape[-1]
        blk = _block_of(n, cfg.block)
        s_shape = d.shape[:-1] + (n // blk,)
        last = len(d.shape) - 1

        def keep(ax):
            return None if ax is None or (ax == last and n // blk != n) else ax
        return {
            "q": ParamDesc(d.shape, jnp.int8, tp=d.tp, fsdp=d.fsdp),
            "s": ParamDesc(s_shape, jnp.float32,
                           tp=d.tp if d.tp != last else None,
                           fsdp=d.fsdp if d.fsdp != last else None),
        }

    mu = map_descs(moment, param_desc)
    return {"step": ParamDesc((), jnp.int32), "mu": mu,
            "nu": map_descs(moment, param_desc)}


def adam_update(grads: Any, state: Any, params: Any, cfg: AdamConfig,
                lr_scale=1.0):
    """Returns (new_params, new_state). Pure/jittable."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        if cfg.compress:
            mu_f = _dequantize(mu["q"], mu["s"], cfg.block)
            nu_f = _dequantize(nu["q"], nu["s"], cfg.block)
        else:
            mu_f, nu_f = mu, nu
        mu_f = cfg.b1 * mu_f + (1 - cfg.b1) * g
        nu_f = cfg.b2 * nu_f + (1 - cfg.b2) * (g * g)
        update = (mu_f / bc1) / (jnp.sqrt(nu_f / bc2) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (update + cfg.weight_decay * p.astype(jnp.float32)))
        if cfg.compress:
            mq, ms = _quantize(mu_f, cfg.block)
            nq, ns = _quantize(nu_f, cfg.block)
            return new_p.astype(p.dtype), {"q": mq, "s": ms}, {"q": nq, "s": ns}
        return new_p.astype(p.dtype), mu_f, nu_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_params, {"step": step, "mu": new_mu, "nu": new_nu}
