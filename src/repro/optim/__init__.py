"""Optimizers and distributed-optimization tricks (pytree-generic)."""

from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine
from repro.optim.clip import clip_by_global_norm

__all__ = ["AdamConfig", "adam_init", "adam_update", "cosine_schedule",
           "linear_warmup_cosine", "clip_by_global_norm"]
