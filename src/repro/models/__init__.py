"""Composable LM substrate: GQA/MLA attention, MoE, xLSTM/Mamba SSM blocks,
decoder-only and encoder-decoder assemblies, parameter descriptors with
TP/FSDP sharding annotations."""
