"""Parameter descriptors + shared layer math.

Every model declares its parameters as a pytree of `ParamDesc` — shape,
dtype, and *which dimension* shards over tensor-parallel ("model") and
FSDP ("data"(+"pod")) mesh axes. From one descriptor tree we derive:

  * real initialised parameters (smoke tests / examples),
  * `jax.ShapeDtypeStruct`s (the 512-device dry-run never allocates),
  * `PartitionSpec`s for pjit in_shardings (TP/FSDP/EP placement).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDesc:
    shape: tuple
    dtype: Any = jnp.float32
    tp: int | None = None       # dim sharded over the "model" axis
    fsdp: int | None = None     # dim sharded over the data(+pod) axes
    scale: float | None = None  # init std; default fan-in
    zero: bool = False          # zero-init (biases, norm offsets...)
    one: bool = False           # ones-init (norm scales)


def is_desc(x) -> bool:
    return isinstance(x, ParamDesc)


def map_descs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_desc)


# ---- derivations -----------------------------------------------------------

def init_params(tree, key, dtype=None):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_desc)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        dt = dtype or d.dtype
        if d.one:
            out.append(jnp.ones(d.shape, dt))
        elif d.zero:
            out.append(jnp.zeros(d.shape, dt))
        else:
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(
                d.shape[0] if len(d.shape) <= 2 else np.prod(d.shape[:-1]))
            out.append((jax.random.normal(k, d.shape, jnp.float32) * std).astype(dt))
    return treedef.unflatten(out)


def shape_structs(tree, dtype=None):
    return map_descs(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype), tree)


def partition_specs(tree, *, tp_axis="model", tp_size: int,
                    fsdp_axes=(), fsdp_size: int = 1):
    """PartitionSpecs honouring divisibility (falls back to replication)."""

    def spec(d: ParamDesc):
        parts = [None] * len(d.shape)
        if d.tp is not None and tp_size > 1 and d.shape[d.tp] % tp_size == 0:
            parts[d.tp] = tp_axis
        if (d.fsdp is not None and fsdp_axes and fsdp_size > 1
                and d.fsdp != d.tp and parts[d.fsdp] is None
                and d.shape[d.fsdp] % fsdp_size == 0):
            parts[d.fsdp] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
        return P(*parts)

    return map_descs(spec, tree)


def count_params(tree) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(tree, is_leaf=is_desc))


# ---- layer math -------------------------------------------------------------

def cast_floats(tree, dtype):
    """Cast all floating leaves to `dtype` (params -> compute dtype)."""
    return jax.tree.map(
        lambda p: p.astype(dtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, tree)


def rms_norm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale + bias


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x [..., S, H, hd]; positions [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
