"""Model assembly: decoder-only LMs (dense / MoE / MLA / SSM / hybrid) and
the Whisper-style encoder-decoder, from one ParamDesc tree.

Uniform-block models scan over a stacked [L, ...] parameter tree with
`jax.checkpoint` remat per layer; heterogeneous stacks (xLSTM's
sLSTM/mLSTM mix) unroll. Decode threads a per-layer cache pytree through
the same scan. Sliding-window archs (Hymba) use a ring-buffer KV cache of
window size — the sub-quadratic decode path that makes long_500k viable.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.common import ParamDesc, is_desc, map_descs, rms_norm


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Execution context: mesh + axis names + chunking knobs."""
    mesh: Any = None
    tp_axis: str = "model"
    dp_axes: tuple = ("data",)
    tp_size: int = 1
    dp_size: int = 1
    qc_train: int = 1024
    qc_prefill: int = 256
    gla_chunk: int = 256
    # perf knobs (EXPERIMENTS.md §Perf) — baseline keeps both off
    opt_acts: bool = False         # Megatron-style activation constraints
    opt_flash_decode: bool = False # shard_map LSE decode for S-sharded caches


def _shard_act(x, ctx: "ModelCtx", tail=()):
    """Constrain an activation to P(dp, *tail) when opt_acts is on."""
    if ctx is None or not ctx.opt_acts or ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    spec = [dp] + [None] * (x.ndim - 1)
    for i, ax in enumerate(tail):
        d = x.ndim - len(tail) + i
        if ax is not None and x.shape[d] % ctx.tp_size == 0:
            spec[d] = ax
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


# ---------------------------------------------------------------------------
# layer structure
# ---------------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> tuple:
    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)
        return tuple(pat[i % len(pat)] for i in range(cfg.n_layers))
    if cfg.encoder_layers:
        return ("dec",) * cfg.n_layers
    if cfg.family == "hybrid":
        return ("hymba",) * cfg.n_layers
    if cfg.family == "ssm":
        return ("mlstm",) * cfg.n_layers
    return ("attn",) * cfg.n_layers


def layer_desc(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    ln = lambda: ParamDesc((d,), one=True)
    if kind == "attn":
        p = {"ln1": ln(),
             "attn": A.mla_desc(cfg) if cfg.use_mla else A.gqa_desc(cfg),
             "ln2": ln()}
        if cfg.is_moe:
            p["moe"] = M.moe_desc(cfg)
        else:
            p["mlp"] = M.mlp_desc(cfg)
        return p
    if kind == "mlstm":
        return {"ln1": ln(), "mlstm": S.mlstm_desc(cfg)}
    if kind == "slstm":
        return {"ln1": ln(), "slstm": S.slstm_desc(cfg)}
    if kind == "hymba":
        return {"ln1": ln(), "attn": A.gqa_desc(cfg),
                "mamba": S.mamba_desc(cfg), "ln2": ln(),
                "mlp": M.mlp_desc(cfg)}
    if kind == "enc":   # whisper encoder block (bidirectional, gelu MLP)
        return {"ln1": ln(), "attn": A.gqa_desc(cfg), "ln2": ln(),
                "mlp": M.mlp_desc(cfg, gated=False)}
    if kind == "dec":   # whisper decoder block (self + cross + gelu MLP)
        return {"ln1": ln(), "attn": A.gqa_desc(cfg),
                "lnx": ln(), "cross": A.cross_desc(cfg), "ln2": ln(),
                "mlp": M.mlp_desc(cfg, gated=False)}
    raise ValueError(kind)


def _stack_desc(desc: dict, n: int) -> dict:
    def add_dim(d: ParamDesc) -> ParamDesc:
        return ParamDesc((n,) + d.shape, d.dtype,
                         tp=None if d.tp is None else d.tp + 1,
                         fsdp=None if d.fsdp is None else d.fsdp + 1,
                         scale=d.scale, zero=d.zero, one=d.one)
    return map_descs(add_dim, desc)


def model_desc(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    kinds = layer_kinds(cfg)
    tree: dict = {
        "embed": ParamDesc((cfg.vocab, d), tp=0, fsdp=1, scale=0.02),
        "ln_f": ParamDesc((d,), one=True),
        "head": ParamDesc((d, cfg.vocab), tp=1, fsdp=0),
    }
    if len(set(kinds)) == 1:
        tree["layers"] = _stack_desc(layer_desc(cfg, kinds[0]), cfg.n_layers)
    else:
        tree["layers"] = tuple(layer_desc(cfg, k) for k in kinds)
    if cfg.encoder_layers:
        tree["enc_pos"] = ParamDesc((cfg.encoder_seq, d), scale=0.02, fsdp=0)
        tree["enc_layers"] = _stack_desc(layer_desc(cfg, "enc"),
                                         cfg.encoder_layers)
        tree["enc_ln_f"] = ParamDesc((d,), one=True)
    return tree


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_block(kind: str, lp, x, cfg: ModelConfig, ctx: ModelCtx,
                 positions, enc_kv=None, *, qc: int):
    """Residual block (train/prefill shared math). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "enc", "dec"):
        h = _shard_act(rms_norm(x, lp["ln1"], cfg.norm_eps), ctx)
        if cfg.use_mla:
            y = A.mla_train(lp["attn"], h, cfg, positions, qc=qc)
        else:
            y = A.gqa_train(lp["attn"], h, cfg, positions,
                            causal=(kind != "enc"), qc=qc, ctx=ctx)
        x = _shard_act(x + _shard_act(y, ctx), ctx)
        if kind == "dec":
            h = rms_norm(x, lp["lnx"], cfg.norm_eps)
            x = x + A.cross_attend(lp["cross"], h, enc_kv, cfg, qc=qc)
        h = _shard_act(rms_norm(x, lp["ln2"], cfg.norm_eps), ctx)
        if "moe" in lp:
            y, aux = M.moe_apply(lp["moe"], h, cfg, ctx)
        else:
            y = M.mlp_apply(lp["mlp"], h, gated=(kind == "attn"),
                            act=jax.nn.silu if kind == "attn" else jax.nn.gelu,
                            ctx=ctx)
        return _shard_act(x + _shard_act(y, ctx), ctx), aux
    if kind == "mlstm":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        return x + S.mlstm_train(lp["mlstm"], h, cfg, chunk=ctx.gla_chunk), aux
    if kind == "slstm":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, _ = S.slstm_train(lp["slstm"], h, cfg)
        return x + y, aux
    if kind == "hymba":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y_attn = A.gqa_train(lp["attn"], h, cfg, positions, qc=qc, ctx=ctx)
        y_ssm = S.mamba_train(lp["mamba"], h, cfg, chunk=ctx.gla_chunk)
        x = x + 0.5 * (y_attn + y_ssm)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + M.mlp_apply(lp["mlp"], h), aux
    raise ValueError(kind)


def _run_layers(params, x, cfg: ModelConfig, ctx: ModelCtx, positions,
                enc_kv=None, *, qc: int):
    kinds = layer_kinds(cfg)
    aux_tot = jnp.zeros((), jnp.float32)
    if isinstance(params["layers"], tuple):        # heterogeneous: unroll
        for kind, lp in zip(kinds, params["layers"]):
            body = lambda xx, lp=lp, kind=kind: _apply_block(
                kind, lp, xx, cfg, ctx, positions, enc_kv, qc=qc)
            if cfg.remat:
                body = jax.checkpoint(body)
            x, aux = body(x)
            aux_tot += aux
        return x, aux_tot

    kind = kinds[0]

    def body(carry, lp):
        x, aux_tot = carry
        x, aux = _apply_block(kind, lp, x, cfg, ctx, positions, enc_kv, qc=qc)
        return (x, aux_tot + aux), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    (x, aux_tot), _ = jax.lax.scan(scan_body, (x, aux_tot), params["layers"])
    return x, aux_tot


# ---------------------------------------------------------------------------
# public forwards
# ---------------------------------------------------------------------------

def _encode(params, enc_inputs, cfg: ModelConfig, ctx: ModelCtx):
    """Whisper encoder over precomputed frame embeddings [B, S_enc, D]."""
    x = enc_inputs.astype(jnp.dtype(cfg.compute_dtype)) + \
        params["enc_pos"].astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(carry, lp):
        xx, _ = carry
        xx, aux = _apply_block("enc", lp, xx, cfg, ctx, positions,
                               qc=ctx.qc_train)
        return (xx, aux), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    (x, _), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                             params["enc_layers"])
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def forward_train(params, batch, cfg: ModelConfig, ctx: ModelCtx):
    """batch: {tokens [B,S], targets [B,S], (enc_inputs [B,Se,D])}.
    Returns (loss, metrics)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    compute_dt = jnp.dtype(cfg.compute_dtype)
    from repro.models.common import cast_floats
    params = cast_floats(params, compute_dt)
    x = params["embed"][tokens]
    positions = jnp.arange(s, dtype=jnp.int32)
    enc_kv = None
    if cfg.encoder_layers:
        enc_out = _encode(params, batch["enc_inputs"], cfg, ctx)
        # cross K/V computed once per layer inside blocks would recompute the
        # encoder; instead share one projection set per layer via scan input.
        enc_kv = enc_out   # projected per-layer below
    x, aux = _run_layers_encdec(params, x, cfg, ctx, positions, enc_kv) \
        if cfg.encoder_layers else _run_layers(params, x, cfg, ctx,
                                               positions, qc=ctx.qc_train)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, jnp.maximum(targets, 0)[..., None],
                               axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + 0.01 * aux / max(cfg.n_layers, 1)
    return total, {"loss": loss, "aux": aux,
                   "tokens": jnp.sum(mask).astype(jnp.float32)}


def _run_layers_encdec(params, x, cfg, ctx, positions, enc_out):
    def body(carry, lp):
        xx, aux_tot = carry
        kv = A.cross_kv(lp["cross"], enc_out, cfg)
        xx, aux = _apply_block("dec", lp, xx, cfg, ctx, positions, kv,
                               qc=ctx.qc_train)
        return (xx, aux_tot + aux), None

    scan_body = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return x, aux


# ---------------------------------------------------------------------------
# serving: cache structure + prefill + decode
# ---------------------------------------------------------------------------

def cache_desc(cfg: ModelConfig, batch: int, s_max: int) -> Any:
    """Per-layer cache descriptor tree (ShapeDtypeStruct-compatible)."""
    dt = jnp.dtype(cfg.compute_dtype)
    kinds = layer_kinds(cfg)

    def one(kind: str):
        if kind == "attn":
            if cfg.use_mla:
                return {"c_kv": ParamDesc((batch, s_max, cfg.kv_lora_rank),
                                          dt, fsdp=0, tp=1),
                        "k_r": ParamDesc((batch, s_max, cfg.mla_rope_dim),
                                         dt, fsdp=0, tp=1)}
            kv_shardable = cfg.n_kv_heads % 16 == 0
            return {"k": ParamDesc((batch, s_max, cfg.n_kv_heads, cfg.hd), dt,
                                   fsdp=0, tp=2 if kv_shardable else 1),
                    "v": ParamDesc((batch, s_max, cfg.n_kv_heads, cfg.hd), dt,
                                   fsdp=0, tp=2 if kv_shardable else 1)}
        if kind == "dec":
            return {"k": ParamDesc((batch, s_max, cfg.n_kv_heads, cfg.hd), dt,
                                   fsdp=0, tp=2),
                    "v": ParamDesc((batch, s_max, cfg.n_kv_heads, cfg.hd), dt,
                                   fsdp=0, tp=2),
                    "xk": ParamDesc((batch, cfg.encoder_seq, cfg.n_heads,
                                     cfg.hd), dt, fsdp=0, tp=2),
                    "xv": ParamDesc((batch, cfg.encoder_seq, cfg.n_heads,
                                     cfg.hd), dt, fsdp=0, tp=2)}
        if kind == "hymba":
            w = min(cfg.sliding_window or s_max, s_max)
            return {"k": ParamDesc((batch, w, cfg.n_kv_heads, cfg.hd), dt, fsdp=0),
                    "v": ParamDesc((batch, w, cfg.n_kv_heads, cfg.hd), dt, fsdp=0),
                    "slot_pos": ParamDesc((w,), jnp.int32),
                    "state": ParamDesc(S.mamba_state_shape(cfg, batch),
                                       jnp.float32, fsdp=0, tp=1)}
        if kind == "mlstm":
            return {"state": ParamDesc(S.mlstm_state_shape(cfg, batch),
                                       jnp.float32, fsdp=0, tp=1)}
        if kind == "slstm":
            z = (batch, cfg.n_heads, cfg.hd)
            return {"c": ParamDesc(z, jnp.float32, fsdp=0, tp=1),
                    "n": ParamDesc(z, jnp.float32, fsdp=0, tp=1),
                    "h": ParamDesc(z, dt, fsdp=0, tp=1),
                    "m": ParamDesc(z, jnp.float32, fsdp=0, tp=1)}
        raise ValueError(kind)

    kinds_eff = ["dec" if cfg.encoder_layers else k for k in kinds]
    if len(set(kinds_eff)) == 1:
        return _stack_desc(one(kinds_eff[0]), cfg.n_layers)
    return tuple(one(k) for k in kinds_eff)


def _decode_block(kind: str, lp, cache, x, cfg, ctx, pos):
    if kind in ("attn", "dec"):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if cfg.use_mla:
            y, cache2 = A.mla_decode(lp["attn"], h, cache, cfg, pos)
        elif (ctx.opt_flash_decode and ctx.tp_size > 1
              and cfg.n_kv_heads % ctx.tp_size != 0
              and cache["k"].shape[1] % ctx.tp_size == 0):
            # S-sharded cache: sequence-parallel LSE decode (perf opt)
            y, cache2 = A.gqa_decode_flash(lp["attn"], h, cache, cfg, pos,
                                           ctx)
        else:
            y, cache2 = A.gqa_decode(lp["attn"], h, cache, cfg, pos)
        x = x + y
        if kind == "dec":
            h = rms_norm(x, lp["lnx"], cfg.norm_eps)
            x = x + A.cross_attend(lp["cross"], h,
                                   {"k": cache["xk"], "v": cache["xv"]},
                                   cfg, qc=1)
            cache2 = {**cache2, "xk": cache["xk"], "xv": cache["xv"]}
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y, _ = M.moe_apply(lp["moe"], h, cfg, ctx)
        else:
            y = M.mlp_apply(lp["mlp"], h, gated=(kind == "attn"),
                            act=jax.nn.silu if kind == "attn" else jax.nn.gelu)
        return x + y, cache2
    if kind == "hymba":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y_attn, ring = _gqa_decode_ring(
            lp["attn"], h, {"k": cache["k"], "v": cache["v"],
                            "slot_pos": cache["slot_pos"]}, cfg, pos)
        y_ssm, state = S.mamba_decode(lp["mamba"], h, cache["state"], cfg)
        x = x + 0.5 * (y_attn + y_ssm)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + M.mlp_apply(lp["mlp"], h), {**ring, "state": state}
    if kind == "mlstm":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, state = S.mlstm_decode(lp["mlstm"], h, cache["state"], cfg)
        return x + y, {"state": state}
    if kind == "slstm":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, st = S.slstm_train(lp["slstm"], h, cfg, state0=(
            cache["c"], cache["n"], cache["h"], cache["m"]))
        return x + y, {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
    raise ValueError(kind)


def _gqa_decode_ring(p, x, cache, cfg: ModelConfig, pos):
    """Sliding-window ring-buffer KV cache decode (Hymba / SWA)."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    w = cache["k"].shape[1]
    q, knew, vnew = A._qkv(p, x, cfg, pos[None] if pos.ndim == 0 else pos)
    slot = pos % w
    k = jax.lax.dynamic_update_slice(cache["k"], knew, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], vnew, (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
    valid = (slot_pos <= pos) & (slot_pos > pos - (cfg.sliding_window or w))
    qr = q.reshape(b, 1, kv, h // kv, hd)
    scores = jnp.einsum("bqgrh,btgh->bgrqt", qr, k,
                        preferred_element_type=jnp.float32) / jnp.sqrt(hd)
    scores = jnp.where(valid[None, None, None, None, :], scores, A.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqt,btgh->bqgrh", probs, v).reshape(b, 1, -1)
    return out @ p["wo"], {"k": k, "v": v, "slot_pos": slot_pos}


def forward_decode(params, cache, tokens, pos, cfg: ModelConfig,
                   ctx: ModelCtx):
    """One decode step. tokens [B,1], pos scalar int32 (current position).
    Returns (logits [B,1,V], new cache)."""
    compute_dt = jnp.dtype(cfg.compute_dtype)
    from repro.models.common import cast_floats
    params = cast_floats(params, compute_dt)
    x = params["embed"][tokens]
    kinds = layer_kinds(cfg)
    kinds_eff = ["dec" if cfg.encoder_layers else k for k in kinds]
    if isinstance(params["layers"], tuple):
        new_cache = []
        for kind, lp, cl in zip(kinds_eff, params["layers"], cache):
            x, c2 = _decode_block(kind, lp, cl, x, cfg, ctx, pos)
            new_cache.append(c2)
        new_cache = tuple(new_cache)
    else:
        def body(x, sl):
            lp, cl = sl
            x, c2 = _decode_block(kinds_eff[0], lp, cl, x, cfg, ctx, pos)
            return x, c2
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits, new_cache


def forward_prefill(params, batch, cfg: ModelConfig, ctx: ModelCtx,
                    prompt_len: int | None = None):
    """Prefill: full-sequence forward returning next-token logits + cache.

    `prompt_len` (static int) marks the true prompt end when the token
    batch is right-padded to the cache length: recurrent layers mask
    writes beyond it (their state must not absorb padding), the Hymba
    ring cache is sliced to the window *ending at* prompt_len, and logits
    are taken at prompt_len-1. None = the whole sequence is real (the
    dry-run path)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    compute_dt = jnp.dtype(cfg.compute_dtype)
    from repro.models.common import cast_floats
    params = cast_floats(params, compute_dt)
    x = params["embed"][tokens]
    positions = jnp.arange(s, dtype=jnp.int32)
    valid = None if prompt_len is None else \
        (positions < prompt_len)                       # [S] bool
    kinds = layer_kinds(cfg)
    kinds_eff = ["dec" if cfg.encoder_layers else k for k in kinds]
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encode(params, batch["enc_inputs"], cfg, ctx)

    def _mask_writes(k, log_f):
        """Zero recurrent writes (k) and freeze decay (f=1) beyond prompt."""
        if valid is None:
            return k, log_f
        vk = valid[None, :, None, None]
        return jnp.where(vk, k, 0).astype(k.dtype), \
            jnp.where(valid[None, :, None], log_f, 0.0)

    def prefill_block(kind, lp, x):
        if kind in ("attn",):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                y, c = A.mla_prefill(lp["attn"], h, cfg, positions,
                                     qc=ctx.qc_prefill)
            else:
                y, c = A.gqa_prefill(lp["attn"], h, cfg, positions,
                                     qc=ctx.qc_prefill)
            x = x + y
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                y, _ = M.moe_apply(lp["moe"], h, cfg, ctx)
            else:
                y = M.mlp_apply(lp["mlp"], h)
            return x + y, c
        if kind == "dec":
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, c = A.gqa_prefill(lp["attn"], h, cfg, positions,
                                 qc=ctx.qc_prefill)
            x = x + y
            kv = A.cross_kv(lp["cross"], enc_out, cfg)
            h = rms_norm(x, lp["lnx"], cfg.norm_eps)
            x = x + A.cross_attend(lp["cross"], h, kv, cfg, qc=ctx.qc_prefill)
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + M.mlp_apply(lp["mlp"], h, gated=False, act=jax.nn.gelu)
            return x, {"k": c["k"], "v": c["v"], "xk": kv["k"], "xv": kv["v"]}
        if kind == "mlstm":
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v, log_f, o = S._mlstm_qkvgates(lp["mlstm"], h, cfg)
            k, log_f = _mask_writes(k, log_f)
            y, st = S.gla_chunk_scan(q, k, v, log_f, chunk=ctx.gla_chunk)
            y = (y.reshape(b, s, -1) * o) @ lp["mlstm"]["wo"]
            return x + y, {"state": st}
        if kind == "slstm":
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, st = S.slstm_train(lp["slstm"], h, cfg, valid=valid)
            return x + y, {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
        if kind == "hymba":
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y_attn, c = A.gqa_prefill(lp["attn"], h, cfg, positions,
                                      qc=ctx.qc_prefill)
            q, kk, vv, log_f = S._mamba_qkv(lp["mamba"], h, cfg)
            kk, log_f = _mask_writes(kk, log_f)
            y_ssm, st = S.gla_chunk_scan(q, kk, vv, log_f,
                                         chunk=ctx.gla_chunk, normalize=False)
            y_ssm = y_ssm.reshape(b, s, -1) @ lp["mamba"]["w_out"]
            x = x + 0.5 * (y_attn + y_ssm)
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + M.mlp_apply(lp["mlp"], h)
            w = min(cfg.sliding_window or s, s)
            end = s if prompt_len is None else prompt_len
            # ring slot j holds the latest position p < end with p % w == j
            slots = jnp.arange(w, dtype=jnp.int32)
            start = end - w
            p_j = start + ((slots - start) % w)
            ring_idx = jnp.clip(p_j, 0, s - 1)
            ring_k = jnp.take(c["k"], ring_idx, axis=1)
            ring_v = jnp.take(c["v"], ring_idx, axis=1)
            slot_pos = jnp.where((p_j >= 0) & (p_j < end), p_j,
                                 jnp.int32(2 ** 30))
            return x, {"k": ring_k, "v": ring_v,
                       "slot_pos": slot_pos.astype(jnp.int32), "state": st}
        raise ValueError(kind)

    if isinstance(params["layers"], tuple):
        caches = []
        for kind, lp in zip(kinds_eff, params["layers"]):
            x, c = prefill_block(kind, lp, x)
            caches.append(c)
        cache = tuple(caches)
    else:
        def body(x, lp):
            return prefill_block(kinds_eff[0], lp, x)
        x, cache = jax.lax.scan(body, x, params["layers"])
    last = (s - 1) if prompt_len is None else (prompt_len - 1)
    x = rms_norm(x[:, last:last + 1], params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits, cache
