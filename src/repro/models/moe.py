"""Dense MLPs and the Mixture-of-Experts layer.

MoE executes under `shard_map`: tokens are data-sharded and **replicated
across the TP axis**, expert weights shard over the TP axis — expert-
parallel ([E, ...] split, DeepSeek: 160 % 16 == 0) when E divides the TP
axis, otherwise tensor-parallel inside every expert ([.., F, ..] split,
Grok: 8 experts on 16-way TP → F/16). Either way each TP shard computes
only its slice and one `psum` over the TP axis combines — the same
collective a TP dense MLP needs, so EP costs no extra all-to-all under
this layout (tokens are never exchanged across data shards).

Dispatch is gather-based (sort-free): top-k assignment → position-in-
expert by cumsum → an int [E, C] slot table scatter → row gather into
[E, C, D] expert batches. Capacity C = T_local·k/E·capacity_factor;
overflow tokens drop (contribute zero), standard for capacity routing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import ParamDesc


# ---------------------------- dense MLP ----------------------------

def mlp_desc(cfg: ModelConfig, d_ff: int | None = None,
             gated: bool = True) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    p = {"w_up": ParamDesc((d, f), tp=1, fsdp=0),
         "w_down": ParamDesc((f, d), tp=0, fsdp=1)}
    if gated:
        p["w_gate"] = ParamDesc((d, f), tp=1, fsdp=0)
    return p


def mlp_apply(p, x, *, gated: bool = True, act=jax.nn.silu, ctx=None):
    up = x @ p["w_up"]
    h = act(x @ p["w_gate"]) * up if gated else act(up)
    if ctx is not None and getattr(ctx, "opt_acts", False):
        from repro.models.lm import _shard_act
        h = _shard_act(h, ctx, tail=(ctx.tp_axis,))
    return h @ p["w_down"]


# ---------------------------- MoE ----------------------------

def moe_desc(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ep = e % 16 == 0  # advisory only; real decision in partition sizes
    p = {
        "wg": ParamDesc((d, e)),                               # router gate
        "w_gate": ParamDesc((e, d, f), tp=0 if ep else 2, fsdp=1),
        "w_up": ParamDesc((e, d, f), tp=0 if ep else 2, fsdp=1),
        "w_down": ParamDesc((e, f, d), tp=0 if ep else 1, fsdp=2),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_desc(cfg, d_ff=cfg.n_shared_experts * f)
    return p


def _moe_local(x, wg, w_gate, w_up, w_down, *, cfg: ModelConfig,
               tp_axis: str, expert_parallel: bool):
    """Per-shard MoE body (inside shard_map).

    x [T_loc, D] (local token rows, replicated over TP);
    expert weights are the local slice: EP -> [E_loc, D, F];
    TP-in-expert -> [E, D, F_loc]."""
    t, d = x.shape
    e = cfg.n_experts
    k = cfg.experts_per_token
    cap = max(4, int(t * k / e * cfg.capacity_factor + 0.999) // 4 * 4)

    logits = (x.astype(jnp.float32) @ wg.astype(jnp.float32))      # [T, E]
    gval, gidx = jax.lax.top_k(logits, k)                          # [T, k]
    weights = jax.nn.softmax(gval, axis=-1)                        # [T, k]

    # position-in-expert over (token-major, slot-minor) order
    flat_e = gidx.reshape(-1)                                      # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)            # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot                      # count before
    slot_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    valid = slot_pos < cap

    # slot table [E, cap] of source token rows (-1 = empty)
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    table = jnp.full((e, cap), -1, jnp.int32).at[
        flat_e, jnp.minimum(slot_pos, cap - 1)].set(
        jnp.where(valid, tok_ids, -1), mode="drop")
    occupied = table >= 0

    if expert_parallel:
        tp_i = jax.lax.axis_index(tp_axis)
        e_loc = w_gate.shape[0]
        local_table = jax.lax.dynamic_slice_in_dim(table, tp_i * e_loc, e_loc, 0)
        local_occ = jax.lax.dynamic_slice_in_dim(occupied, tp_i * e_loc, e_loc, 0)
    else:
        local_table, local_occ = table, occupied
        e_loc = e

    xin = x[jnp.maximum(local_table, 0)]                           # [E_loc, C, D]
    xin = xin * local_occ[..., None].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", xin, w_up)
    out = jnp.einsum("ecf,efd->ecd", h, w_down)                    # [E_loc, C, D]

    # combine: route each slot's output back to its token, weighted
    if expert_parallel:
        full = jnp.zeros((e, cap, d), out.dtype)
        out_full = jax.lax.dynamic_update_slice_in_dim(
            full, out, tp_i * e_loc, 0)
    else:
        out_full = out
    slot_out = out_full[flat_e, jnp.minimum(slot_pos, cap - 1)]    # [T*k, D]
    slot_out = slot_out * valid[:, None].astype(out.dtype)
    y = jnp.einsum("tkd,tk->td", slot_out.reshape(t, k, d),
                   weights.astype(out.dtype))
    y = jax.lax.psum(y, tp_axis)

    # load-balance auxiliary loss (Switch-style), for training metrics
    me = jnp.mean(jax.nn.softmax(logits, -1), axis=0)
    ce = jnp.mean(onehot.reshape(t, k, e).sum(1).astype(jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return y, aux


def moe_apply(p, x, cfg: ModelConfig, ctx):
    """x [B, S, D] -> (y, aux_loss). ctx: ModelCtx with mesh/axes."""
    b, s, d = x.shape
    ep = (cfg.n_experts % ctx.tp_size == 0) and ctx.tp_size > 1
    dp_axes = ctx.dp_axes
    xf = x.reshape(b * s, d)

    def body(xl, wg, w1, w2, w3):
        y, aux = _moe_local(xl, wg, w1, w2, w3, cfg=cfg,
                            tp_axis=ctx.tp_axis, expert_parallel=ep)
        return y, jax.lax.pmean(aux, dp_axes if len(dp_axes) > 1 else dp_axes[0])

    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    if ep:
        wspec1 = P(ctx.tp_axis, None, None)
        wspec2 = P(ctx.tp_axis, None, None)
    else:
        wspec1 = P(None, None, ctx.tp_axis)
        wspec2 = P(None, ctx.tp_axis, None)
    y, aux = jax.shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(dp, None), P(None, None), wspec1, wspec1, wspec2),
        out_specs=(P(dp, None), P()),
        check_vma=False,
    )(xf, p["wg"], p["w_gate"], p["w_up"], p["w_down"])
    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x)
    return y, aux
