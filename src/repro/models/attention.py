"""Attention blocks: GQA (+ sliding window) and MLA (DeepSeek-V2).

Training/prefill attention is **query-chunked** (scan over Q chunks with
full-K inner attention): peak intermediate is [B, H, qc, S] instead of
[B, H, S, S] — the XLA-friendly flash structure that keeps 32K-token
prefill inside HBM. Decode is a single-token cache read; KV caches for
GQA shard over (batch=data, seq=model) when kv_heads don't divide the TP
axis (see DESIGN.md §5), and MLA caches only the compressed c_kv + shared
rope key, which is the paper-faithful MLA memory win.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDesc, apply_rope

NEG_INF = -1e30


def pick_qc(s: int, qc: int) -> int:
    """Largest divisor of s that is ≤ qc (query-chunk size must tile s —
    e.g. whisper's 1500-frame encoder gets 750 instead of 1024)."""
    qc = min(qc, s)
    while s % qc:
        qc -= 1
    return max(qc, 1)


# ============================ GQA ============================

def gqa_desc(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": ParamDesc((d, h * hd), tp=1, fsdp=0),
        "wk": ParamDesc((d, kv * hd), tp=1, fsdp=0),
        "wv": ParamDesc((d, kv * hd), tp=1, fsdp=0),
        "wo": ParamDesc((h * hd, d), tp=0, fsdp=1),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamDesc((h * hd,), zero=True)
        p["bk"] = ParamDesc((kv * hd,), zero=True)
        p["bv"] = ParamDesc((kv * hd,), zero=True)
    return p


def _qkv(p, x, cfg: ModelConfig, positions, rope: bool = True):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _shard_heads(x, ctx, head_dim_idx: int):
    """Pin attention tensors: heads shard over TP when divisible, else the
    whole tensor is computed model-replicated (prevents XLA partial-summing
    the score einsum over a sharded head_dim — measured 3×470MB all-reduces
    per layer on qwen2 multipod)."""
    if ctx is None or not getattr(ctx, "opt_acts", False) or ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    spec = [None] * x.ndim
    spec[0] = dp
    if x.shape[head_dim_idx] % ctx.tp_size == 0:
        spec[head_dim_idx] = ctx.tp_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def _attend_chunked(q, k, v, *, causal: bool, window: int, q_offset,
                    qc: int, n_rep: int, ctx=None):
    """q [B,S,H,hd], k/v [B,T,KV,hd]; scan over Q chunks. Returns [B,S,H,hd].

    q_offset: position of q[0] relative to k[0] (prefill: 0; enc-dec cross
    attention: causal=False)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    q = _shard_heads(q, ctx, 2)
    k = _shard_heads(k, ctx, 2)
    v = _shard_heads(v, ctx, 2)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qc = pick_qc(s, qc)
    n_chunks = s // qc
    qr = q.reshape(b, n_chunks, qc, kvh, n_rep, hd)
    kpos = jnp.arange(t)

    def one_chunk(ci, qch):
        # qch [B, qc, KV, R, hd]
        scores = jnp.einsum("bqgrh,btgh->bgrqt", qch, k,
                            preferred_element_type=jnp.float32) * scale
        qpos = ci * qc + jnp.arange(qc) + q_offset
        mask = jnp.ones((qc, t), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bgrqt,btgh->bqgrh", probs, v)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(n_chunks), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)
    return out


def gqa_train(p, x, cfg: ModelConfig, positions, *, causal=True,
              qc: int = 1024, ctx=None):
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    out = _attend_chunked(q, k, v, causal=causal, window=cfg.sliding_window,
                          q_offset=0, qc=qc,
                          n_rep=cfg.n_heads // cfg.n_kv_heads, ctx=ctx)
    return out.reshape(b, s, -1) @ p["wo"]


def gqa_prefill(p, x, cfg: ModelConfig, positions, *, qc: int = 256):
    """Returns (y, cache{k,v})."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    out = _attend_chunked(q, k, v, causal=True, window=cfg.sliding_window,
                          q_offset=0, qc=qc, n_rep=cfg.n_heads // cfg.n_kv_heads)
    y = out.reshape(b, s, -1) @ p["wo"]
    return y, {"k": k, "v": v}


def gqa_decode(p, x, cache, cfg: ModelConfig, pos):
    """x [B,1,D]; cache k/v [B,S,KV,hd]; pos scalar int32 (current length).
    Returns (y [B,1,D], new cache)."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, knew, vnew = _qkv(p, x, cfg, pos[None] if pos.ndim == 0 else pos)
    # write the new K/V at position pos
    k = jax.lax.dynamic_update_slice(cache["k"], knew, (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], vnew, (0, pos, 0, 0))
    t = k.shape[1]
    qr = q.reshape(b, 1, kv, h // kv, hd)
    scores = jnp.einsum("bqgrh,btgh->bgrqt", qr, k,
                        preferred_element_type=jnp.float32) / jnp.sqrt(hd)
    kpos = jnp.arange(t)
    mask = kpos <= pos
    if cfg.sliding_window > 0:
        mask &= kpos > pos - cfg.sliding_window
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqt,btgh->bqgrh", probs, v).reshape(b, 1, -1)
    return out @ p["wo"], {"k": k, "v": v}


# ============================ MLA (DeepSeek-V2) ============================
# Decoupled RoPE MLA: cache holds the compressed c_kv [B,S,r] and the
# shared rope key [B,S,rope_dim] only.

MLA_NOPE = 128   # per-head no-rope dim (DeepSeek-V2)
MLA_V = 128      # per-head value dim


def mla_desc(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    rd = cfg.mla_rope_dim
    return {
        "wq": ParamDesc((d, h * (MLA_NOPE + rd)), tp=1, fsdp=0),
        "w_dkv": ParamDesc((d, r), fsdp=0),
        "kv_norm": ParamDesc((r,), one=True),
        "w_uk": ParamDesc((r, h * MLA_NOPE), tp=1, fsdp=0),
        "w_uv": ParamDesc((r, h * MLA_V), tp=1, fsdp=0),
        "w_kr": ParamDesc((d, rd), fsdp=0),
        "wo": ParamDesc((h * MLA_V, d), tp=0, fsdp=1),
    }


def _mla_qkv(p, x, cfg: ModelConfig, positions):
    from repro.models.common import rms_norm

    b, s, _ = x.shape
    h, rd = cfg.n_heads, cfg.mla_rope_dim
    q = (x @ p["wq"]).reshape(b, s, h, MLA_NOPE + rd)
    q_c, q_r = q[..., :MLA_NOPE], q[..., MLA_NOPE:]
    q_r = apply_rope(q_r, positions, cfg.rope_theta)
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)  # [B,S,r]
    k_r = apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                     cfg.rope_theta)[:, :, 0]                    # [B,S,rd]
    return q_c, q_r, c_kv, k_r


def _mla_attend(p, q_c, q_r, c_kv, k_r, cfg, *, causal, q_offset, qc):
    b, s, h, _ = q_c.shape
    t = c_kv.shape[1]
    k_c = (c_kv @ p["w_uk"]).reshape(b, t, h, MLA_NOPE)
    v = (c_kv @ p["w_uv"]).reshape(b, t, h, MLA_V)
    scale = 1.0 / jnp.sqrt(MLA_NOPE + cfg.mla_rope_dim).astype(jnp.float32)
    qc = pick_qc(s, qc)
    n_chunks = s // qc
    qcr = jnp.moveaxis(q_c.reshape(b, n_chunks, qc, h, MLA_NOPE), 1, 0)
    qrr = jnp.moveaxis(q_r.reshape(b, n_chunks, qc, h, cfg.mla_rope_dim), 1, 0)
    kpos = jnp.arange(t)

    def one_chunk(args):
        ci, qcc, qrc = args
        s1 = jnp.einsum("bqhd,bthd->bhqt", qcc, k_c,
                        preferred_element_type=jnp.float32)
        s2 = jnp.einsum("bqhd,btd->bhqt", qrc, k_r,
                        preferred_element_type=jnp.float32)
        scores = (s1 + s2) * scale
        if causal:
            qpos = ci * qc + jnp.arange(qc) + q_offset
            mask = kpos[None, :] <= qpos[:, None]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqt,bthd->bqhd", probs, v)

    out = jax.lax.map(one_chunk, (jnp.arange(n_chunks), qcr, qrr))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h * MLA_V)
    return out @ p["wo"]


def mla_train(p, x, cfg: ModelConfig, positions, *, qc: int = 1024):
    q_c, q_r, c_kv, k_r = _mla_qkv(p, x, cfg, positions)
    return _mla_attend(p, q_c, q_r, c_kv, k_r, cfg, causal=True,
                       q_offset=0, qc=qc)


def mla_prefill(p, x, cfg: ModelConfig, positions, *, qc: int = 256):
    q_c, q_r, c_kv, k_r = _mla_qkv(p, x, cfg, positions)
    y = _mla_attend(p, q_c, q_r, c_kv, k_r, cfg, causal=True, q_offset=0,
                    qc=qc)
    return y, {"c_kv": c_kv, "k_r": k_r}


def mla_decode(p, x, cache, cfg: ModelConfig, pos):
    q_c, q_r, c_new, kr_new = _mla_qkv(
        p, x, cfg, pos[None] if pos.ndim == 0 else pos)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, pos, 0))
    k_r = jax.lax.dynamic_update_slice(cache["k_r"], kr_new, (0, pos, 0))
    b = x.shape[0]
    t = c_kv.shape[1]
    k_c = (c_kv @ p["w_uk"]).reshape(b, t, cfg.n_heads, MLA_NOPE)
    v = (c_kv @ p["w_uv"]).reshape(b, t, cfg.n_heads, MLA_V)
    scale = 1.0 / jnp.sqrt(MLA_NOPE + cfg.mla_rope_dim).astype(jnp.float32)
    s1 = jnp.einsum("bqhd,bthd->bhqt", q_c, k_c,
                    preferred_element_type=jnp.float32)
    s2 = jnp.einsum("bqhd,btd->bhqt", q_r, k_r,
                    preferred_element_type=jnp.float32)
    scores = (s1 + s2) * scale
    mask = jnp.arange(t) <= pos
    scores = jnp.where(mask[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqt,bthd->bqhd", probs, v).reshape(b, 1, -1)
    return out @ p["wo"], {"c_kv": c_kv, "k_r": k_r}


# ============================ cross-attention (enc-dec) ====================

def cross_desc(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "wq": ParamDesc((d, h * hd), tp=1, fsdp=0),
        "wk": ParamDesc((d, h * hd), tp=1, fsdp=0),
        "wv": ParamDesc((d, h * hd), tp=1, fsdp=0),
        "wo": ParamDesc((h * hd, d), tp=0, fsdp=1),
    }


def cross_kv(p, enc_out, cfg: ModelConfig):
    b, t, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(b, t, cfg.n_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(b, t, cfg.n_heads, cfg.hd)
    return {"k": k, "v": v}


def cross_attend(p, x, kv, cfg: ModelConfig, *, qc: int = 1024):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    out = _attend_chunked(q, kv["k"], kv["v"], causal=False, window=0,
                          q_offset=0, qc=qc, n_rep=1)
    return out.reshape(b, s, -1) @ p["wo"]


# ================== sequence-parallel flash decode (perf opt) =============
# When kv_heads don't divide the TP axis the KV cache shards over the
# sequence axis; XLA's auto-partitioner then all-gathers the WHOLE cache
# every decode step (measured: 2x25.8 GB/step on internlm2-1.8b decode_32k).
# This manual shard_map computes per-shard partial attention and combines
# with log-sum-exp: the collective drops to [B, H, hd]-sized psums.

def gqa_decode_flash(p, x, cache, cfg: ModelConfig, pos, ctx):
    """Drop-in for gqa_decode when the cache is S-sharded over the TP axis.

    cache k/v [B, S, KV, hd] sharded P(dp, tp, None, None); x [B,1,D]
    replicated over tp; returns (y [B,1,D], new cache, same sharding)."""
    from jax.sharding import PartitionSpec as P

    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, knew, vnew = _qkv(p, x, cfg, pos[None] if pos.ndim == 0 else pos)
    # cache write: dus on the sharded dim lowers to a shard-local select
    k = jax.lax.dynamic_update_slice(cache["k"], knew, (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], vnew, (0, pos, 0, 0))

    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    tp = ctx.tp_axis
    qr = q.reshape(b, kv, h // kv, hd)

    def core(qs, ks, vs):
        # qs [B_l, KV, R, hd]; ks/vs [B_l, S_l, KV, hd] (local shard)
        s_l = ks.shape[1]
        kpos = jnp.arange(s_l) + jax.lax.axis_index(tp) * s_l
        scores = jnp.einsum("bgrh,btgh->bgrt", qs, ks,
                            preferred_element_type=jnp.float32) / jnp.sqrt(hd)
        mask = kpos <= pos
        if cfg.sliding_window > 0:
            mask &= kpos > pos - cfg.sliding_window
        scores = jnp.where(mask[None, None, None, :], scores, NEG_INF)
        m_loc = jnp.max(scores, axis=-1)                        # [B,KV,R]
        e = jnp.exp(scores - m_loc[..., None])
        l_loc = jnp.sum(e, axis=-1)
        o_loc = jnp.einsum("bgrt,btgh->bgrh", e.astype(vs.dtype), vs)
        # log-sum-exp combine across sequence shards
        m_glob = jax.lax.pmax(m_loc, tp)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * corr, tp)
        o_glob = jax.lax.psum(o_loc * corr[..., None].astype(vs.dtype), tp)
        return (o_glob / jnp.maximum(l_glob, 1e-30)[..., None].astype(vs.dtype))

    out = jax.shard_map(
        core, mesh=ctx.mesh,
        in_specs=(P(dp, None, None, None), P(dp, tp, None, None),
                  P(dp, tp, None, None)),
        out_specs=P(dp, None, None, None),
        check_vma=False)(qr, k, v)
    y = out.reshape(b, 1, h * hd) @ p["wo"]
    return y, {"k": k, "v": v}
