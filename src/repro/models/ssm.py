"""Recurrent sequence mixers: gated linear attention chunk-scan (the shared
TPU-native primitive), mLSTM (xLSTM matrix memory), sLSTM (xLSTM scalar
memory, truly recurrent), and Mamba-style SSD heads (Hymba).

Hardware adaptation: mLSTM/Mamba recurrences are computed in **chunkwise
parallel form** — within a chunk, decay-weighted attention on the MXU;
across chunks, a `lax.scan` carries the [dk, dv] matrix state. This is the
standard SSD/GLA duality and is what makes these layers train at matmul
throughput on TPU while keeping O(1)-state decode (the reason these archs
run the long_500k shape)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDesc


# ---------------------------------------------------------------------------
# GLA chunk scan: y_t = (q_t / z_t) · Σ_{u≤t} (∏_{j=u+1..t} f_j) k_u v_uᵀ
# ---------------------------------------------------------------------------

def gla_chunk_scan(q, k, v, log_f, state0=None, *, chunk: int = 256,
                   normalize: bool = True):
    """q,k [B,S,H,dk], v [B,S,H,dv], log_f [B,S,H] (≤0 decay logs).

    Returns (y [B,S,H,dv], final state [B,H,dk,dv(+1)]).
    If normalize, a ones-column is appended to v to carry the xLSTM
    normalizer n; outputs are divided by max(|q·n|, 1)."""
    b, s, h, dk = q.shape
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0
    n_chunks = s // c
    qc = jnp.moveaxis(q.reshape(b, n_chunks, c, h, dk), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, n_chunks, c, h, dk), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, c, h, dv), 1, 0)
    fc = jnp.moveaxis(log_f.reshape(b, n_chunks, c, h), 1, 0)

    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(state, inputs):
        qi, ki, vi, fi = inputs                  # [B,c,H,*]
        cum = jnp.cumsum(fi.astype(jnp.float32), axis=1)       # [B,c,H]
        tot = cum[:, -1:]                                       # [B,1,H]
        # intra-chunk decay-weighted attention (causal)
        qd = qi.astype(jnp.float32) * jnp.exp(cum)[..., None]
        kd = ki.astype(jnp.float32) * jnp.exp(-cum)[..., None]
        att = jnp.einsum("bqhd,bkhd->bhqk", qd, kd)
        causal = jnp.tril(jnp.ones((c, c), bool))
        att = jnp.where(causal[None, None], att, 0.0)
        y_intra = jnp.einsum("bhqk,bkhv->bqhv", att, vi.astype(jnp.float32))
        # inter-chunk contribution from carried state
        y_inter = jnp.einsum("bqhd,bhdv->bqhv", qd, state)
        # state update
        kdec = ki.astype(jnp.float32) * jnp.exp(tot - cum)[..., None]
        state = jnp.exp(tot)[:, 0, :, None, None] * state + \
            jnp.einsum("bkhd,bkhv->bhdv", kdec, vi.astype(jnp.float32))
        return state, y_intra + y_inter

    state, ys = jax.lax.scan(step, state0, (qc, kc, vc, fc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dv)
    if normalize:
        n = y[..., -1:]
        y = y[..., :-1] / jnp.maximum(jnp.abs(n), 1.0)
    return y.astype(q.dtype), state


def gla_decode_step(q1, k1, v1, log_f1, state, *, normalize: bool = True):
    """One-token recurrent update. q1/k1 [B,1,H,dk], v1 [B,1,H,dv],
    log_f1 [B,1,H], state [B,H,dk,dv(+1)]. Returns (y [B,1,H,dv], state)."""
    if normalize:
        v1 = jnp.concatenate([v1, jnp.ones(v1.shape[:-1] + (1,), v1.dtype)], -1)
    f = jnp.exp(log_f1.astype(jnp.float32))[:, 0, :, None, None]   # [B,H,1,1]
    kv = jnp.einsum("bhd,bhv->bhdv", k1[:, 0].astype(jnp.float32),
                    v1[:, 0].astype(jnp.float32))
    state = f * state + kv
    y = jnp.einsum("bhd,bhdv->bhv", q1[:, 0].astype(jnp.float32), state)
    if normalize:
        n = y[..., -1:]
        y = y[..., :-1] / jnp.maximum(jnp.abs(n), 1.0)
    return y[:, None].astype(q1.dtype), state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM): matrix memory + exponential gating
# ---------------------------------------------------------------------------

def mlstm_desc(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "wq": ParamDesc((d, h * hd), tp=1, fsdp=0),
        "wk": ParamDesc((d, h * hd), tp=1, fsdp=0),
        "wv": ParamDesc((d, h * hd), tp=1, fsdp=0),
        "wi": ParamDesc((d, h)),        # input gate (exp)
        "wf": ParamDesc((d, h)),        # forget gate
        "wo_gate": ParamDesc((d, h * hd), tp=1, fsdp=0),
        "wo": ParamDesc((h * hd, d), tp=0, fsdp=1),
    }


def _mlstm_qkvgates(p, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, h, hd) / jnp.sqrt(hd)
    v = (x @ p["wv"]).reshape(b, s, h, hd)
    log_f = jax.nn.log_sigmoid((x @ p["wf"]).astype(jnp.float32))  # [B,S,H]
    i_gate = jnp.exp(jnp.minimum((x @ p["wi"]).astype(jnp.float32), 8.0))
    k = k * i_gate[..., None].astype(k.dtype)   # fold input gate into writes
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    return q, k, v, log_f, o


def mlstm_train(p, x, cfg: ModelConfig, *, chunk: int = 256):
    b, s, _ = x.shape
    q, k, v, log_f, o = _mlstm_qkvgates(p, x, cfg)
    y, _ = gla_chunk_scan(q, k, v, log_f, chunk=chunk)
    y = y.reshape(b, s, -1) * o
    return y @ p["wo"]


def mlstm_decode(p, x, state, cfg: ModelConfig):
    b = x.shape[0]
    q, k, v, log_f, o = _mlstm_qkvgates(p, x, cfg)
    y, state = gla_decode_step(q, k, v, log_f, state)
    y = y.reshape(b, 1, -1) * o
    return y @ p["wo"], state


def mlstm_state_shape(cfg: ModelConfig, batch: int):
    return (batch, cfg.n_heads, cfg.hd, cfg.hd + 1)


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM): scalar memory, h_{t-1} recurrence — lax.scan over time
# ---------------------------------------------------------------------------

def slstm_desc(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "wx": ParamDesc((d, h * hd * 4), tp=1, fsdp=0),    # i,f,z,o from x
        "wr": ParamDesc((h, hd, hd * 4), tp=0, fsdp=1),    # block-diag recurrence
        "wo": ParamDesc((h * hd, d), tp=0, fsdp=1),
    }


def slstm_train(p, x, cfg: ModelConfig, state0=None, valid=None):
    """valid: optional [S] bool — False positions write nothing (i=0) and
    keep state (f=1); used by padded-prefill serving."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    gx = (x @ p["wx"]).reshape(b, s, h, hd * 4)

    if state0 is None:
        state0 = slstm_init_state(cfg, b, h_dtype=x.dtype)

    if valid is None:
        valid = jnp.ones((s,), bool)

    def step(carry, inputs):
        gxt, v_t = inputs
        c, n, hprev, m = carry                 # each [B,H,hd]
        g = gxt + jnp.einsum("bhd,hdf->bhf", hprev, p["wr"])
        gi, gf, gz, go = jnp.split(g.astype(jnp.float32), 4, axis=-1)
        log_i = jnp.where(v_t, jnp.minimum(gi, 8.0), -30.0)
        log_f = jnp.where(v_t, jax.nn.log_sigmoid(gf), 0.0)
        m_new = jnp.maximum(log_f + m, log_i)
        c = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * jnp.tanh(gz)
        n = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
        hnew = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        hkeep = jnp.where(v_t, hnew.astype(gxt.dtype), hprev)
        return (c, n, hkeep, m_new), hnew

    carry, ys = jax.lax.scan(step, state0,
                             (jnp.moveaxis(gx, 1, 0), valid))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h * hd).astype(x.dtype)
    return y @ p["wo"], carry


def slstm_decode(p, x, state, cfg: ModelConfig):
    y, state = slstm_train(p, x, cfg, state0=state)
    return y, state


def slstm_init_state(cfg: ModelConfig, batch: int, h_dtype=jnp.float32):
    z = jnp.zeros((batch, cfg.n_heads, cfg.hd), jnp.float32)
    return (z, z, z.astype(h_dtype), z - 10.0)


# ---------------------------------------------------------------------------
# Mamba-style SSD heads (Hymba): scalar-decay GLA with small state dim
# ---------------------------------------------------------------------------

def mamba_desc(cfg: ModelConfig) -> dict:
    d, h, n = cfg.d_model, cfg.n_heads, cfg.ssm_state
    hd = cfg.hd
    return {
        "w_in": ParamDesc((d, h * hd), tp=1, fsdp=0),     # values (x path)
        "w_b": ParamDesc((d, h * n)),                      # input proj B (keys)
        "w_c": ParamDesc((d, h * n)),                      # output proj C (queries)
        "w_dt": ParamDesc((d, h)),                         # per-head step size
        "a_log": ParamDesc((h,), zero=True),               # per-head decay base
        "w_out": ParamDesc((h * hd, d), tp=0, fsdp=1),
    }


def _mamba_qkv(p, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, n, hd = cfg.n_heads, cfg.ssm_state, cfg.hd
    v = (x @ p["w_in"]).reshape(b, s, h, hd)
    kk = (x @ p["w_b"]).reshape(b, s, h, n)
    q = (x @ p["w_c"]).reshape(b, s, h, n)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32))      # [B,S,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                   # [H] < 0
    log_f = dt * a[None, None, :]
    v = v * dt[..., None].astype(v.dtype)      # Euler-step input scaling
    return q, kk, v, log_f


def mamba_train(p, x, cfg: ModelConfig, *, chunk: int = 256):
    b, s, _ = x.shape
    q, k, v, log_f = _mamba_qkv(p, x, cfg)
    y, _ = gla_chunk_scan(q, k, v, log_f, chunk=chunk, normalize=False)
    return y.reshape(b, s, -1) @ p["w_out"]


def mamba_decode(p, x, state, cfg: ModelConfig):
    b = x.shape[0]
    q, k, v, log_f = _mamba_qkv(p, x, cfg)
    y, state = gla_decode_step(q, k, v, log_f, state, normalize=False)
    return y.reshape(b, 1, -1) @ p["w_out"], state


def mamba_state_shape(cfg: ModelConfig, batch: int):
    return (batch, cfg.n_heads, cfg.ssm_state, cfg.hd)
