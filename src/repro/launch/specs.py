"""ShapeDtypeStruct input stand-ins + PartitionSpecs for every
(architecture × input shape × mesh) dry-run cell. No device allocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import MeshAxes
from repro.models import common, lm
from repro.optim import adam as adam_mod


def batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for the step's data inputs."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        d = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "targets": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    elif shape.kind == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    else:  # decode: one new token against an s-long cache
        d = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.encoder_layers and shape.kind != "decode":
        d["enc_inputs"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return d


def batch_partition(cfg: ModelConfig, shape: ShapeSpec, axes: MeshAxes):
    dp = axes.dp_axes if len(axes.dp_axes) > 1 else axes.dp_axes[0]
    bdim = dp if shape.global_batch % axes.dp_size == 0 else None
    out = {"tokens": P(bdim, None)}
    if shape.kind == "train":
        out["targets"] = P(bdim, None)
    if cfg.encoder_layers and shape.kind != "decode":
        out["enc_inputs"] = P(bdim, None, None)
    return out


def param_structs(cfg: ModelConfig):
    desc = lm.model_desc(cfg)
    return common.shape_structs(desc, dtype=jnp.dtype(cfg.param_dtype)), desc


def param_partition(desc, axes: MeshAxes, *, fsdp: bool):
    return common.partition_specs(
        desc, tp_axis=axes.tp_axis, tp_size=axes.tp_size,
        fsdp_axes=axes.dp_axes if fsdp else (),
        fsdp_size=axes.dp_size if fsdp else 1)


def opt_structs(desc, cfg: ModelConfig, opt_cfg):
    """ShapeDtypeStructs + PartitionSpecs for the Adam state."""
    return adam_mod.adam_state_desc(desc, opt_cfg,
                                    param_dtype=jnp.dtype(cfg.param_dtype))


def cache_structs(cfg: ModelConfig, shape: ShapeSpec, axes: MeshAxes):
    """Decode cache ShapeDtypeStructs + PartitionSpecs.

    KV caches shard batch over data; the sequence axis shards over `model`
    when kv-heads don't divide the TP axis (DESIGN.md §5)."""
    desc = lm.cache_desc(cfg, shape.global_batch, shape.seq_len)
    structs = common.shape_structs(desc)

    dp = axes.dp_axes if len(axes.dp_axes) > 1 else axes.dp_axes[0]
    b_ok = shape.global_batch % axes.dp_size == 0

    def spec(d: common.ParamDesc):
        # cache descs mark the batch dim via `fsdp`; layer stacking shifts
        # every dim index by one, so resolve against the actual shape.
        parts = [None] * len(d.shape)
        if (b_ok and d.fsdp is not None and d.fsdp < len(d.shape)
                and d.shape[d.fsdp] == shape.global_batch):
            parts[d.fsdp] = dp
        if d.tp is not None and d.tp < len(d.shape) \
                and d.shape[d.tp] % axes.tp_size == 0 and parts[d.tp] is None:
            parts[d.tp] = axes.tp_axis
        return P(*parts)

    specs = common.map_descs(spec, desc)
    return structs, specs
