"""Fault-tolerant training driver.

Composes the substrate: token pipeline (step-indexed, bitwise resumable),
train_step (grad accumulation + remat + AdamW), checkpoint manager
(atomic, rotated, async), straggler monitor, and preemption handler.
Works at smoke scale on one CPU device and unchanged on a production mesh
(pass `mesh` + shardings — the dry-run proves those compile).

CLI:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 30 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config, get_smoke_config
from repro.data.tokens import TokenStream
from repro.launch import steps as ST
from repro.models import lm
from repro.optim import AdamConfig
from repro.runtime import PreemptionHandler, StepMonitor


def train_loop(cfg, *, steps: int, global_batch: int, seq_len: int,
               ckpt_dir: str | None = None, save_every: int = 50,
               log_every: int = 10, lr: float = 3e-4, seed: int = 0,
               mesh=None, resume: bool = True, accum: int = 1,
               deadline_s: float | None = None, verbose: bool = True):
    from repro.launch.mesh import make_mesh_compat

    mesh = mesh or make_mesh_compat((1, 1), ("data", "model"))
    dp_axes = tuple(n for n in mesh.axis_names if n != "model")
    ctx = lm.ModelCtx(mesh=mesh, dp_axes=dp_axes,
                      tp_size=mesh.shape["model"],
                      dp_size=int(np.prod([mesh.shape[a] for a in dp_axes])),
                      qc_train=min(1024, seq_len),
                      gla_chunk=min(256, seq_len))
    opt_cfg = AdamConfig(lr=lr, weight_decay=0.01, compress=cfg.opt_compress)
    params, opt_state = ST.init_train_state(cfg, jax.random.PRNGKey(seed),
                                            opt_cfg)
    stream = TokenStream(cfg.vocab, seq_len, global_batch, seed=seed + 1)

    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if manager and resume and manager.latest_step() is not None:
        state, meta = manager.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = int(meta["step"])
        if verbose:
            print(f"resumed from step {start_step}", flush=True)

    step_fn = jax.jit(ST.make_train_step(cfg, ctx, accum=accum,
                                         opt_cfg=opt_cfg),
                      donate_argnums=(0, 1))
    monitor = StepMonitor(deadline_s=deadline_s)
    preempt = PreemptionHandler()
    history = []
    try:
        with mesh:
            for step in range(start_step, steps):
                monitor.start_step()
                batch = {k: jnp.asarray(v)
                         for k, v in stream.batch(step).items()}
                if cfg.encoder_layers:
                    batch["enc_inputs"] = 0.05 * jax.random.normal(
                        jax.random.PRNGKey(step),
                        (global_batch, cfg.encoder_seq, cfg.d_model))
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                stats = monitor.end_step()
                history.append({"step": step + 1, "loss": loss, **stats})
                if verbose and (step + 1) % log_every == 0:
                    print(f"step {step+1:5d} loss {loss:.4f} "
                          f"({stats['step_time_s']:.2f}s"
                          f"{' STRAGGLER' if stats['straggler'] else ''})",
                          flush=True)
                if stats["escalate"] and verbose:
                    print("straggler escalation: recommend checkpoint + "
                          "reschedule", flush=True)
                want_save = manager and ((step + 1) % save_every == 0
                                         or step + 1 == steps
                                         or preempt.requested)
                if want_save:
                    manager.save(step + 1,
                                 {"params": params, "opt": opt_state},
                                 background=False)
                if preempt.requested:
                    if verbose:
                        print(f"preemption: checkpointed at {step+1}, "
                              "exiting cleanly", flush=True)
                    break
    finally:
        preempt.restore()
        if manager:
            manager.wait()
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    _, _, hist = train_loop(cfg, steps=args.steps, global_batch=args.batch,
                            seq_len=args.seq, ckpt_dir=args.ckpt,
                            accum=args.accum, lr=args.lr)
    print(f"first loss {hist[0]['loss']:.4f} -> last {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
