import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture × input shape × mesh) cell
lowers AND compiles on the production meshes, and harvest the roofline
inputs (memory_analysis, cost_analysis, per-collective bytes) from the
compiled artifact.

The two lines above MUST stay first: jax locks the device count on first
initialisation, and the dry-run needs 512 placeholder host devices to build
the (2, 16, 16) multi-pod mesh. Smoke tests and benchmarks never import
this module, so they keep seeing 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all [--force]

Each cell's result (status, memory stats, FLOPs, collective bytes, wall
compile time) is cached as JSON under artifacts/dryrun/.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import artifacts_dir, enable_compilation_cache
from repro.configs.base import (SHAPES, ARCH_IDS, get_config,
                                shape_supported)
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models import common, lm
from repro.optim import adam as adam_mod

FSDP_PARAM_THRESHOLD = 3e9   # shard params over data axes above this


OPT_LEVELS = {
    "none": {"ctx": {}, "cfg": {}},
    # §Perf iteration 1+2: activation sharding constraints + sequence-
    # parallel LSE flash decode for S-sharded KV caches
    "v1": {"ctx": {"opt_acts": True, "opt_flash_decode": True}, "cfg": {}},
    # §Perf iteration 3: + attention head-sharding pins and 4x larger
    # microbatches for the FSDP giants — ZeRO-3 weight all-gathers are
    # re-issued per accumulation step, so accum 16->4 cuts gather volume
    # 4x at the cost of 4x activation memory (remat-bounded)
    "v2": {"ctx": {"opt_acts": True, "opt_flash_decode": True,
                   "qc_train": 512},
           "cfg": {"microbatch_seqs": 4}},
    # §Perf iteration 4: v2's accum 16->4 overflows HBM on the 236B
    # (temp 51.7 GB CPU-f32 ≈ 26 GB bf16 > 16 GB); accum 16->8 is the
    # fit-constrained optimum (2x fewer ZeRO-3 re-gathers, temp halved)
    "v3": {"ctx": {"opt_acts": True, "opt_flash_decode": True,
                   "qc_train": 512},
           "cfg": {"microbatch_seqs": 2}},
}


def _apply_opt_cfg(cfg, opt: str):
    import dataclasses as _dc

    over = dict(OPT_LEVELS[opt]["cfg"])
    if over.get("microbatch_seqs") and cfg.microbatch_seqs >= over["microbatch_seqs"]:
        over.pop("microbatch_seqs")        # only raise, never lower
    return _dc.replace(cfg, **over) if over else cfg


def build_ctx(mesh, axes, shape, opt: str = "none"):
    kw = {"qc_train": 1024, "qc_prefill": 256, "gla_chunk": 256}
    kw.update(OPT_LEVELS[opt]["ctx"])
    return lm.ModelCtx(mesh=mesh, tp_axis=axes.tp_axis,
                       dp_axes=axes.dp_axes, tp_size=axes.tp_size,
                       dp_size=axes.dp_size, **kw)


def shard(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt: str = "none"):
    """Returns (lowered, meta) for one cell."""
    cfg = _apply_opt_cfg(get_config(arch), opt)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axes(mesh)
    ctx = build_ctx(mesh, axes, shape, opt)
    param_sds, desc = SP.param_structs(cfg)
    n_params = common.count_params(desc)
    fsdp = n_params > FSDP_PARAM_THRESHOLD
    pspecs = SP.param_partition(desc, axes, fsdp=fsdp)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_params": n_params, "fsdp": fsdp,
            "family": cfg.family}

    with mesh:
        if shape.kind == "train":
            accum = ST.accum_steps(cfg, shape, axes.dp_size)
            meta["accum_steps"] = accum
            opt_cfg = ST.default_opt_cfg(cfg)
            opt_desc = adam_mod.adam_state_desc(desc, opt_cfg)
            opt_sds = common.shape_structs(opt_desc)
            opt_specs = SP.param_partition(opt_desc, axes, fsdp=fsdp)
            batch_sds = SP.batch_specs(cfg, shape)
            bspecs = SP.batch_partition(cfg, shape, axes)
            step = ST.make_train_step(cfg, ctx, accum=accum, opt_cfg=opt_cfg)
            jitted = jax.jit(
                step,
                in_shardings=(shard(mesh, pspecs), shard(mesh, opt_specs),
                              shard(mesh, bspecs)),
                out_shardings=(shard(mesh, pspecs), shard(mesh, opt_specs),
                               None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(param_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            batch_sds = SP.batch_specs(cfg, shape)
            bspecs = SP.batch_partition(cfg, shape, axes)
            cache_sds, cache_specs = SP.cache_structs(cfg, shape, axes)
            step = ST.make_prefill_step(cfg, ctx)
            jitted = jax.jit(
                step,
                in_shardings=(shard(mesh, pspecs), shard(mesh, bspecs)),
                out_shardings=(None, shard(mesh, cache_specs)))
            lowered = jitted.lower(param_sds, batch_sds)
        else:  # decode
            cache_sds, cache_specs = SP.cache_structs(cfg, shape, axes)
            bspec = SP.batch_partition(cfg, shape, axes)["tokens"]
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step = ST.make_decode_step(cfg, ctx)
            jitted = jax.jit(
                step,
                in_shardings=(shard(mesh, pspecs), shard(mesh, cache_specs),
                              NamedSharding(mesh, bspec), None),
                out_shardings=(None, shard(mesh, cache_specs)),
                donate_argnums=(1,))
            lowered = jitted.lower(param_sds, cache_sds, tokens, pos)
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             *, keep_hlo: bool = False, opt: str = "none") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_supported(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod, opt)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        mem_stats = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes",
                     "peak_memory_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_stats[attr] = int(v)
        print(f"[{arch} {shape_name} {mesh_name}] memory_analysis:",
              mem_stats, flush=True)
        try:
            cost = dict(compiled.cost_analysis())
            cost = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float))}
        except Exception:
            cost = {}
        print(f"[{arch} {shape_name} {mesh_name}] cost_analysis "
              f"flops={cost.get('flops')}", flush=True)
        hlo_text = compiled.as_text()
        summary = analyze(hlo_text)
        result = {**meta, "status": "ok",
                  "lower_s": round(t_lower, 1),
                  "compile_s": round(t_compile, 1),
                  "memory": mem_stats,
                  "cost_analysis": cost,
                  "hlo_dot_flops": summary.dot_flops,
                  "hlo_hbm_bytes": summary.hbm_bytes,
                  "collective_bytes": summary.coll_bytes,
                  "collective_by_kind": dict(summary.coll_by_kind),
                  "hlo_size_chars": len(hlo_text)}
        if keep_hlo:
            sub = "dryrun" if opt == "none" else f"dryrun_{opt}"
            path = os.path.join(artifacts_dir(sub, "hlo"),
                                f"{arch}_{shape_name}_{mesh_name}.hlo")
            with open(path, "w") as f:
                f.write(hlo_text)
            result["hlo_path"] = path
        return result
    except Exception as e:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def cell_path(arch, shape_name, mesh_name, opt: str = "none"):
    sub = "dryrun" if opt == "none" else f"dryrun_{opt}"
    return os.path.join(artifacts_dir(sub),
                        f"{arch}_{shape_name}_{mesh_name}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--opt", default="none", choices=list(OPT_LEVELS))
    args = ap.parse_args()
    enable_compilation_cache()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                path = cell_path(arch, shape_name, mesh_name, args.opt)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"cached   {arch:18s} {shape_name:12s} "
                              f"{mesh_name}: {prev['status']}", flush=True)
                        n_ok += prev["status"] == "ok"
                        n_skip += prev["status"] == "skipped"
                        continue
                res = run_cell(arch, shape_name, multi_pod,
                               keep_hlo=args.keep_hlo, opt=args.opt)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                tag = res["status"]
                n_ok += tag == "ok"
                n_skip += tag == "skipped"
                n_err += tag == "error"
                extra = ""
                if tag == "ok":
                    extra = (f"compile={res['compile_s']}s "
                             f"flops={res['hlo_dot_flops']:.3e} "
                             f"coll={res['collective_bytes']:.3e}B")
                elif tag == "error":
                    extra = res["error"][:160]
                print(f"{tag:8s} {arch:18s} {shape_name:12s} {mesh_name}: "
                      f"{extra}", flush=True)
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} skipped={n_skip} errors={n_err}",
          flush=True)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
