"""Batched serving driver: prefill + greedy decode over a prompt batch.

Prompts are padded to the cache length; prefill returns each example's
true-prompt-end logits (`last_index`) and a cache whose padded slots are
progressively overwritten as decode advances — no recomputation, single
compile for the whole generation loop.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models import common, lm


def pad_prompts(prompts: list[list[int]], s_max: int, pad_id: int = 0):
    b = len(prompts)
    toks = np.full((b, s_max), pad_id, dtype=np.int32)
    lens = np.zeros(b, dtype=np.int32)
    for i, p in enumerate(prompts):
        p = p[:s_max]
        toks[i, :len(p)] = p
        lens[i] = len(p)
    return jnp.asarray(toks), jnp.asarray(lens)


def generate(params, cfg, prompts: list[list[int]], *, max_new: int,
             ctx: lm.ModelCtx | None = None, enc_inputs=None,
             greedy: bool = True, seed: int = 0):
    """Greedy/sampled generation. Returns [B, max_new] int32 tokens.

    Note: all prompts must share one length for exact ring-buffer (Hymba)
    semantics; mixed lengths are fine for full-cache archs."""
    from repro.launch.mesh import make_mesh_compat

    ctx = ctx or lm.ModelCtx(
        mesh=make_mesh_compat((1, 1), ("data", "model")),
        qc_prefill=64, gla_chunk=64)
    lens_set = {len(p) for p in prompts}
    assert len(lens_set) == 1, \
        "generate() requires uniform prompt lengths (recurrent state + " \
        "ring caches are masked against a single static prompt_len)"
    max_len = max(len(p) for p in prompts)
    s_max = max_len + max_new
    # keep chunked shapes divisible
    s_max = ((s_max + 63) // 64) * 64
    tokens, _lens = pad_prompts(prompts, s_max)
    batch = {"tokens": tokens}
    if enc_inputs is not None:
        batch["enc_inputs"] = enc_inputs

    prefill = jax.jit(lambda p, b: lm.forward_prefill(
        p, b, cfg, ctx, prompt_len=max_len))
    decode = jax.jit(lambda p, c, t, pos: lm.forward_decode(
        p, c, t, pos, cfg, ctx))

    with ctx.mesh:
        logits, cache = prefill(params, batch)
        out = []
        key = jax.random.PRNGKey(seed)
        for i in range(max_new):
            if greedy:
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, -1]).astype(jnp.int32)
            out.append(nxt)
            pos = jnp.asarray(max_len + i, jnp.int32)
            logits, cache = decode(params, cache, nxt[:, None], pos)
    return np.stack([np.asarray(t) for t in out], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = common.init_params(lm.model_desc(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, min(cfg.vocab, 200),
                                 size=args.prompt_len))
               for _ in range(args.batch)]
    enc = None
    if cfg.encoder_layers:
        enc = jnp.asarray(0.05 * rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    toks = generate(params, cfg, prompts, max_new=args.max_new,
                    enc_inputs=enc)
    print("generated:", toks[:, :8], "... shape", toks.shape)


if __name__ == "__main__":
    main()
