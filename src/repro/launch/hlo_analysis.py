"""Static analysis of compiled (SPMD-partitioned) HLO text.

Extracts the three roofline ingredients per device:
  * dot FLOPs            — every `dot` op, 2·K·|out| (K resolved through a
                           per-computation symbol table), loop-aware;
  * HBM traffic bytes    — operand+output bytes of real ops at fusion
                           boundaries (bitcast/GTE/parameter/tuple excluded);
  * collective bytes     — all-reduce / all-gather / reduce-scatter /
                           all-to-all / collective-permute output bytes,
                           split per collective class.

Loop awareness: `while` bodies (jax.lax.scan/fori — layer stacks, grad
accumulation, query chunking) appear once in HLO text but execute
trip-count times; we recover trip counts from the loop condition's
compare-against-constant and multiply through nested loops. `conditional`
branches contribute their maximum. Fusion computations are descended for
FLOPs (dots stay dots) but not bytes (fused intermediates never touch HBM).

All shapes in post-partitioning HLO are per-device, so every number this
module reports is per-chip. Note: the XLA *CPU* backend upcasts bf16 dots
to f32, so byte counts from CPU-compiled HLO over-estimate a TPU's bf16
traffic by ≤2× — stated in EXPERIMENTS.md §Roofline methodology.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that are pure bookkeeping — no HBM traffic of their own
NO_TRAFFIC_OPS = {"bitcast", "get-tuple-element", "parameter", "tuple",
                  "constant", "after-all", "partition-id", "replica-id",
                  "iota", "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMPARE_RE = re.compile(
    r"compare\(\s*%?([\w\.\-]+),\s*%?([\w\.\-]+)\s*\).*direction=(LT|GT|LE|GE)")


def _shapes_in(text: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(text: str) -> int:
    return sum(DTYPE_BYTES[dt] * n for dt, n, _ in _shapes_in(text))


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    whiles: list = dataclasses.field(default_factory=list)   # (cond, body)
    fusions: list = dataclasses.field(default_factory=list)
    calls: list = dataclasses.field(default_factory=list)
    conditionals: list = dataclasses.field(default_factory=list)
    constants: dict = dataclasses.field(default_factory=dict)
    compares: list = dataclasses.field(default_factory=list)


def parse_hlo(text: str):
    comps: dict[str, CompStats] = {}
    cur: CompStats | None = None
    symtab: dict[str, list] = {}
    entry_name = None
    for raw in text.splitlines():
        if not raw.strip():
            continue
        if not raw.startswith(" ") and raw.rstrip().endswith("{") \
                and "->" in raw:
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", raw)
            if m:
                cur = comps.setdefault(m.group(2), CompStats())
                symtab = {}
                if m.group(1):
                    entry_name = m.group(2)
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        line = raw.strip()
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rhs = d.group(1), d.group(2)
        # output shape = first shape group on the RHS (covers tuples too)
        rhs_head = rhs.split("(", 1)[0]
        out_shapes = _shapes_in(rhs_head)
        if out_shapes:
            symtab[name] = out_shapes[0][2]        # dims of first component
        opm = _OP_RE.search(rhs)
        op = opm.group(1) if opm else ""

        cm = _CONST_RE.search(rhs)
        if cm and "constant(" in rhs:
            cur.constants[name] = int(cm.group(1))
        pm = _COMPARE_RE.search(rhs)
        if pm:
            cur.compares.append((pm.group(1), pm.group(2), pm.group(3)))

        # collectives
        matched_coll = None
        for kind in COLLECTIVES:
            if op in (kind, kind + "-start"):
                matched_coll = kind
                break
        if matched_coll:
            nbytes = _bytes_of(rhs_head)
            cur.coll_bytes += nbytes
            cur.coll_by_kind[matched_coll] += nbytes

        # dot FLOPs: 2 * K * |out|
        if op == "dot":
            out_elems = 1
            for dim in (out_shapes[0][2] if out_shapes else []):
                out_elems *= dim
            ops_m = re.search(r"dot\(([^)]*)\)", rhs)
            k = 1
            if ops_m:
                first_operand = ops_m.group(1).split(",")[0].strip()
                first_operand = first_operand.lstrip("%")
                lhs_dims = symtab.get(first_operand)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if lhs_dims and cdims and cdims.group(1):
                    for idx in cdims.group(1).split(","):
                        i = int(idx)
                        if i < len(lhs_dims):
                            k *= lhs_dims[i]
            cur.dot_flops += 2.0 * k * out_elems

        # HBM traffic (skip bookkeeping ops; count output shape bytes —
        # operand bytes are the producing op's outputs, already counted)
        if op not in NO_TRAFFIC_OPS and op:
            cur.hbm_bytes += _bytes_of(rhs_head)

        # structure
        if op == "while":
            mcond = re.search(r"condition=%?([\w\.\-]+)", rhs)
            mbody = re.search(r"body=%?([\w\.\-]+)", rhs)
            if mcond and mbody:
                cur.whiles.append((mcond.group(1), mbody.group(1)))
        elif op == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", rhs)
            if m:
                cur.fusions.append(m.group(1))
        elif op == "conditional":
            b = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if b:
                cur.conditionals.append(
                    [x.strip().lstrip("%") for x in b.group(1).split(",")])
        elif op in ("call", "async-start") or " to_apply=" in rhs:
            if not matched_coll and op not in ("reduce", "reduce-window",
                                               "scatter", "select-and-scatter",
                                               "sort", "map"):
                m = re.search(r"to_apply=%?([\w\.\-]+)", rhs)
                if m:
                    cur.calls.append(m.group(1))

    return comps, entry_name


def _trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    for a, b, _direction in cond.compares:
        for name in (b, a):
            if name in cond.constants:
                return max(1, cond.constants[name])
    if len(cond.constants) == 1:
        return max(1, next(iter(cond.constants.values())))
    return 1


@dataclasses.dataclass
class HLOSummary:
    dot_flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: dict


def analyze(text: str) -> HLOSummary:
    comps, entry = parse_hlo(text)
    memo: dict = {}

    def walk(name: str, in_fusion: bool, depth=0):
        if depth > 64 or name not in comps:
            return (0.0, 0.0, 0.0, {})
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        memo[key] = (0.0, 0.0, 0.0, {})      # cycle guard
        c = comps[name]
        flops = c.dot_flops
        hbm = 0.0 if in_fusion else c.hbm_bytes
        coll = c.coll_bytes
        kinds = dict(c.coll_by_kind)

        def acc(res, mult=1.0):
            nonlocal flops, hbm, coll
            flops += res[0] * mult
            hbm += res[1] * mult
            coll += res[2] * mult
            for k, v in res[3].items():
                kinds[k] = kinds.get(k, 0.0) + v * mult

        for cond, body in c.whiles:
            trip = _trip_count(comps, cond)
            acc(walk(body, in_fusion, depth + 1), trip)
            acc(walk(cond, in_fusion, depth + 1), trip)
        for f in c.fusions:
            acc(walk(f, True, depth + 1))
        for f in c.calls:
            acc(walk(f, in_fusion, depth + 1))
        for branches in c.conditionals:
            results = [walk(b, in_fusion, depth + 1) for b in branches]
            if results:
                best = max(results, key=lambda r: r[0] + r[1])
                acc(best)
        memo[key] = (flops, hbm, coll, kinds)
        return memo[key]

    flops, hbm, coll, kinds = walk(entry, False) if entry else (0, 0, 0, {})
    return HLOSummary(dot_flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                      coll_by_kind=kinds)
