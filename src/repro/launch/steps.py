"""Step builders: train_step (grad-accum microbatching + remat + AdamW) and
serve steps (prefill / decode). Pure functions of (params, opt, batch) so
dry-run lowering needs only ShapeDtypeStructs."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import lm
from repro.optim import AdamConfig, adam_init, adam_update, clip_by_global_norm


def default_opt_cfg(cfg: ModelConfig) -> AdamConfig:
    return AdamConfig(lr=3e-4, weight_decay=0.01, compress=cfg.opt_compress)


def accum_steps(cfg: ModelConfig, shape: ShapeSpec, dp_size: int) -> int:
    per_replica = max(1, shape.global_batch // dp_size)
    return max(1, per_replica // max(cfg.microbatch_seqs, 1))


def make_train_step(cfg: ModelConfig, ctx: lm.ModelCtx, *, accum: int,
                    opt_cfg: AdamConfig | None = None, max_grad_norm=1.0):
    opt_cfg = opt_cfg or default_opt_cfg(cfg)

    def train_step(params, opt_state, batch):
        b = batch["tokens"].shape[0]
        mb = b // accum

        def loss_fn(p, mbatch):
            return lm.forward_train(p, mbatch, cfg, ctx)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            from jax.sharding import PartitionSpec as P
            dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]

            def split(a):
                # [B, ...] -> [accum, B/accum, ...]; row b -> (b % accum,
                # b // accum) so each microbatch spans every data shard.
                out = a.reshape(mb, accum, *a.shape[1:]).swapaxes(0, 1)
                return jax.lax.with_sharding_constraint(
                    out, P(None, dp, *([None] * (a.ndim - 1))))

            micro = jax.tree.map(split, batch)

            def body(carry, mbatch):
                g_acc, loss_acc = carry
                (loss, _), grads = grad_fn(params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = {}

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = adam_update(grads, opt_state, params, opt_cfg)
        out_metrics = {"loss": loss, "grad_norm": gnorm,
                       "step": opt_state["step"]}
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: lm.ModelCtx):
    def prefill_step(params, batch):
        return lm.forward_prefill(params, batch, cfg, ctx)
    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: lm.ModelCtx):
    def decode_step(params, cache, tokens, pos):
        return lm.forward_decode(params, cache, tokens, pos, cfg, ctx)
    return decode_step


def init_train_state(cfg: ModelConfig, key, opt_cfg: AdamConfig | None = None):
    """Materialised params + optimizer state (examples/smoke scale only)."""
    from repro.models import common

    opt_cfg = opt_cfg or default_opt_cfg(cfg)
    desc = lm.model_desc(cfg)
    params = common.init_params(desc, key,
                                dtype=jnp.dtype(cfg.param_dtype))
    return params, adam_init(params, opt_cfg)
