"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis composes
with `data` for batch/FSDP sharding (gradient all-reduce crosses the DCN).

Defined as functions (never module-level constants) so importing this
module touches no jax device state.
"""

from __future__ import annotations

import dataclasses

import jax


def make_mesh_compat(shape, axes):
    """`jax.make_mesh` across jax versions: newer releases take (and some
    sharding modes need) `axis_types=Auto`; 0.4.x has no such kwarg."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp_axes: tuple      # axes batch/FSDP shard over (includes "pod")
    tp_axis: str
    dp_size: int
    tp_size: int


def mesh_axes(mesh) -> MeshAxes:
    names = mesh.axis_names
    tp_axis = "model"
    dp_axes = tuple(n for n in names if n != tp_axis)
    dp_size = 1
    for n in dp_axes:
        dp_size *= mesh.shape[n]
    return MeshAxes(dp_axes=dp_axes, tp_axis=tp_axis,
                    dp_size=dp_size, tp_size=mesh.shape[tp_axis])
