"""Synthetic filtered-ANN dataset pool.

The paper trains on six real-world datasets (arxiv, yfcc, LAION-1M,
tripclick, ytb_audio, ytb_video) and validates on five unseen ones
(synth_192d, synth_512d, synth_768d_hc, yahoo800k, dbpedia560k). This
container is offline, so we synthesise datasets that mirror each one's
*structural* characteristics — size ratio, dimensionality, label
cardinality, label skew (Zipf), geometric difficulty (LID via latent
dimensionality), and label–vector coupling — at a scale the 1-core CPU
budget affords. Every generator is deterministic in its seed.

Vectors: Gaussian clusters on an `latent_dim`-dimensional manifold embedded
into `dim` ambient dims (controls LID), plus ambient noise. Labels: a blend
of cluster-preferred labels (label–vector coupling, drives the paper's
"distribution factor") and global Zipf draws.

Queries follow paper §6.1.3: query vector = base vector + Gaussian noise at
10% of the median base norm; Equality/AND carry 1–3 labels drawn from an
existing vector's label set; OR carries a broader 2–8 label set.
"""

from __future__ import annotations

import dataclasses
import os
from functools import lru_cache

import numpy as np

from repro.ann import labels as lb
from repro.ann.dataset import ANNDataset, QuerySet, ground_truth_topk
from repro.ann.predicates import Predicate


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    dim: int
    universe: int
    latent_dim: int          # manifold dim -> controls LID_mean
    n_clusters: int
    zipf_a: float            # label popularity skew
    avg_labels: float        # mean labels per vector
    coupling: float          # 0..1 share of labels taken from cluster-preferred pool
    noise: float             # ambient noise scale (raises LID)
    seed: int


def _scale() -> float:
    """Global dataset size multiplier (REPRO_ANN_SCALE env, default 1)."""
    return float(os.environ.get("REPRO_ANN_SCALE", "1.0"))


# Mirrors paper Table 2 (training) — sizes/dims scaled to CPU budget, with
# relative ordering of size, dim, |U| and LID difficulty preserved.
TRAIN_SPECS = {
    "arxiv":      DatasetSpec("arxiv",      9000, 96,  400, 12, 64, 1.3, 2.2, 0.5, 0.30, 101),
    "yfcc":       DatasetSpec("yfcc",      16000, 48, 2000, 10, 96, 1.2, 3.0, 0.5, 0.25, 102),
    "laion":      DatasetSpec("laion",     16000, 64,   30, 16, 48, 1.4, 1.6, 0.6, 0.35, 103),
    "tripclick":  DatasetSpec("tripclick", 16000, 96,   29, 14, 48, 1.5, 1.5, 0.6, 0.30, 104),
    "ytb_audio":  DatasetSpec("ytb_audio", 20000, 32,  500,  8, 80, 1.3, 2.0, 0.5, 0.20, 105),
    # ytb_video is the paper's high-LID outlier (LID_mean = 236): nearly
    # isotropic full-rank Gaussian, weak cluster structure.
    "ytb_video":  DatasetSpec("ytb_video",  8000, 128, 500, 128, 8, 1.3, 2.0, 0.3, 1.00, 106),
}

# Mirrors paper Table 4 (validation, unseen during router training).
VALIDATION_SPECS = {
    "synth_192d":    DatasetSpec("synth_192d",    12000, 48,  200, 10, 64, 1.2, 2.0, 0.5, 0.25, 201),
    "synth_512d":    DatasetSpec("synth_512d",    12000, 64,   30, 14, 48, 1.4, 1.6, 0.6, 0.30, 202),
    "synth_768d_hc": DatasetSpec("synth_768d_hc", 12000, 96, 1000, 20, 96, 1.2, 2.5, 0.4, 0.45, 203),
    "yahoo800k":     DatasetSpec("yahoo800k",     12000, 96,   14, 24, 32, 1.6, 1.3, 0.5, 0.50, 204),
    "dbpedia560k":   DatasetSpec("dbpedia560k",    9000, 96,   14, 22, 32, 1.6, 1.2, 0.5, 0.45, 205),
}

ALL_SPECS = {**TRAIN_SPECS, **VALIDATION_SPECS}


def synthesize(spec: DatasetSpec) -> ANNDataset:
    rng = np.random.default_rng(spec.seed)
    n, d, m, c = spec.n, spec.dim, spec.latent_dim, spec.n_clusters
    n = max(64, int(n * _scale()))

    # --- vectors: latent Gaussian clusters embedded into ambient space ---
    centers = rng.normal(0.0, 1.0, size=(c, m)).astype(np.float32) * 4.0
    assign = rng.integers(0, c, size=n)
    latent = centers[assign] + rng.normal(0.0, 1.0, size=(n, m)).astype(np.float32)
    basis = rng.normal(0.0, 1.0 / np.sqrt(m), size=(m, d)).astype(np.float32)
    vecs = latent @ basis + spec.noise * rng.normal(0.0, 1.0, size=(n, d)).astype(np.float32)

    # --- labels: cluster-preferred pool blended with global Zipf draws ---
    u = spec.universe
    # global Zipf popularity over labels
    pop = (np.arange(1, u + 1, dtype=np.float64)) ** (-spec.zipf_a)
    pop /= pop.sum()
    perm = rng.permutation(u)            # decouple label id from popularity rank
    pop = pop[np.argsort(perm)]
    pref_size = max(1, min(u, int(np.ceil(u / c)) + 2))
    cluster_pref = [rng.choice(u, size=pref_size, replace=False, p=pop) for _ in range(c)]

    label_sets: list[list[int]] = []
    counts = rng.poisson(max(spec.avg_labels - 1.0, 0.0), size=n) + 1
    for i in range(n):
        k = int(min(counts[i], u))
        ls: set[int] = set()
        pref = cluster_pref[assign[i]]
        while len(ls) < k:
            if rng.random() < spec.coupling:
                ls.add(int(pref[rng.integers(0, len(pref))]))
            else:
                ls.add(int(rng.choice(u, p=pop)))
        label_sets.append(sorted(ls))

    return ANNDataset.build(spec.name, vecs, label_sets, u)


@lru_cache(maxsize=None)
def get_dataset(name: str) -> ANNDataset:
    return synthesize(ALL_SPECS[name])


def make_queries(ds: ANNDataset, pred: Predicate, n_queries: int, *,
                 k: int = 10, seed: int = 0,
                 with_ground_truth: bool = True) -> QuerySet:
    """Generate a filtered query workload per paper §6.1.3."""
    pred = Predicate(pred)
    rng = np.random.default_rng(seed + 7 * int(pred))
    n = ds.n
    base_idx = rng.integers(0, n, size=n_queries)
    med_norm = float(np.median(np.sqrt(ds.norms_sq)))
    qvecs = ds.vectors[base_idx] + (0.1 * med_norm / np.sqrt(ds.dim)) * \
        rng.normal(0.0, 1.0, size=(n_queries, ds.dim)).astype(np.float32)
    qvecs = qvecs.astype(np.float32)

    # label frequencies for OR sampling
    label_freq = np.zeros(ds.universe, dtype=np.float64)
    for g in range(ds.n_groups):
        for l in lb.unpack_one(ds.group_bitmaps[g]):
            label_freq[l] += float(ds.group_size[g])
    label_p = label_freq / label_freq.sum() if label_freq.sum() > 0 else None

    qbms = np.zeros((n_queries, ds.bitmaps.shape[1]), dtype=np.uint32)
    for qi in range(n_queries):
        src = lb.unpack_one(ds.bitmaps[rng.integers(0, n)])
        src_sorted = sorted(src)
        if pred == Predicate.EQUALITY:
            ls = src_sorted                      # exact existing label set
        elif pred == Predicate.AND:
            take = int(rng.integers(1, min(3, len(src_sorted)) + 1))
            ls = list(rng.choice(src_sorted, size=take, replace=False))
        else:  # OR: broader 2-8 labels, frequency-weighted
            take = int(rng.integers(2, 9))
            ls = list(np.unique(rng.choice(
                ds.universe, size=take, replace=True, p=label_p)))
        qbms[qi] = lb.pack_one([int(x) for x in ls], ds.universe)

    gt = (ground_truth_topk(ds, qvecs, qbms, pred, k)
          if with_ground_truth else np.full((n_queries, k), -1, np.int32))
    return QuerySet(dataset=ds.name, pred=pred, vectors=qvecs,
                    bitmaps=qbms, ground_truth=gt, k=k)
