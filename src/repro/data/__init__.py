"""Data substrate: synthetic filtered-ANN datasets (mirroring the paper's
train/validation pools) and the deterministic token pipeline for LM training."""
