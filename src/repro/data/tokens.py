"""Deterministic synthetic token pipeline for LM training.

Sequences are sampled from a fixed random bigram chain (vocab-restricted),
so they carry learnable structure — training loss demonstrably decreases.
Batches are a pure function of (seed, step): the iterator state is just the
step counter, which makes checkpoint-resume exact (bitwise) and sharding-
agnostic. This is the property a production loader needs at multi-pod
scale (restore data position from the step id, no host-local cursors).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    active_vocab: int = 256     # bigram chain lives on a vocab subset

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.active_vocab, self.vocab)
        # sparse-ish bigram transition table: each symbol has 8 likely successors
        succ = rng.integers(0, v, size=(v, 8))
        self._succ = succ.astype(np.int64)
        self._v = v

    def batch(self, step: int) -> dict:
        """Pure function of step -> {tokens, targets} [B, S] int32."""
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        seq = np.empty((b, s + 1), dtype=np.int64)
        seq[:, 0] = rng.integers(0, self._v, size=b)
        choices = rng.integers(0, 8, size=(b, s))
        mix = rng.random((b, s)) < 0.1          # 10% uniform noise
        noise = rng.integers(0, self._v, size=(b, s))
        for t in range(s):
            nxt = self._succ[seq[:, t], choices[:, t]]
            seq[:, t + 1] = np.where(mix[:, t], noise[:, t], nxt)
        return {"tokens": seq[:, :-1].astype(np.int32),
                "targets": seq[:, 1:].astype(np.int32)}
