"""Checkpointing: sharding-agnostic save/restore + rotation + async save.

Arrays are written as one `.npz` with path-flattened keys plus a JSON
manifest (tree structure, dtypes, step metadata). Writes are atomic
(tmp + rename), so a preemption mid-save never corrupts the latest
checkpoint. Restore returns host arrays that the caller `device_put`s
with *its* shardings — which is exactly what elastic resharding needs
(restore on a different mesh than the one that saved).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    dtypes = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = str(arr.dtype)
        if arr.dtype.name == "bfloat16":    # npz can't round-trip ml_dtypes
            arr = arr.view(np.uint16)
        out[key] = arr
    return out, dtypes


def save_pytree(path: str, tree, *, metadata: dict | None = None) -> None:
    """Atomic save of an arbitrary pytree of arrays."""
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, dtypes = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"treedef": str(treedef), "keys": sorted(flat),
                   "dtypes": dtypes, "metadata": metadata or {}}, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def restore_pytree(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    import ml_dtypes

    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        dtypes = json.load(f).get("dtypes", {})
    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    leaves = []
    for p, leaf in flat_like:
        key = "/".join(str(getattr(x, "key", getattr(x, "idx", x)))
                       for x in p)
        arr = data[key]
        if dtypes.get(key) == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(getattr(leaf, "shape", ()))
        if tuple(arr.shape) != want:
            raise ValueError(f"checkpoint mismatch at {key}: "
                             f"{arr.shape} vs {want}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_metadata(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["metadata"]


class CheckpointManager:
    """Step-indexed checkpoint directory with rotation and async save."""

    def __init__(self, directory: str, *, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree, *, metadata: dict | None = None,
             background: bool = False) -> None:
        meta = {"step": step, **(metadata or {})}
        if background:
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._save_sync(step, tree, meta)

    def _save_sync(self, step, tree, meta):
        save_pytree(self._step_dir(step), tree, metadata=meta)
        self._rotate()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def restore(self, like, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        tree = restore_pytree(self._step_dir(step), like)
        meta = restore_metadata(self._step_dir(step))
        return tree, meta

    def _rotate(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
