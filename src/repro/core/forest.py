"""CART trees + RandomForest (numpy) — used for (a) the RandomForest
feature-importance ranking driving the paper's nested feature ablation
(§6.2a) and (b) the RF-Reg / RF-classifier rows of Table 6.

Trees are array-encoded (feature/threshold/left/right/value) for fast
vectorised prediction. `y` may be [N] (regression) or [N, C] one-hot
(classification-as-regression, argmax at predict) — the SSE split
criterion covers both.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Tree:
    feature: np.ndarray     # [nodes] int32, -1 = leaf
    threshold: np.ndarray   # [nodes] float32
    left: np.ndarray        # [nodes] int32
    right: np.ndarray       # [nodes] int32
    value: np.ndarray       # [nodes, C] float32 leaf means


def _best_split(x, y, feat_ids, n_thresholds, min_leaf):
    """Vectorised best (feature, threshold) by SSE reduction."""
    n = x.shape[0]
    ysum = y.sum(0)
    ysq = (y * y).sum()
    base = ysq - (ysum * ysum).sum() / n
    best = (None, None, 0.0)
    for f in feat_ids:
        xv = x[:, f]
        qs = np.unique(np.quantile(xv, np.linspace(0.05, 0.95, n_thresholds)))
        if qs.size == 0:
            continue
        m = xv[None, :] <= qs[:, None]                    # [T, N]
        nl = m.sum(1).astype(np.float64)                  # [T]
        ok = (nl >= min_leaf) & (n - nl >= min_leaf)
        if not ok.any():
            continue
        sl = m.astype(np.float64) @ y                     # [T, C]
        sr = ysum[None, :] - sl
        with np.errstate(divide="ignore", invalid="ignore"):
            sse = ysq - (sl * sl).sum(1) / np.maximum(nl, 1) \
                      - (sr * sr).sum(1) / np.maximum(n - nl, 1)
        red = np.where(ok, base - sse, -np.inf)
        j = int(np.argmax(red))
        if red[j] > best[2]:
            best = (f, float(qs[j]), float(red[j]))
    return best


def fit_tree(x: np.ndarray, y: np.ndarray, *, max_depth=8, min_leaf=8,
             n_thresholds=12, rng=None, max_features=None,
             importance=None) -> Tree:
    if y.ndim == 1:
        y = y[:, None]
    n, f = x.shape
    nodes = {"feature": [], "threshold": [], "left": [], "right": [], "value": []}

    def new_node():
        for k in nodes:
            nodes[k].append(0 if k != "value" else np.zeros(y.shape[1]))
        return len(nodes["feature"]) - 1

    def build(idx, depth):
        node = new_node()
        yy = y[idx]
        nodes["value"][node] = yy.mean(0)
        nodes["feature"][node] = -1
        if depth >= max_depth or idx.size < 2 * min_leaf:
            return node
        feat_ids = np.arange(f)
        if max_features and rng is not None:
            feat_ids = rng.choice(f, size=min(max_features, f), replace=False)
        fid, thr, red = _best_split(x[idx], yy, feat_ids, n_thresholds, min_leaf)
        if fid is None or red <= 1e-12:
            return node
        if importance is not None:
            importance[fid] += red
        m = x[idx, fid] <= thr
        nodes["feature"][node] = fid
        nodes["threshold"][node] = thr
        nodes["left"][node] = build(idx[m], depth + 1)
        nodes["right"][node] = build(idx[~m], depth + 1)
        return node

    build(np.arange(n), 0)
    return Tree(
        feature=np.asarray(nodes["feature"], np.int32),
        threshold=np.asarray(nodes["threshold"], np.float32),
        left=np.asarray(nodes["left"], np.int32),
        right=np.asarray(nodes["right"], np.int32),
        value=np.stack(nodes["value"]).astype(np.float32))


def predict_tree(t: Tree, x: np.ndarray) -> np.ndarray:
    idx = np.zeros(x.shape[0], dtype=np.int32)
    active = t.feature[idx] >= 0
    while active.any():
        f = t.feature[idx]
        go_left = x[np.arange(x.shape[0]), np.maximum(f, 0)] <= t.threshold[idx]
        nxt = np.where(go_left, t.left[idx], t.right[idx])
        idx = np.where(active, nxt, idx)
        active = t.feature[idx] >= 0
    return t.value[idx]


class RandomForest:
    """Regression (y [N]) or classification-as-regression (y [N, C])."""

    def __init__(self, n_trees=20, max_depth=8, min_leaf=8, seed=0,
                 max_features=None):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.seed = seed
        self.max_features = max_features
        self.trees: list[Tree] = []
        self.feature_importances_: np.ndarray | None = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RandomForest":
        rng = np.random.default_rng(self.seed)
        n = x.shape[0]
        imp = np.zeros(x.shape[1])
        self.trees = []
        mf = self.max_features or max(1, int(np.sqrt(x.shape[1])))
        for _ in range(self.n_trees):
            boot = rng.integers(0, n, size=n)
            self.trees.append(fit_tree(
                x[boot], y[boot] if y.ndim == 1 else y[boot, :],
                max_depth=self.max_depth, min_leaf=self.min_leaf,
                rng=rng, max_features=mf, importance=imp))
        tot = imp.sum()
        self.feature_importances_ = imp / tot if tot > 0 else imp
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        out = sum(predict_tree(t, x) for t in self.trees) / len(self.trees)
        return out[:, 0] if out.shape[1] == 1 else out
