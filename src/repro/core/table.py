"""The offline benchmark table B (paper Eq. 6):

    B[ds, pt, m, ps] = (recall, QPS)

built by benchmarking every (method, parameter setting) on every
(dataset, predicate type) combination, exactly as the paper's offline
stage does. Persisted as JSON under artifacts/."""

from __future__ import annotations

import dataclasses
import json

# version-stamped table file (legacy bare-list files read as version 0);
# `repro.ann.store` validates this stamp against the one recorded at
# link time so a store never routes with a silently-swapped table.
TABLE_FORMAT = "repro.benchmark-table"
TABLE_VERSION = 1


def table_file_version(path: str) -> int:
    """Version stamp of a saved table file (0 for the legacy bare-list
    format). Raises ValueError if the file is not a benchmark table."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, list):
        return 0
    if isinstance(data, dict) and data.get("format") == TABLE_FORMAT:
        return int(data.get("version", -1))
    raise ValueError(f"{path!r} is not a benchmark table file")


@dataclasses.dataclass
class BenchmarkTable:
    entries: dict  # (ds, pt:int, method, ps_id) -> {"recall": float, "qps": float}

    @staticmethod
    def new() -> "BenchmarkTable":
        return BenchmarkTable(entries={})

    def add(self, ds: str, pt: int, method: str, ps_id: str,
            recall: float, qps: float) -> None:
        self.entries[(ds, int(pt), method, ps_id)] = {
            "recall": float(recall), "qps": float(qps)}

    def copy(self) -> "BenchmarkTable":
        """Deep-enough copy: fresh entries dict with fresh cell dicts.
        The online layer (`repro.ann.telemetry.OnlineBenchmarkTable`)
        builds on this so EWMA folds never mutate the offline table."""
        return BenchmarkTable(
            entries={k: dict(v) for k, v in self.entries.items()})

    def settings(self, ds: str, pt: int, method: str):
        out = []
        for (d, p, m, ps_id), v in self.entries.items():
            if (d, p, m) == (ds, int(pt), method):
                out.append((ps_id, v))
        return out

    def best_qps_setting(self, ds: str, pt: int, method: str, t: float):
        """argmax_ps QPS s.t. recall >= T  (Alg. 2 line 8); None if no
        setting meets T."""
        cands = [(ps_id, v) for ps_id, v in self.settings(ds, pt, method)
                 if v["recall"] >= t]
        if not cands:
            return None
        return max(cands, key=lambda kv: kv[1]["qps"])

    def max_recall_setting(self, ds: str, pt: int, method: str):
        """Fallback (Alg. 2 line 14): the max-recall setting."""
        cands = self.settings(ds, pt, method)
        if not cands:
            return None
        return max(cands, key=lambda kv: (kv[1]["recall"], kv[1]["qps"]))

    def routing_arrays(self, ds: str, pt: int, methods: list, t: float):
        """Per-method routing tables for the vectorised Algorithm 2.

        Returns (has_pass [M] bool, qps [M] float, ps_pass [M] ps_id|None,
        ps_fallback [M] ps_id|None): the best-QPS setting meeting T per
        method, and the fallback setting (best-QPS-meeting-T, else
        max-recall) used when no method passes the threshold.
        """
        import numpy as np

        m = len(methods)
        has_pass = np.zeros(m, dtype=bool)
        qps = np.full(m, -np.inf)
        ps_pass = np.empty(m, dtype=object)
        ps_fallback = np.empty(m, dtype=object)
        for j, name in enumerate(methods):
            hit = self.best_qps_setting(ds, pt, name, t)
            if hit is not None:
                has_pass[j] = True
                ps_pass[j] = hit[0]
                qps[j] = hit[1]["qps"]
            fb = hit or self.max_recall_setting(ds, pt, name)
            ps_fallback[j] = fb[0] if fb else None
        return has_pass, qps, ps_pass, ps_fallback

    # ---- persistence ----
    def save(self, path: str) -> None:
        """Write the version-stamped table file (format, version, rows)."""
        rows = [{"ds": k[0], "pt": k[1], "method": k[2], "ps": k[3], **v}
                for k, v in self.entries.items()]
        with open(path, "w") as f:
            json.dump({"format": TABLE_FORMAT, "version": TABLE_VERSION,
                       "rows": rows}, f, indent=1)

    @staticmethod
    def load(path: str) -> "BenchmarkTable":
        """Read a saved table: the stamped format, or the legacy bare
        list (version 0). Raises ValueError for a newer-than-supported
        version."""
        with open(path) as f:
            data = json.load(f)
        if isinstance(data, dict):
            if data.get("format") != TABLE_FORMAT:
                raise ValueError(
                    f"{path!r} is not a {TABLE_FORMAT} file "
                    f"(format={data.get('format')!r})")
            if int(data.get("version", -1)) > TABLE_VERSION:
                raise ValueError(
                    f"table file version {data['version']} is newer than "
                    f"supported version {TABLE_VERSION}")
            rows = data["rows"]
        else:
            rows = data            # legacy pre-stamp list
        t = BenchmarkTable.new()
        for r in rows:
            t.add(r["ds"], r["pt"], r["method"], r["ps"], r["recall"], r["qps"])
        return t
