"""Feature extraction — all 22 candidate features from paper §4.2.

Groups:
  (1) 6 query-aware     — n_labels, selectivity, min/max/mean per-label
                          frequency, label co-occurrence;
  (2) 15 dataset-level  — size, dim, LID mean/median/std, relative-contrast
                          median / 5–95% trimmed mean / p95, label
                          cardinality, label entropy, #unique label
                          combinations, avg labels per vector, distribution
                          factor (mean sliced Wasserstein), correlation
                          ratio, normalized correlation ratio;
  (3) 1 predicate type  — categorical (one-hot in the model input, counted
                          as a single feature as in the paper).

The final minimal set (paper §6.2): ``selectivity, lid_mean, pred``.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

from repro.ann import labels as lb
from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate

QUERY_FEATURES = [
    "n_labels", "selectivity", "min_label_freq", "max_label_freq",
    "mean_label_freq", "label_cooccurrence",
]
DATASET_FEATURES = [
    "size", "dim", "lid_mean", "lid_median", "lid_std",
    "rc_median", "rc_trimmed_mean", "rc_p95",
    "label_cardinality", "label_entropy", "n_label_combinations",
    "avg_labels_per_vector", "distribution_factor",
    "correlation_ratio", "normalized_correlation_ratio",
]
NUMERIC_FEATURES = QUERY_FEATURES + DATASET_FEATURES   # 21 numeric
ALL_FEATURES = NUMERIC_FEATURES + ["pred"]             # + categorical = 22

MINIMAL_FEATURES = ["selectivity", "lid_mean", "pred"]  # paper's final set


# ---------------------------------------------------------------------------
# dataset-level features
# ---------------------------------------------------------------------------

def _knn_dists(vectors: np.ndarray, queries: np.ndarray, k: int) -> np.ndarray:
    """[Q, k] ascending Euclidean distances (self-matches removed)."""
    n2 = (vectors ** 2).sum(1)
    d = n2[None, :] - 2.0 * queries @ vectors.T + (queries ** 2).sum(1)[:, None]
    d = np.maximum(d, 0.0)
    kk = min(k + 1, d.shape[1])
    part = np.partition(d, kk - 1, axis=1)[:, :kk]
    part = np.sort(part, axis=1)
    # drop a zero self-distance column if present
    out = np.where(part[:, :1] < 1e-9, part[:, 1:kk], part[:, :kk - 1]) \
        if kk > 1 else part
    return np.sqrt(out)


def lid_mle(r: np.ndarray) -> np.ndarray:
    """Maximum-likelihood LID per query from ascending kNN distances r [Q,k]
    (paper Eq. 3)."""
    rk = r[:, -1:]
    ratio = np.clip(r / np.maximum(rk, 1e-12), 1e-12, 1.0)
    m = np.mean(np.log(ratio), axis=1)
    return -1.0 / np.minimum(m, -1e-9)


def _sliced_w1(a: np.ndarray, b: np.ndarray, n_proj: int, rng) -> float:
    """Mean sliced Wasserstein-1 distance between point sets a and b."""
    d = a.shape[1]
    dirs = rng.normal(size=(n_proj, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    qs = np.linspace(0.02, 0.98, 25)
    tot = 0.0
    for u in dirs:
        pa = np.quantile(a @ u, qs)
        pb = np.quantile(b @ u, qs)
        tot += np.abs(pa - pb).mean()
    return tot / n_proj


@dataclasses.dataclass
class DatasetFeatures:
    values: dict[str, float]
    label_freq: np.ndarray      # [U] fraction of vectors carrying each label


_DS_FEATURE_CACHE: dict[int, DatasetFeatures] = {}


def dataset_features(ds: ANNDataset, *, sample: int = 256, k: int = 20,
                     seed: int = 0) -> DatasetFeatures:
    if id(ds) in _DS_FEATURE_CACHE:
        return _DS_FEATURE_CACHE[id(ds)]
    rng = np.random.default_rng(seed)
    n = ds.n
    idx = rng.choice(n, size=min(sample, n), replace=False)
    r = _knn_dists(ds.vectors, ds.vectors[idx], k)
    lid = lid_mle(r)
    rc = r[:, -1] / np.maximum(r[:, 0], 1e-12)

    # label structure
    label_freq = np.zeros(ds.universe, dtype=np.float64)
    sizes = ds.group_size.astype(np.float64)
    for g in range(ds.n_groups):
        for l in lb.unpack_one(ds.group_bitmaps[g]):
            label_freq[l] += sizes[g]
    label_freq /= n
    p = label_freq[label_freq > 0]
    entropy = float(-(p * np.log(p)).sum())
    avg_labels = float(label_freq.sum())

    # distribution factor + correlation ratios over frequent labels
    freq_labels = np.argsort(-label_freq)[:64]
    freq_labels = [int(l) for l in freq_labels if label_freq[l] * n >= 20]
    df_vals, cr_num, cr_norm_num, cr_den = [], 0.0, 0.0, 0.0
    glob_idx = rng.choice(n, size=min(1024, n), replace=False)
    lid_global = float(np.mean(lid))
    for l in freq_labels[:32]:
        word, bit = l >> 5, np.uint32(1) << np.uint32(l & 31)
        mem = np.nonzero((ds.bitmaps[:, word] & bit) != 0)[0]
        if mem.size < 20:
            continue
        sub = ds.vectors[mem[rng.permutation(mem.size)[:256]]]
        df_vals.append(_sliced_w1(sub, ds.vectors[glob_idx], 6, rng))
        r_sub = _knn_dists(sub, sub[: min(64, sub.shape[0])], min(10, sub.shape[0] - 2))
        lid_sub = float(np.mean(lid_mle(r_sub)))
        rnd = ds.vectors[rng.choice(n, size=sub.shape[0], replace=False)]
        r_rnd = _knn_dists(rnd, rnd[: min(64, rnd.shape[0])], min(10, rnd.shape[0] - 2))
        lid_rnd = float(np.mean(lid_mle(r_rnd)))
        w = float(mem.size)
        cr_num += w * lid_sub
        cr_norm_num += w * (lid_sub / max(lid_rnd, 1e-9))
        cr_den += w

    tm_lo, tm_hi = np.quantile(rc, [0.05, 0.95])
    trimmed = rc[(rc >= tm_lo) & (rc <= tm_hi)]
    values = {
        "size": float(n),
        "dim": float(ds.dim),
        "lid_mean": float(np.mean(lid)),
        "lid_median": float(np.median(lid)),
        "lid_std": float(np.std(lid)),
        "rc_median": float(np.median(rc)),
        "rc_trimmed_mean": float(trimmed.mean() if trimmed.size else rc.mean()),
        "rc_p95": float(np.quantile(rc, 0.95)),
        "label_cardinality": float(ds.universe),
        "label_entropy": entropy,
        "n_label_combinations": float(ds.n_groups),
        "avg_labels_per_vector": avg_labels,
        "distribution_factor": float(np.mean(df_vals)) if df_vals else 0.0,
        "correlation_ratio": float(cr_num / cr_den / max(lid_global, 1e-9)) if cr_den else 1.0,
        "normalized_correlation_ratio": float(cr_norm_num / cr_den) if cr_den else 1.0,
    }
    feats = DatasetFeatures(values=values, label_freq=label_freq)
    _DS_FEATURE_CACHE[id(ds)] = feats
    return feats


# ---------------------------------------------------------------------------
# per-query features
# ---------------------------------------------------------------------------

def query_features(ds: ANNDataset, dsf: DatasetFeatures, qbm: np.ndarray,
                   pred: Predicate) -> dict[str, float]:
    labs = sorted(lb.unpack_one(qbm))
    freqs = np.array([dsf.label_freq[l] for l in labs]) if labs else np.zeros(1)
    sel = ds.selectivity(qbm, pred)
    cooc = ds.selectivity(qbm, Predicate.AND)   # containment fraction
    return {
        "n_labels": float(len(labs)),
        "selectivity": float(sel),
        "min_label_freq": float(freqs.min()),
        "max_label_freq": float(freqs.max()),
        "mean_label_freq": float(freqs.mean()),
        "label_cooccurrence": float(cooc),
    }


def feature_matrix(ds: ANNDataset, qbms: np.ndarray, pred: Predicate,
                   feature_names: list[str]) -> np.ndarray:
    """[Q, F(+2 for one-hot pred)] raw feature matrix in `feature_names`
    order; 'pred' expands to a 3-way one-hot."""
    dsf = dataset_features(ds)
    nq = qbms.shape[0]
    cols = []
    qf = [query_features(ds, dsf, qbms[i], pred) for i in range(nq)]
    for name in feature_names:
        if name == "pred":
            oh = np.zeros((nq, 3))
            oh[:, int(Predicate(pred))] = 1.0
            cols.append(oh)
        elif name in QUERY_FEATURES:
            cols.append(np.array([q[name] for q in qf])[:, None])
        else:
            cols.append(np.full((nq, 1), dsf.values[name]))
    return np.concatenate(cols, axis=1).astype(np.float32)
