"""Feature extraction — all 22 candidate features from paper §4.2.

Groups:
  (1) 6 query-aware     — n_labels, selectivity, min/max/mean per-label
                          frequency, label co-occurrence;
  (2) 15 dataset-level  — size, dim, LID mean/median/std, relative-contrast
                          median / 5–95% trimmed mean / p95, label
                          cardinality, label entropy, #unique label
                          combinations, avg labels per vector, distribution
                          factor (mean sliced Wasserstein), correlation
                          ratio, normalized correlation ratio;
  (3) 1 predicate type  — categorical (one-hot in the model input, counted
                          as a single feature as in the paper).

The final minimal set (paper §6.2): ``selectivity, lid_mean, pred``.

Query-aware features are computed **batched**: `feature_matrix` runs one
vectorised pass per feature over the whole query batch (selectivity /
co-occurrence via a single group-table reduction — or the Pallas
`selectivity` kernel over the device-resident bitmap tensor on TPU — and
label-frequency stats via masked reductions over `DatasetFeatures.
label_freq`). `query_features` survives as the scalar per-query reference
implementation used by the parity tests and latency benchmark.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.ann import labels as lb
from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate

QUERY_FEATURES = [
    "n_labels", "selectivity", "min_label_freq", "max_label_freq",
    "mean_label_freq", "label_cooccurrence",
]
DATASET_FEATURES = [
    "size", "dim", "lid_mean", "lid_median", "lid_std",
    "rc_median", "rc_trimmed_mean", "rc_p95",
    "label_cardinality", "label_entropy", "n_label_combinations",
    "avg_labels_per_vector", "distribution_factor",
    "correlation_ratio", "normalized_correlation_ratio",
]
NUMERIC_FEATURES = QUERY_FEATURES + DATASET_FEATURES   # 21 numeric
ALL_FEATURES = NUMERIC_FEATURES + ["pred"]             # + categorical = 22

MINIMAL_FEATURES = ["selectivity", "lid_mean", "pred"]  # paper's final set


# ---------------------------------------------------------------------------
# dataset-level features
# ---------------------------------------------------------------------------

def _knn_dists(vectors: np.ndarray, queries: np.ndarray, k: int) -> np.ndarray:
    """[Q, k] ascending Euclidean distances (self-matches removed)."""
    n2 = (vectors ** 2).sum(1)
    d = n2[None, :] - 2.0 * queries @ vectors.T + (queries ** 2).sum(1)[:, None]
    d = np.maximum(d, 0.0)
    kk = min(k + 1, d.shape[1])
    part = np.partition(d, kk - 1, axis=1)[:, :kk]
    part = np.sort(part, axis=1)
    # drop a zero self-distance column if present
    out = np.where(part[:, :1] < 1e-9, part[:, 1:kk], part[:, :kk - 1]) \
        if kk > 1 else part
    return np.sqrt(out)


def lid_mle(r: np.ndarray) -> np.ndarray:
    """Maximum-likelihood LID per query from ascending kNN distances r [Q,k]
    (paper Eq. 3)."""
    rk = r[:, -1:]
    ratio = np.clip(r / np.maximum(rk, 1e-12), 1e-12, 1.0)
    m = np.mean(np.log(ratio), axis=1)
    return -1.0 / np.minimum(m, -1e-9)


def _sliced_w1(a: np.ndarray, b: np.ndarray, n_proj: int, rng) -> float:
    """Mean sliced Wasserstein-1 distance between point sets a and b."""
    d = a.shape[1]
    dirs = rng.normal(size=(n_proj, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    qs = np.linspace(0.02, 0.98, 25)
    tot = 0.0
    for u in dirs:
        pa = np.quantile(a @ u, qs)
        pb = np.quantile(b @ u, qs)
        tot += np.abs(pa - pb).mean()
    return tot / n_proj


@dataclasses.dataclass
class DatasetFeatures:
    values: dict[str, float]
    label_freq: np.ndarray      # [U] fraction of vectors carrying each label


# Per-dataset features are cached ON the FilteredIndex handle (its
# `_features` slot), not in a module global: feature state shares the
# handle's lifecycle, so `close()` frees it with everything else the
# handle owns. Handle-less callers fall back to a weak per-instance map
# — the features live exactly as long as the dataset object itself, and
# nothing global pins the dataset's arrays (per-instance keys can't
# alias the way metadata keys could). Keyed by id() with a weakref
# cleanup callback (ANNDataset is an eq-dataclass, so not hashable);
# the identity re-check on lookup guards against id reuse.
_FALLBACK_FEATURES: dict = {}   # id(ds) -> (weakref.ref(ds), features)


def _fallback_get(ds):
    hit = _FALLBACK_FEATURES.get(id(ds))
    return hit[1] if hit is not None and hit[0]() is ds else None


def _fallback_put(ds, feats) -> None:
    import weakref

    key = id(ds)
    _FALLBACK_FEATURES[key] = (
        weakref.ref(ds, lambda _: _FALLBACK_FEATURES.pop(key, None)), feats)


def clear_feature_cache() -> None:
    """Evict cached per-dataset features: the handle-less fallback map
    and the pooled default handles. Owned handles drop theirs on
    `FilteredIndex.close()`."""
    from repro.ann.index import _POOL

    _FALLBACK_FEATURES.clear()
    for fx in _POOL.values():
        fx._features = None


def _unpack_bits(qbms: np.ndarray, universe: int) -> np.ndarray:
    """[Q, W] uint32 packed bitmaps -> [Q, universe] bool membership."""
    shifts = np.arange(32, dtype=np.uint32)
    bits = (qbms[:, :, None] >> shifts) & np.uint32(1)   # [Q, W, 32]
    return bits.astype(bool).reshape(qbms.shape[0], -1)[:, :universe]


def dataset_features(ds: ANNDataset, *, sample: int = 256, k: int = 20,
                     seed: int = 0, fx=None) -> DatasetFeatures:
    """All 15 dataset-level features (+ the per-label carrier fractions).

    Args:
        ds: the dataset.
        sample/k/seed: LID/RC estimation knobs (deterministic in seed).
        fx: the caller's owned serving handle for `ds` (`FilteredIndex`
            or `ShardedFilteredIndex`) — the computed features are cached
            on it and freed by its `close()`. Without one, a weak
            per-instance cache holds them for the dataset object's own
            lifetime (nothing pins the dataset's arrays globally).
    Returns: the (cached) `DatasetFeatures`.
    """
    feats = (getattr(fx, "_features", None) if fx is not None
             else _fallback_get(ds))
    if feats is not None:
        return feats
    rng = np.random.default_rng(seed)
    n = ds.n
    idx = rng.choice(n, size=min(sample, n), replace=False)
    r = _knn_dists(ds.vectors, ds.vectors[idx], k)
    lid = lid_mle(r)
    rc = r[:, -1] / np.maximum(r[:, 0], 1e-12)

    # label structure: per-label carrier fraction via one group-table pass
    sizes = ds.group_size.astype(np.float64)
    gbits = _unpack_bits(ds.group_bitmaps, ds.universe)  # [G, U]
    label_freq = (sizes[:, None] * gbits).sum(0) / n
    p = label_freq[label_freq > 0]
    entropy = float(-(p * np.log(p)).sum())
    avg_labels = float(label_freq.sum())

    # distribution factor + correlation ratios over frequent labels
    freq_labels = np.argsort(-label_freq)[:64]
    freq_labels = [int(l) for l in freq_labels if label_freq[l] * n >= 20]
    df_vals, cr_num, cr_norm_num, cr_den = [], 0.0, 0.0, 0.0
    glob_idx = rng.choice(n, size=min(1024, n), replace=False)
    lid_global = float(np.mean(lid))
    for l in freq_labels[:32]:
        word, bit = l >> 5, np.uint32(1) << np.uint32(l & 31)
        mem = np.nonzero((ds.bitmaps[:, word] & bit) != 0)[0]
        if mem.size < 20:
            continue
        sub = ds.vectors[mem[rng.permutation(mem.size)[:256]]]
        df_vals.append(_sliced_w1(sub, ds.vectors[glob_idx], 6, rng))
        r_sub = _knn_dists(sub, sub[: min(64, sub.shape[0])], min(10, sub.shape[0] - 2))
        lid_sub = float(np.mean(lid_mle(r_sub)))
        rnd = ds.vectors[rng.choice(n, size=sub.shape[0], replace=False)]
        r_rnd = _knn_dists(rnd, rnd[: min(64, rnd.shape[0])], min(10, rnd.shape[0] - 2))
        lid_rnd = float(np.mean(lid_mle(r_rnd)))
        w = float(mem.size)
        cr_num += w * lid_sub
        cr_norm_num += w * (lid_sub / max(lid_rnd, 1e-9))
        cr_den += w
    tm_lo, tm_hi = np.quantile(rc, [0.05, 0.95])
    trimmed = rc[(rc >= tm_lo) & (rc <= tm_hi)]
    values = {
        "size": float(n),
        "dim": float(ds.dim),
        "lid_mean": float(np.mean(lid)),
        "lid_median": float(np.median(lid)),
        "lid_std": float(np.std(lid)),
        "rc_median": float(np.median(rc)),
        "rc_trimmed_mean": float(trimmed.mean() if trimmed.size else rc.mean()),
        "rc_p95": float(np.quantile(rc, 0.95)),
        "label_cardinality": float(ds.universe),
        "label_entropy": entropy,
        "n_label_combinations": float(ds.n_groups),
        "avg_labels_per_vector": avg_labels,
        "distribution_factor": float(np.mean(df_vals)) if df_vals else 0.0,
        "correlation_ratio": float(cr_num / cr_den / max(lid_global, 1e-9)) if cr_den else 1.0,
        "normalized_correlation_ratio": float(cr_norm_num / cr_den) if cr_den else 1.0,
    }
    feats = DatasetFeatures(values=values, label_freq=label_freq)
    if fx is None:
        _fallback_put(ds, feats)
    elif not getattr(fx, "closed", False):  # never resurrect closed state
        fx._features = feats
    return feats


# ---------------------------------------------------------------------------
# per-query features — batched fast path + scalar reference
# ---------------------------------------------------------------------------

_LIVE_UNKNOWN = object()   # "look it up" sentinel for the live= kwargs


def _live_of(fx):
    """The handle's `LiveStats`, when `fx` is a live index (duck-typed:
    anything exposing `live_stats()` — `LiveFilteredIndex` /
    `ShardedLiveIndex`). None for sealed handles."""
    get = getattr(fx, "live_stats", None)
    return get() if callable(get) else None


def _match_counts(qbms: np.ndarray, bitmaps: np.ndarray,
                  pred: Predicate) -> np.ndarray:
    """[Q] exact predicate match counts of each query against a small
    row set (word-looped, unweighted) — the live-correction workhorse."""
    pred = Predicate(pred)
    q, w = qbms.shape
    n = bitmaps.shape[0]
    if pred == Predicate.EQUALITY:
        ok = np.ones((q, n), dtype=bool)
        for i in range(w):
            ok &= bitmaps[None, :, i] == qbms[:, i, None]
    elif pred == Predicate.OR:
        ok = np.zeros((q, n), dtype=bool)
        for i in range(w):
            ok |= (bitmaps[None, :, i] & qbms[:, i, None]) != 0
    else:                                       # AND
        ok = np.ones((q, n), dtype=bool)
        for i in range(w):
            qw = qbms[:, i, None]
            ok &= (bitmaps[None, :, i] & qw) == qw
    return ok.sum(1).astype(np.float64)


def batch_selectivity(ds: ANNDataset, qbms: np.ndarray,
                      pred: Predicate, *, fx=None,
                      live=_LIVE_UNKNOWN) -> np.ndarray:
    """[Q] predicate selectivity fractions for a whole query batch.

    On TPU this is one Pallas `selectivity` kernel call over the
    device-resident [N, W] bitmap tensor; on other backends one word-looped
    group-table reduction (G ≪ N rows, weighted by group size) — both are
    exact, and both replace the Q independent host scans of the old
    per-query path.

    `fx`: the caller's owned `FilteredIndex` for `ds`, when it has one —
    otherwise the TPU path falls back to the shared default pool (which
    would pin a *second* copy of the device tensors if an owned handle
    already exists). When `fx` is a **live** handle, the base counts are
    corrected exactly to the live set: matches on tombstoned base rows
    are subtracted, matches on live delta rows added, and the fraction
    is taken over the live row count — so routing never sees the stale
    sealed-base selectivity as the delta grows. Callers that already
    hold a `LiveStats` pass it via `live=` (one consistent snapshot per
    feature pass); `live=None` forces the sealed path.
    """
    if live is _LIVE_UNKNOWN:
        live = _live_of(fx)
    if live is None:
        return _base_selectivity(ds, qbms, pred, fx=fx)
    # count base matches against the *snapshot's* base (LiveStats.base_ds)
    # rather than the caller's `ds`: a compaction racing this pass would
    # otherwise pair generation-g tombstone corrections with a
    # generation-g+1 base. (The TPU kernel path still reads the handle's
    # current device tensors; the CPU group-table path is fully
    # consistent, and a post-compact base has its tombstones folded in,
    # so the one-batch skew on TPU is bounded by the delta size.)
    base_ds = live.base_ds
    if base_ds is None or base_ds.n == 0:
        counts = np.zeros(qbms.shape[0], dtype=np.float64)
    else:
        counts = _base_selectivity(base_ds, qbms, pred, fx=fx) * base_ds.n
    if live.base_tomb_bitmaps.shape[0]:
        counts = counts - _match_counts(qbms, live.base_tomb_bitmaps, pred)
    if live.delta_bitmaps.shape[0]:
        counts = counts + _match_counts(qbms, live.delta_bitmaps, pred)
    return np.maximum(counts, 0.0) / max(live.n_live, 1)


def _base_selectivity(ds: ANNDataset, qbms: np.ndarray,
                      pred: Predicate, *, fx=None) -> np.ndarray:
    """Sealed-base selectivity fractions (over `ds.n`); see
    `batch_selectivity` for the serving-facing wrapper."""
    import jax

    pred = Predicate(pred)
    if jax.default_backend() == "tpu":
        import jax.numpy as jnp

        from repro.ann.index import default_index
        from repro.kernels import ops

        # qbms is per-request: upload directly (the handle's as_device
        # cache would pin every batch forever)
        counts = ops.selectivity(jnp.asarray(qbms),
                                 (fx or default_index(ds)).device.bitmaps,
                                 pred=int(pred))
        return np.asarray(counts).astype(np.float64) / ds.n

    # queries repeat label sets heavily (they are drawn from base vectors):
    # evaluate unique bitmaps once and scatter the results back
    uq, inv = np.unique(qbms, axis=0, return_inverse=True)
    if uq.shape[0] < qbms.shape[0]:
        return _base_selectivity(ds, uq, pred, fx=fx)[inv]

    gb = ds.group_bitmaps                       # [G, W]
    q, w = qbms.shape
    g = gb.shape[0]
    if pred == Predicate.EQUALITY:
        if g == 0:
            return np.zeros(q, dtype=np.float64)
        # exact-match selectivity: each query matches at most one (unique)
        # group bitmap — a hashed searchsorted probe beats the [Q, G]
        # compare by a factor of W
        mults = np.random.default_rng(0x9E3779B9).integers(
            1, 2 ** 63, size=w, dtype=np.uint64) * 2 + 1
        gh = (gb.astype(np.uint64) * mults[None, :]).sum(1, dtype=np.uint64)
        order = np.argsort(gh, kind="stable")
        ghs = gh[order]
        if not (ghs[1:] == ghs[:-1]).any():
            qh = (qbms.astype(np.uint64) * mults[None, :]).sum(
                1, dtype=np.uint64)
            cand = order[np.clip(np.searchsorted(ghs, qh), 0, g - 1)]
            hit = (gh[cand] == qh) & (gb[cand] == qbms).all(1)
            counts = np.where(hit, ds.group_size[cand], 0)
            return counts.astype(np.float64) / ds.n
        # hash collision between two distinct groups (vanishingly rare):
        # fall back to the word-looped full compare
        ok = np.ones((q, g), dtype=bool)
        for i in range(w):
            ok &= gb[None, :, i] == qbms[:, i, None]
    elif pred == Predicate.OR:
        ok = np.zeros((q, g), dtype=bool)
        for i in range(w):
            ok |= (gb[None, :, i] & qbms[:, i, None]) != 0
    else:                                       # AND
        ok = np.ones((q, g), dtype=bool)
        for i in range(w):
            qw = qbms[:, i, None]
            ok &= (gb[None, :, i] & qw) == qw
    return (ok @ ds.group_size.astype(np.float64)) / ds.n


def query_feature_arrays(ds: ANNDataset, dsf: DatasetFeatures,
                         qbms: np.ndarray, pred: Predicate, *,
                         fx=None, live=_LIVE_UNKNOWN) -> dict:
    """All 6 query-aware features for a whole batch: name -> [Q] float64.

    Numerically identical to Q calls of `query_features` (asserted by
    tests/test_features.py) but fully vectorised. For a live handle the
    per-label frequencies come from the live counts (`fx.live_stats()`)
    instead of the sealed-base `dsf.label_freq`; the same `LiveStats`
    snapshot feeds every column (pass `live=` to share one with the
    caller).
    """
    bits = _unpack_bits(qbms, ds.universe)                 # [Q, U] bool
    nl = bits.sum(1)
    if live is _LIVE_UNKNOWN:
        live = _live_of(fx)
    lf = (dsf.label_freq if live is None else live.label_freq)[None, :]
    has = nl > 0
    minf = np.where(has, np.min(np.where(bits, lf, np.inf), axis=1), 0.0)
    maxf = np.where(has, np.max(np.where(bits, lf, -np.inf), axis=1), 0.0)
    meanf = np.where(has, (bits * lf).sum(1) / np.maximum(nl, 1), 0.0)
    sel = batch_selectivity(ds, qbms, pred, fx=fx, live=live)
    cooc = sel if Predicate(pred) == Predicate.AND \
        else batch_selectivity(ds, qbms, Predicate.AND, fx=fx, live=live)
    return {
        "n_labels": nl.astype(np.float64),
        "selectivity": sel,
        "min_label_freq": minf,
        "max_label_freq": maxf,
        "mean_label_freq": meanf,
        "label_cooccurrence": cooc,
    }


def query_features(ds: ANNDataset, dsf: DatasetFeatures, qbm: np.ndarray,
                   pred: Predicate) -> dict[str, float]:
    """Scalar per-query reference (one host scan per feature)."""
    labs = sorted(lb.unpack_one(qbm))
    freqs = np.array([dsf.label_freq[l] for l in labs]) if labs else np.zeros(1)
    sel = ds.selectivity(qbm, pred)
    cooc = ds.selectivity(qbm, Predicate.AND)   # containment fraction
    return {
        "n_labels": float(len(labs)),
        "selectivity": float(sel),
        "min_label_freq": float(freqs.min()),
        "max_label_freq": float(freqs.max()),
        "mean_label_freq": float(freqs.mean()),
        "label_cooccurrence": float(cooc),
    }


def feature_matrix(ds: ANNDataset, qbms: np.ndarray, pred: Predicate,
                   feature_names: list[str], *, fx=None) -> np.ndarray:
    """[Q, F(+2 for one-hot pred)] raw feature matrix in `feature_names`
    order; 'pred' expands to a 3-way one-hot. Query-aware columns come from
    the batched `query_feature_arrays` pass — no per-query Python loop.
    `fx`: optional owned `FilteredIndex` (see `batch_selectivity`; also
    holds the dataset-feature cache). A live handle additionally corrects
    the selectivity/label-frequency columns and the `size` feature to the
    live row set."""
    dsf = dataset_features(ds, fx=fx)
    nq = qbms.shape[0]
    live = _live_of(fx)        # one consistent snapshot per feature pass
    qf = query_feature_arrays(ds, dsf, qbms, pred, fx=fx, live=live) \
        if any(n in QUERY_FEATURES for n in feature_names) else {}
    cols = []
    for name in feature_names:
        if name == "pred":
            oh = np.zeros((nq, 3))
            oh[:, int(Predicate(pred))] = 1.0
            cols.append(oh)
        elif name in QUERY_FEATURES:
            cols.append(np.asarray(qf[name], dtype=np.float64)[:, None])
        else:
            val = dsf.values[name]
            if live is not None and name == "size":
                val = float(live.n_live)
            cols.append(np.full((nq, 1), val))
    return np.concatenate(cols, axis=1).astype(np.float32)
