"""MLP-Reg — the paper's router model (§4.3): per-candidate-method 2-hidden-
layer (64, 32) ReLU MLP regressors trained with MSE + Adam, plus the MLP
*classifier* variant used by the §6.2(b) ablation. Pure JAX."""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamConfig, adam_init, adam_update


@dataclasses.dataclass
class Scaler:
    mean: np.ndarray
    std: np.ndarray

    @staticmethod
    def fit(x: np.ndarray) -> "Scaler":
        return Scaler(mean=x.mean(0), std=x.std(0) + 1e-8)

    def transform(self, x: np.ndarray) -> np.ndarray:
        return ((x - self.mean) / self.std).astype(np.float32)


def init_mlp(sizes: tuple[int, ...], key) -> list:
    params = []
    for din, dout in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (din, dout)) * jnp.sqrt(2.0 / din)
        params.append({"w": w, "b": jnp.zeros((dout,))})
    return params


def forward(params: list, x: jax.Array) -> jax.Array:
    h = x
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    out = h @ params[-1]["w"] + params[-1]["b"]
    return out


def _mse_loss(params, x, y):
    pred = forward(params, x)[:, 0]
    return jnp.mean((pred - y) ** 2)


def _ce_loss(params, x, y):
    logits = forward(params, x)
    return -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), y[:, None], axis=1))


@partial(jax.jit, static_argnames=("cfg", "classification"))
def _train_epoch(params, opt, xb, yb, cfg, classification):
    loss_fn = _ce_loss if classification else _mse_loss

    def step(carry, batch):
        params, opt = carry
        x, y = batch
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, opt = adam_update(grads, opt, params, cfg)
        return (params, opt), loss

    (params, opt), losses = jax.lax.scan(step, (params, opt), (xb, yb))
    return params, opt, losses.mean()


def train_mlp(x: np.ndarray, y: np.ndarray, *, hidden=(64, 32),
              n_out: int = 1, classification: bool = False,
              epochs: int = 200, batch: int = 256, lr: float = 1e-3,
              seed: int = 0):
    """Returns trained params (list of layer dicts). y: [N] float (reg) or
    [N] int class labels (cls)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    sizes = (x.shape[1],) + tuple(hidden) + (n_out,)
    params = init_mlp(sizes, key)
    cfg = AdamConfig(lr=lr)
    opt = adam_init(params, cfg)
    n = x.shape[0]
    batch = min(batch, n)
    steps = n // batch
    x = x.astype(np.float32)
    y = y.astype(np.int32 if classification else np.float32)
    for _ in range(epochs):
        perm = rng.permutation(n)[: steps * batch]
        xb = jnp.asarray(x[perm].reshape(steps, batch, -1))
        yb = jnp.asarray(y[perm].reshape(steps, batch, *y.shape[1:]))
        params, opt, _ = _train_epoch(params, opt, xb, yb, cfg, classification)
    return params


@jax.jit
def predict(params: list, x: jax.Array) -> jax.Array:
    return forward(params, x)


def stack_params(models: list) -> list:
    """Stack M structurally identical parameter pytrees into one pytree
    whose leaves carry a leading [M] axis (router batching: one vmapped
    forward serves all per-method models)."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *models)


@jax.jit
def forward_stacked(stacked: list, x: jax.Array) -> jax.Array:
    """One fused forward for all M stacked models: [M, Q, n_out]."""
    return jax.vmap(forward, in_axes=(0, None))(stacked, x)


def forward_np(params: list, x: np.ndarray) -> np.ndarray:
    """Pure-numpy inference twin of `forward` — per-query routing runs in
    single-digit µs (no device dispatch), which is what makes the router's
    latency overhead negligible (§6.3). `params` are numpy layer dicts."""
    h = x
    for layer in params[:-1]:
        h = np.maximum(h @ layer["w"] + layer["b"], 0.0)
    return h @ params[-1]["w"] + params[-1]["b"]


def params_to_numpy(params: list) -> list:
    return [{k: np.asarray(v) for k, v in l.items()} for l in params]


def params_from_numpy(params: list) -> list:
    return [{k: jnp.asarray(v) for k, v in l.items()} for l in params]
