"""RuleRouter — paper Algorithm 1, verbatim decision tree over
(predicate type, LID_mean, card(V)).

The paper's thresholds (LID_mean > 100, card(V) < 100) were calibrated on
full-scale embeddings; our scaled synthetic pool spans a smaller LID range,
so the thresholds are constructor parameters with defaults chosen to
separate the same datasets the paper's thresholds separate (ytb_video is
the high-LID outlier; LAION/tripclick are the low-cardinality ones). The
*structure* of the tree is unchanged.
"""

from __future__ import annotations

import dataclasses

from repro.ann.predicates import Predicate


@dataclasses.dataclass(frozen=True)
class RuleRouter:
    lid_hi: float = 40.0      # paper: 100 (full-scale embeddings)
    card_lo: float = 100.0    # paper: 100

    def route(self, pred: Predicate, lid_mean: float, card: float) -> str:
        pred = Predicate(pred)
        if pred == Predicate.EQUALITY:
            return "labelnav"                      # UNG
        if pred == Predicate.AND:
            if lid_mean > self.lid_hi or card < self.card_lo:
                return "labelnav"                  # UNG
            return "sieve"                         # SIEVE
        # OR
        if lid_mean > self.lid_hi:
            return "labelnav"                      # UNG
        return "postfilter"                        # Post-filter
