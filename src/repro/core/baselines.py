"""Model-family baselines for the §6.2(b) classification-vs-regression
ablation: {LogisticReg, MLP, RandomForest} classifiers and
{Ridge, MLP-Reg, RF-Reg} regressors, all sharing features and labels."""

from __future__ import annotations

import numpy as np

from repro.core import mlp
from repro.core.forest import RandomForest


# ---- regressors -------------------------------------------------------------

def ridge_fit(x: np.ndarray, y: np.ndarray, lam: float = 1e-2):
    xb = np.concatenate([x, np.ones((x.shape[0], 1))], axis=1)
    a = xb.T @ xb + lam * np.eye(xb.shape[1])
    w = np.linalg.solve(a, xb.T @ y)
    return w


def ridge_predict(w: np.ndarray, x: np.ndarray) -> np.ndarray:
    xb = np.concatenate([x, np.ones((x.shape[0], 1))], axis=1)
    return xb @ w


class PerMethodRegressor:
    """Wraps any per-method scalar regressor into a [Q, M] recall predictor."""

    def __init__(self, kind: str, seed: int = 0):
        self.kind = kind
        self.seed = seed
        self.models = []

    def fit(self, x: np.ndarray, y: np.ndarray) -> "PerMethodRegressor":
        m = y.shape[1]
        self.models = []
        for j in range(m):
            if self.kind == "ridge":
                self.models.append(ridge_fit(x, y[:, j]))
            elif self.kind == "mlp":
                self.models.append(mlp.train_mlp(
                    x, y[:, j], hidden=(64, 32), seed=self.seed + j))
            elif self.kind == "rf":
                self.models.append(RandomForest(
                    n_trees=20, max_depth=8, seed=self.seed + j).fit(x, y[:, j]))
            else:
                raise ValueError(self.kind)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        cols = []
        for mdl in self.models:
            if self.kind == "ridge":
                cols.append(ridge_predict(mdl, x))
            elif self.kind == "mlp":
                cols.append(mlp.forward_np(mlp.params_to_numpy(mdl), x)[:, 0])
            else:
                cols.append(mdl.predict(x))
        return np.stack(cols, axis=1)


# ---- classifiers ------------------------------------------------------------

class BestMethodClassifier:
    """Predicts the argmax-recall method directly (top-1 label)."""

    def __init__(self, kind: str, n_classes: int, seed: int = 0):
        self.kind = kind
        self.n_classes = n_classes
        self.seed = seed
        self.model = None

    def fit(self, x: np.ndarray, y_best: np.ndarray) -> "BestMethodClassifier":
        if self.kind == "logistic":
            self.model = mlp.train_mlp(x, y_best, hidden=(),
                                       n_out=self.n_classes,
                                       classification=True, seed=self.seed)
        elif self.kind == "mlp":
            self.model = mlp.train_mlp(x, y_best, hidden=(64, 32),
                                       n_out=self.n_classes,
                                       classification=True, seed=self.seed)
        elif self.kind == "rf":
            onehot = np.eye(self.n_classes, dtype=np.float32)[y_best]
            self.model = RandomForest(n_trees=20, max_depth=8,
                                      seed=self.seed).fit(x, onehot)
        else:
            raise ValueError(self.kind)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        if self.kind in ("logistic", "mlp"):
            logits = np.asarray(mlp.predict(self.model, x))
            return logits.argmax(1)
        return self.model.predict(x).argmax(1)
