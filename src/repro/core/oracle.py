"""Oracle router (paper §6.3): per query, the method achieving the highest
actual recall — the theoretical upper bound the ML router chases."""

from __future__ import annotations

import numpy as np

from repro.core.training import Collection, METHOD_ORDER


def oracle_recall(coll: Collection, ds: str, pt: int,
                  methods=METHOD_ORDER) -> np.ndarray:
    cell = coll.cells[(ds, int(pt))]
    stacked = np.stack([cell.recall[m] for m in methods], axis=1)   # [Q, M]
    return stacked.max(axis=1)


def oracle_choice(coll: Collection, ds: str, pt: int,
                  methods=METHOD_ORDER) -> np.ndarray:
    cell = coll.cells[(ds, int(pt))]
    stacked = np.stack([cell.recall[m] for m in methods], axis=1)
    return stacked.argmax(axis=1)
