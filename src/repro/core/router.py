"""ML Router — paper §4 / Algorithm 2, batched end to end.

Batch pipeline (no per-query Python loop anywhere on the hot path):
features for the whole query batch come from one vectorised
`features.feature_matrix` pass (selectivity/co-occurrence via a single
group-table reduction, or the Pallas selectivity kernel on TPU); the M
per-method MLP-Reg models are stacked into a single [M, ...] parameter
pytree and evaluated with one jitted vmapped forward; Algorithm 2
(threshold filter `r̂_m ≥ T` → max-QPS passing method from the offline
benchmark table B → argmax-r̂ fallback) runs as numpy array ops over
precomputed per-method `(ps_id, qps)` tables from
`BenchmarkTable.routing_arrays`.

TPU-idiomatic addition (DESIGN.md §3): batched group dispatch — route a
*batch* of queries with one fused forward, then execute each chosen
(method, ps) group as a single batched search. That dispatch lives in
`repro.ann.service.RouterService`. Persistence is a versioned artifact
directory (`router.json` manifest + `weights.npz` + `table.json`); the
pre-artifact pickle format is no longer loadable — re-save old routers
with `MLRouter.save(dir)` from a checkout that still reads them.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate
from repro.core import features as F
from repro.core import mlp
from repro.core.table import BenchmarkTable

# versioned router artifact directory (router.json manifest + npz weights
# + benchmark table); MLRouter.load also reads the legacy pickle format.
ARTIFACT_FORMAT = "repro.router"
ARTIFACT_VERSION = 1
_MANIFEST = "router.json"
_WEIGHTS = "weights.npz"
_TABLE = "table.json"


def artifact_versions(path: str) -> dict:
    """Version + content stamps of a router artifact directory, without
    loading it: ``{"router_version": int, "table_version": int,
    "content_sha1": str}``.

    The format versions catch an artifact written by a different code
    era; the content digest (sha1 over the manifest, weights and table
    bytes) catches an artifact that was re-trained or swapped in place
    — same format, different router. `repro.ann.store.IndexStore`
    records all three at link time and re-validates the triple on every
    `open()`, so an index can never silently serve through a router or
    benchmark table that changed under it. Raises ValueError if `path`
    is not a router artifact directory.
    """
    import hashlib

    from repro.ann.dataset import sha1_file
    from repro.core.table import table_file_version

    manifest_path = os.path.join(path, _MANIFEST)
    if not os.path.isdir(path) or not os.path.exists(manifest_path):
        raise ValueError(
            f"{path!r} is not a router artifact directory (no {_MANIFEST})")
    with open(manifest_path) as f:
        manifest = json.load(f)
    if manifest.get("format") != ARTIFACT_FORMAT:
        raise ValueError(
            f"{path!r} is not a {ARTIFACT_FORMAT} artifact "
            f"(format={manifest.get('format')!r})")
    table_path = os.path.join(path, manifest.get("table", _TABLE))
    if not os.path.exists(table_path):
        raise ValueError(
            f"router artifact {path!r} is missing its benchmark table "
            f"file {os.path.basename(table_path)!r}")
    # combined digest of per-file chunked hashes (constant memory)
    h = hashlib.sha1()
    for fname in (_MANIFEST, manifest.get("weights", _WEIGHTS),
                  manifest.get("table", _TABLE)):
        fpath = os.path.join(path, fname)
        if os.path.exists(fpath):
            h.update(sha1_file(fpath).encode())
    return {
        "router_version": int(manifest.get("version", -1)),
        "table_version": table_file_version(table_path),
        "content_sha1": h.hexdigest(),
    }


@dataclasses.dataclass
class MLRouter:
    feature_names: list            # e.g. F.MINIMAL_FEATURES
    methods: list                  # candidate method names, fixed order
    models: dict                   # method -> MLP params (numpy)
    scaler: mlp.Scaler
    table: BenchmarkTable
    _stacked: object = dataclasses.field(default=None, init=False,
                                         repr=False, compare=False)

    # ---- prediction -----------------------------------------------------
    def predict_recalls(self, ds: ANNDataset, qbms: np.ndarray,
                        pred: Predicate, *, fx=None) -> np.ndarray:
        """[Q, M] predicted recall@10 per candidate method (one vectorised
        feature pass + one stacked-MLP forward for the whole batch).
        `fx`: the caller's owned `FilteredIndex`, so the TPU feature
        kernel reuses its device tensors instead of the default pool."""
        x = F.feature_matrix(ds, qbms, pred, self.feature_names, fx=fx)
        return self.predict_recalls_from_features(x)

    def retrained(self, models: dict, scaler: "mlp.Scaler",
                  table: BenchmarkTable | None = None) -> "MLRouter":
        """Fresh router with new weights but this router's feature set
        and method order (the online adapter's retrain constructor —
        a new instance so the serving swap is one reference assignment
        and the stacked-params cache starts cold)."""
        return MLRouter(feature_names=list(self.feature_names),
                        methods=list(self.methods), models=models,
                        scaler=scaler,
                        table=self.table if table is None else table)

    def stacked_params(self):
        """All M per-method models as one [M, ...]-leaved pytree (cached)."""
        if self._stacked is None:
            self._stacked = mlp.stack_params(
                [mlp.params_from_numpy(self.models[m]) for m in self.methods])
        return self._stacked

    def predict_recalls_from_features(self, x_raw: np.ndarray) -> np.ndarray:
        xs = self.scaler.transform(x_raw)
        out = mlp.forward_stacked(self.stacked_params(), xs)   # [M, Q, 1]
        return np.asarray(out[:, :, 0]).T.astype(np.float32)   # [Q, M]

    # ---- Algorithm 2 ------------------------------------------------------
    def route_from_predictions(self, r_hat: np.ndarray, ds_name: str,
                               pred: Predicate, t: float):
        """Vectorised Algorithm 2. Returns list of (method, ps_id) per query.

        Selection is pure array ops over the per-method routing tables:
        argmax of QPS masked to passing methods, argmax-r̂ fallback rows
        where nothing passes.
        """
        pt = int(Predicate(pred))
        has_pass, qps, ps_pass, ps_fallback = self.table.routing_arrays(
            ds_name, pt, self.methods, t)
        r = np.asarray(r_hat, dtype=np.float64)
        passing = (r >= t) & has_pass[None, :]                 # [Q, M]
        any_pass = passing.any(axis=1)
        # argmax picks the first maximal index, matching the scalar loop's
        # max()-in-method-order tie-breaking
        j_pass = np.argmax(np.where(passing, qps[None, :], -np.inf), axis=1)
        j_fb = np.argmax(r, axis=1)
        j_star = np.where(any_pass, j_pass, j_fb)
        ps_sel = np.where(any_pass, ps_pass[j_star], ps_fallback[j_star])
        names = np.array(self.methods, dtype=object)[j_star]
        return list(zip(names.tolist(), ps_sel.tolist()))

    def route_from_predictions_loop(self, r_hat: np.ndarray, ds_name: str,
                                    pred: Predicate, t: float):
        """Scalar per-query Algorithm 2 (the seed implementation) — the
        parity oracle for `route_from_predictions`, shared by the tests
        and the routing-latency benchmark. Not a hot path."""
        pt = int(Predicate(pred))
        ps_of, qps_of = {}, {}
        for m in self.methods:
            hit = self.table.best_qps_setting(ds_name, pt, m, t)
            if hit is not None:
                ps_of[m], qps_of[m] = hit[0], hit[1]["qps"]
        decisions = []
        for qi in range(r_hat.shape[0]):
            passing = [m for j, m in enumerate(self.methods)
                       if r_hat[qi, j] >= t and m in ps_of]
            if passing:
                m_star = max(passing, key=lambda m: qps_of[m])
                decisions.append((m_star, ps_of[m_star]))
            else:  # fallback: argmax predicted recall, max-recall setting
                m_star = self.methods[int(np.argmax(r_hat[qi]))]
                hit = self.table.best_qps_setting(ds_name, pt, m_star, t) \
                    or self.table.max_recall_setting(ds_name, pt, m_star)
                decisions.append((m_star, hit[0] if hit else None))
        return decisions

    def route(self, ds: ANNDataset, qbms: np.ndarray, pred: Predicate,
              t: float):
        r_hat = self.predict_recalls(ds, qbms, pred)
        return self.route_from_predictions(r_hat, ds.name, pred, t)

    # ---- persistence ----
    def save(self, path: str) -> None:
        """Write the versioned artifact directory at `path`:

            path/router.json   — manifest (format, version, features,
                                 method order, layer counts)
            path/weights.npz   — per-method MLP layers + scaler
            path/table.json    — offline benchmark table B
        """
        if os.path.isfile(path):
            raise ValueError(
                f"router artifact path {path!r} is an existing file; the "
                f"versioned artifact is a directory")
        os.makedirs(path, exist_ok=True)
        arrays = {"scaler/mean": np.asarray(self.scaler.mean),
                  "scaler/std": np.asarray(self.scaler.std)}
        n_layers = {}
        for m in self.methods:
            layers = self.models[m]
            n_layers[m] = len(layers)
            for i, layer in enumerate(layers):
                arrays[f"model/{m}/{i}/w"] = np.asarray(layer["w"])
                arrays[f"model/{m}/{i}/b"] = np.asarray(layer["b"])
        np.savez(os.path.join(path, _WEIGHTS), **arrays)
        self.table.save(os.path.join(path, _TABLE))
        manifest = {
            "format": ARTIFACT_FORMAT,
            "version": ARTIFACT_VERSION,
            "feature_names": list(self.feature_names),
            "methods": list(self.methods),
            "n_layers": n_layers,
            "weights": _WEIGHTS,
            "table": _TABLE,
        }
        with open(os.path.join(path, _MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)

    @staticmethod
    def load(path: str) -> "MLRouter":
        """Load a versioned router artifact directory.

        Raises ValueError for anything that is not an artifact directory
        — including the pre-artifact pickle files, whose loader was
        removed after its one-PR-cycle deprecation window."""
        if not os.path.isdir(path):
            raise ValueError(
                f"{path!r} is not a router artifact directory; the legacy "
                f"pickle format is no longer supported — re-save it with "
                f"MLRouter.save(dir)")
        return MLRouter._load_artifact(path)

    @staticmethod
    def _load_artifact(path: str) -> "MLRouter":
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        if manifest.get("format") != ARTIFACT_FORMAT:
            raise ValueError(
                f"{path!r} is not a {ARTIFACT_FORMAT} artifact "
                f"(format={manifest.get('format')!r})")
        if int(manifest.get("version", -1)) > ARTIFACT_VERSION:
            raise ValueError(
                f"router artifact version {manifest['version']} is newer "
                f"than supported version {ARTIFACT_VERSION}")
        with np.load(os.path.join(path, manifest["weights"])) as z:
            scaler = mlp.Scaler(z["scaler/mean"].copy(),
                                z["scaler/std"].copy())
            models = {}
            for m in manifest["methods"]:
                models[m] = [
                    {"w": z[f"model/{m}/{i}/w"].copy(),
                     "b": z[f"model/{m}/{i}/b"].copy()}
                    for i in range(int(manifest["n_layers"][m]))]
        table = BenchmarkTable.load(os.path.join(path, manifest["table"]))
        return MLRouter(feature_names=list(manifest["feature_names"]),
                        methods=list(manifest["methods"]),
                        models=models, scaler=scaler, table=table)
