"""ML Router — paper §4 / Algorithm 2, batched end to end.

Batch pipeline (no per-query Python loop anywhere on the hot path):
features for the whole query batch come from one vectorised
`features.feature_matrix` pass (selectivity/co-occurrence via a single
group-table reduction, or the Pallas selectivity kernel on TPU); the M
per-method MLP-Reg models are stacked into a single [M, ...] parameter
pytree and evaluated with one jitted vmapped forward; Algorithm 2
(threshold filter `r̂_m ≥ T` → max-QPS passing method from the offline
benchmark table B → argmax-r̂ fallback) runs as numpy array ops over
precomputed per-method `(ps_id, qps)` tables from
`BenchmarkTable.routing_arrays`.

TPU-idiomatic addition (DESIGN.md §3): `route_and_search` routes a *batch*
of queries with one fused forward, then groups queries by chosen
(method, ps) and executes each group as a single batched search.
"""

from __future__ import annotations

import dataclasses
import pickle

import numpy as np

from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate
from repro.core import features as F
from repro.core import mlp
from repro.core.table import BenchmarkTable


@dataclasses.dataclass
class MLRouter:
    feature_names: list            # e.g. F.MINIMAL_FEATURES
    methods: list                  # candidate method names, fixed order
    models: dict                   # method -> MLP params (numpy)
    scaler: mlp.Scaler
    table: BenchmarkTable
    _stacked: object = dataclasses.field(default=None, init=False,
                                         repr=False, compare=False)

    # ---- prediction -----------------------------------------------------
    def predict_recalls(self, ds: ANNDataset, qbms: np.ndarray,
                        pred: Predicate) -> np.ndarray:
        """[Q, M] predicted recall@10 per candidate method (one vectorised
        feature pass + one stacked-MLP forward for the whole batch)."""
        x = F.feature_matrix(ds, qbms, pred, self.feature_names)
        return self.predict_recalls_from_features(x)

    def stacked_params(self):
        """All M per-method models as one [M, ...]-leaved pytree (cached)."""
        if self._stacked is None:
            self._stacked = mlp.stack_params(
                [mlp.params_from_numpy(self.models[m]) for m in self.methods])
        return self._stacked

    def predict_recalls_from_features(self, x_raw: np.ndarray) -> np.ndarray:
        xs = self.scaler.transform(x_raw)
        out = mlp.forward_stacked(self.stacked_params(), xs)   # [M, Q, 1]
        return np.asarray(out[:, :, 0]).T.astype(np.float32)   # [Q, M]

    # ---- Algorithm 2 ------------------------------------------------------
    def route_from_predictions(self, r_hat: np.ndarray, ds_name: str,
                               pred: Predicate, t: float):
        """Vectorised Algorithm 2. Returns list of (method, ps_id) per query.

        Selection is pure array ops over the per-method routing tables:
        argmax of QPS masked to passing methods, argmax-r̂ fallback rows
        where nothing passes.
        """
        pt = int(Predicate(pred))
        has_pass, qps, ps_pass, ps_fallback = self.table.routing_arrays(
            ds_name, pt, self.methods, t)
        r = np.asarray(r_hat, dtype=np.float64)
        passing = (r >= t) & has_pass[None, :]                 # [Q, M]
        any_pass = passing.any(axis=1)
        # argmax picks the first maximal index, matching the scalar loop's
        # max()-in-method-order tie-breaking
        j_pass = np.argmax(np.where(passing, qps[None, :], -np.inf), axis=1)
        j_fb = np.argmax(r, axis=1)
        j_star = np.where(any_pass, j_pass, j_fb)
        ps_sel = np.where(any_pass, ps_pass[j_star], ps_fallback[j_star])
        names = np.array(self.methods, dtype=object)[j_star]
        return list(zip(names.tolist(), ps_sel.tolist()))

    def route_from_predictions_loop(self, r_hat: np.ndarray, ds_name: str,
                                    pred: Predicate, t: float):
        """Scalar per-query Algorithm 2 (the seed implementation) — the
        parity oracle for `route_from_predictions`, shared by the tests
        and the routing-latency benchmark. Not a hot path."""
        pt = int(Predicate(pred))
        ps_of, qps_of = {}, {}
        for m in self.methods:
            hit = self.table.best_qps_setting(ds_name, pt, m, t)
            if hit is not None:
                ps_of[m], qps_of[m] = hit[0], hit[1]["qps"]
        decisions = []
        for qi in range(r_hat.shape[0]):
            passing = [m for j, m in enumerate(self.methods)
                       if r_hat[qi, j] >= t and m in ps_of]
            if passing:
                m_star = max(passing, key=lambda m: qps_of[m])
                decisions.append((m_star, ps_of[m_star]))
            else:  # fallback: argmax predicted recall, max-recall setting
                m_star = self.methods[int(np.argmax(r_hat[qi]))]
                hit = self.table.best_qps_setting(ds_name, pt, m_star, t) \
                    or self.table.max_recall_setting(ds_name, pt, m_star)
                decisions.append((m_star, hit[0] if hit else None))
        return decisions

    def route(self, ds: ANNDataset, qbms: np.ndarray, pred: Predicate,
              t: float):
        r_hat = self.predict_recalls(ds, qbms, pred)
        return self.route_from_predictions(r_hat, ds.name, pred, t)

    # ---- batched dispatch --------------------------------------------------
    def route_and_search(self, ds: ANNDataset, qvecs: np.ndarray,
                         qbms: np.ndarray, pred: Predicate, k: int,
                         t: float, methods_impl: dict):
        """Route, then execute each (method, ps) group as one batched search.
        Returns (ids [Q, k], decisions)."""
        from repro.ann import engine

        decisions = self.route(ds, qbms, pred, t)
        out = np.full((qvecs.shape[0], k), -1, dtype=np.int32)
        groups: dict = {}
        for qi, d in enumerate(decisions):
            groups.setdefault(d, []).append(qi)
        for (m_name, ps_id), idxs in groups.items():
            method = methods_impl[m_name]
            by_id = {s.ps_id: s for s in method.param_settings()}
            # B may not cover a brand-new deployment dataset yet: fall back
            # to the method's max-budget setting until it is benchmarked.
            setting = by_id.get(ps_id, method.param_settings()[-1])
            index = engine.get_index(method, ds, setting.build)
            idxs = np.asarray(idxs)
            out[idxs] = method.search(ds, index, qvecs[idxs], qbms[idxs],
                                      pred, k, setting.search_dict)
        return out, decisions

    # ---- persistence ----
    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump({
                "feature_names": self.feature_names,
                "methods": self.methods,
                "models": self.models,
                "scaler": (self.scaler.mean, self.scaler.std),
                "table": self.table.entries,
            }, f)

    @staticmethod
    def load(path: str) -> "MLRouter":
        with open(path, "rb") as f:
            d = pickle.load(f)
        return MLRouter(
            feature_names=d["feature_names"], methods=d["methods"],
            models=d["models"], scaler=mlp.Scaler(*d["scaler"]),
            table=BenchmarkTable(entries=d["table"]))
