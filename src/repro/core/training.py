"""Offline stage: training-data collection (paper §6.1.2) + router training.

For every (dataset, predicate type, method) we sweep the method's parameter
space (Table 3 analogue), record (mean recall, QPS) per setting into the
benchmark table B, select the best-recall setting (the method's "potential
best performance"), and keep its *per-query* recall@10 vector as the
regression labels. Features are extracted once per query with **all** 21
numeric features so ablations can slice subsets without re-collecting.
"""

from __future__ import annotations

import dataclasses
import os
import pickle

import numpy as np

from repro.ann import bench
from repro.ann.dataset import ANNDataset
from repro.ann.predicates import PREDICATES, Predicate
from repro.common import artifacts_dir
from repro.core import features as F
from repro.core import mlp
from repro.core.router import MLRouter
from repro.core.table import BenchmarkTable

METHOD_ORDER = ["labelnav", "postfilter", "sieve", "ivf_gamma", "fvamana"]


@dataclasses.dataclass
class CellRecord:
    """One (dataset, predicate) cell of collected data."""
    dataset: str
    pred: int
    numeric: np.ndarray            # [Q, 21] raw numeric features
    recall: dict                   # method -> [Q] per-query recall (best ps)
    best_ps: dict                  # method -> ps_id used for labels
    qvecs: np.ndarray
    qbms: np.ndarray
    gt: np.ndarray
    sweep: list                    # [(method, ps_id, mean_recall, qps)]


@dataclasses.dataclass
class Collection:
    cells: dict                    # (ds, pt) -> CellRecord
    table: BenchmarkTable

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: str) -> "Collection":
        with open(path, "rb") as f:
            return pickle.load(f)


def collect(datasets: dict, methods: dict | None = None, *,
            n_queries: int = 200, seed: int = 0, k: int = 10,
            verbose: bool = True) -> Collection:
    """`methods` defaults to the live candidate-registry view; datasets
    may map to `ANNDataset` or `FilteredIndex` values (bare datasets use
    the shared default pool so repeat collections reuse device tensors)."""
    from repro.ann.index import as_index
    from repro.ann.registry import candidate_methods
    from repro.data.ann_synth import make_queries

    if methods is None:
        methods = candidate_methods()
    cells = {}
    table = BenchmarkTable.new()
    for ds_name, ds in datasets.items():
        fx = as_index(ds)
        ds = fx.ds
        for pred in PREDICATES:
            qs = make_queries(ds, pred, n_queries, k=k, seed=seed)
            numeric = F.feature_matrix(ds, qs.bitmaps, pred,
                                       F.NUMERIC_FEATURES, fx=fx)
            recall, best_ps, sweep = {}, {}, []
            for m_name, m in methods.items():
                best = None
                for setting in m.param_settings():
                    r = bench.run_method(fx, m, setting, qs)
                    table.add(ds_name, int(pred), m_name, setting.ps_id,
                              r.mean_recall, r.qps)
                    sweep.append((m_name, setting.ps_id, r.mean_recall, r.qps))
                    if best is None or (r.mean_recall, r.qps) > \
                            (best.mean_recall, best.qps):
                        best = r
                recall[m_name] = best.recall_per_query
                best_ps[m_name] = best.ps_id
                if verbose:
                    print(f"  {ds_name:14s} {pred.name:8s} {m_name:11s} "
                          f"best={best.ps_id:6s} recall={best.mean_recall:.3f} "
                          f"qps={best.qps:.0f}", flush=True)
            cells[(ds_name, int(pred))] = CellRecord(
                dataset=ds_name, pred=int(pred), numeric=numeric,
                recall=recall, best_ps=best_ps, qvecs=qs.vectors,
                qbms=qs.bitmaps, gt=qs.ground_truth, sweep=sweep)
    return Collection(cells=cells, table=table)


# ---------------------------------------------------------------------------
# assembling model inputs from a Collection
# ---------------------------------------------------------------------------

def assemble_xy(coll: Collection, feature_names: list,
                methods: list = METHOD_ORDER):
    """Returns (X_raw [N, Fexp], y [N, M], meta rows)."""
    xs, ys, meta = [], [], []
    numeric_idx = {n: i for i, n in enumerate(F.NUMERIC_FEATURES)}
    for (ds, pt), cell in sorted(coll.cells.items()):
        q = cell.numeric.shape[0]
        cols = []
        for name in feature_names:
            if name == "pred":
                oh = np.zeros((q, 3), dtype=np.float32)
                oh[:, pt] = 1.0
                cols.append(oh)
            else:
                cols.append(cell.numeric[:, numeric_idx[name]][:, None])
        xs.append(np.concatenate(cols, axis=1))
        ys.append(np.stack([cell.recall[m] for m in methods], axis=1))
        meta.extend([(ds, pt, qi) for qi in range(q)])
    return (np.concatenate(xs).astype(np.float32),
            np.concatenate(ys).astype(np.float32), meta)


def train_models_from_xy(x_raw: "np.ndarray", y: "np.ndarray",
                         methods: list, *, seed: int = 0,
                         hidden=(64, 32), epochs: int = 200):
    """Fit the scaler + one MLP-Reg per method on an already-assembled
    (X_raw [N, F], y [N, M]) pair. This is the shared core of offline
    `train_models` and the online adapter's audit-label retrain
    (`repro.ann.telemetry.OnlineRouterAdapter`). Returns
    (models, scaler)."""
    scaler = mlp.Scaler.fit(x_raw)
    xs = scaler.transform(x_raw)
    models = {}
    for j, m in enumerate(methods):
        params = mlp.train_mlp(xs, y[:, j], hidden=hidden, epochs=epochs,
                               seed=seed + 131 * j)
        models[m] = mlp.params_to_numpy(params)
    return models, scaler


def train_models(coll: Collection, feature_names: list, *, seed: int = 0,
                 hidden=(64, 32), epochs: int = 200,
                 methods: list = METHOD_ORDER):
    """Train one MLP-Reg per candidate method. Returns (models, scaler)."""
    x_raw, y, _ = assemble_xy(coll, feature_names, methods)
    return train_models_from_xy(x_raw, y, methods, seed=seed,
                                hidden=hidden, epochs=epochs)


def train_router(coll_train: Collection, table: BenchmarkTable,
                 feature_names=None, *, seed: int = 0,
                 hidden=(64, 32), epochs: int = 200) -> MLRouter:
    feature_names = feature_names or F.MINIMAL_FEATURES
    models, scaler = train_models(coll_train, feature_names, seed=seed,
                                  hidden=hidden, epochs=epochs)
    return MLRouter(feature_names=feature_names, methods=METHOD_ORDER,
                    models=models, scaler=scaler, table=table)


# ---------------------------------------------------------------------------
# artifact-cached full pipeline
# ---------------------------------------------------------------------------

def default_paths():
    d = artifacts_dir("router")
    return (os.path.join(d, "collect_train.pkl"),
            os.path.join(d, "collect_val.pkl"),
            os.path.join(d, "router"))       # versioned artifact directory


def _router_artifact_path(p: str) -> str | None:
    """Loadable router artifact at `p`: the versioned directory (manifest
    present), else None. Legacy `.pkl` artifacts from pre-PR-2 runs are
    ignored — rebuild (or re-save from an old checkout) to migrate."""
    if os.path.isdir(p) and os.path.exists(os.path.join(p, "router.json")):
        return p
    return None


def build_all(*, n_queries: int = 200, seed: int = 0, force: bool = False,
              verbose: bool = True):
    """Collect train+val data, build B, train the router. Artifact-cached."""
    from repro.data.ann_synth import TRAIN_SPECS, VALIDATION_SPECS, get_dataset

    p_train, p_val, p_router = default_paths()
    router_path = _router_artifact_path(p_router)
    if not force and os.path.exists(p_train) and os.path.exists(p_val) \
            and router_path is not None:
        return (Collection.load(p_train), Collection.load(p_val),
                MLRouter.load(router_path))

    train_ds = {n: get_dataset(n) for n in TRAIN_SPECS}
    val_ds = {n: get_dataset(n) for n in VALIDATION_SPECS}
    if verbose:
        print("== collecting training datasets ==", flush=True)
    coll_train = collect(train_ds, n_queries=n_queries,
                         seed=seed, verbose=verbose)
    if verbose:
        print("== collecting validation datasets ==", flush=True)
    coll_val = collect(val_ds, n_queries=n_queries,
                       seed=seed + 1, verbose=verbose)
    # B spans both pools (offline benchmarking; §4.1 builds it on the
    # deployment/validation datasets — train entries are free to keep)
    table = BenchmarkTable.new()
    table.entries.update(coll_train.table.entries)
    table.entries.update(coll_val.table.entries)
    router = train_router(coll_train, table)
    coll_train.save(p_train)
    coll_val.save(p_val)
    router.save(p_router)
    return coll_train, coll_val, router
