"""The paper's primary contribution: query-aware routing for filtered ANN.

Modules: features (22-feature extraction), table (offline benchmark table
B), rule_router (Alg. 1), mlp (MLP-Reg), forest (RandomForest), baselines
(ablation model families), router (Alg. 2 ML Router), training (offline
stage), oracle (upper bound)."""

from repro.core.router import MLRouter
from repro.core.rule_router import RuleRouter
from repro.core.table import BenchmarkTable

__all__ = ["MLRouter", "RuleRouter", "BenchmarkTable"]
