"""`RouterService` — the query-aware serving facade (paper's deployment
story): binds an `MLRouter` and a method registry to a `FilteredIndex` and
serves typed `QueryBatch` → `SearchResult` traffic.

* `search()` — route the whole batch with one fused forward (vectorised
  features + stacked-MLP + array-op Algorithm 2), then execute each
  chosen (method, ps) group as one batched search on the owned index.
* `search_chunked()` — the same pipeline micro-batched over fixed-size
  query chunks via `engine.run_chunked` (bounded per-chunk memory and
  latency for serving).
* `explain()` — per-query routing transparency: predicted recall r̂ per
  candidate, the threshold-passing set, the chosen (method, ps), and the
  offline benchmark-table row that justified it.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.ann import engine
from repro.ann import registry as registry_mod
from repro.ann.index import (FilteredIndex, QueryBatch, RoutingDecision,
                             SearchResult, exact_distances)


@dataclasses.dataclass
class QueryExplanation:
    """Why one query was routed where it was."""
    query: int
    method: str
    ps_id: str | None
    r_hat: dict                 # candidate method -> predicted recall@10
    passing: list               # methods with r̂ ≥ T and a T-feasible setting
    table_row: dict | None      # offline B row for the chosen (method, ps)
    threshold: float


class RouterService:
    """Serving facade over (FilteredIndex, MLRouter, method registry)."""

    def __init__(self, index: FilteredIndex, router, *, t: float = 0.9,
                 methods=None):
        """`methods`: optional Mapping name -> Method overriding the
        default candidate-registry view (e.g. a trimmed pool)."""
        self.index = index
        self.router = router
        self.t = float(t)
        self.methods = (methods if methods is not None
                        else registry_mod.candidate_methods())

    @property
    def ds(self):
        return self.index.ds

    # ---- routing ---------------------------------------------------------
    def predict(self, batch: QueryBatch) -> np.ndarray:
        """[Q, M] predicted recall per candidate method."""
        return self.router.predict_recalls(self.ds, batch.bitmaps,
                                           batch.pred, fx=self.index)

    def route(self, batch: QueryBatch, *,
              t: float | None = None) -> list[RoutingDecision]:
        r_hat = self.predict(batch)
        return self._decide(r_hat, batch, t)

    def _decide(self, r_hat, batch, t):
        t = self.t if t is None else t
        dec = self.router.route_from_predictions(
            r_hat, self.ds.name, batch.pred, t)
        return [RoutingDecision(m, ps) for m, ps in dec]

    # ---- serving ---------------------------------------------------------
    def search(self, batch: QueryBatch, *,
               t: float | None = None) -> SearchResult:
        """Route the batch, then run each (method, ps) group as one
        batched search. Returns ids + exact distances + decisions +
        stage timings."""
        t0 = time.perf_counter()
        r_hat = self.predict(batch)
        decisions = self._decide(r_hat, batch, t)
        t1 = time.perf_counter()

        ids = np.full((batch.q, batch.k), -1, dtype=np.int32)
        raw = np.full((batch.q, batch.k), np.inf, dtype=np.float32)
        groups: dict = {}
        for qi, d in enumerate(decisions):
            groups.setdefault(d, []).append(qi)
        for (m_name, ps_id), idxs in groups.items():
            method = self.methods[m_name]
            # B may not cover a brand-new deployment dataset yet: fall
            # back to the method's max-budget setting until benchmarked.
            setting = engine.resolve_setting(method, ps_id)
            idxs = np.asarray(idxs)
            g_ids, g_raw = self.index.run_method(method, setting,
                                                 batch.take(idxs))
            ids[idxs] = g_ids
            raw[idxs] = g_raw
        t2 = time.perf_counter()
        return SearchResult(
            ids=ids,
            distances=exact_distances(raw, ids, batch.vectors),
            decisions=decisions,
            timings={"route_s": t1 - t0, "search_s": t2 - t1,
                     "total_s": t2 - t0})

    def search_chunked(self, batch: QueryBatch, *,
                       chunk: int = engine.DEFAULT_QCHUNK,
                       t: float | None = None) -> SearchResult:
        """`search` micro-batched over fixed-size query chunks via
        `engine.run_chunked` (static shapes per chunk; the serving
        entry point for steady traffic).

        `chunk` bounds the routing/result granularity; methods still pad
        their kernels to their own internal chunk (`engine.
        DEFAULT_QCHUNK`), so values below that trade redundant kernel
        work for latency, not memory."""
        timings = {"route_s": 0.0, "search_s": 0.0, "total_s": 0.0}

        def fn(qv, qb):
            res = self.search(
                QueryBatch(qv, qb, batch.pred, batch.k), t=t)
            for key, val in res.timings.items():
                timings[key] += val
            dec = np.empty(len(res.decisions), dtype=object)
            dec[:] = res.decisions
            return res.ids, res.distances, dec

        ids, dists, dec = engine.run_chunked(
            fn, batch.q, batch.vectors, batch.bitmaps, chunk=chunk)
        return SearchResult(ids=ids, distances=dists,
                            decisions=list(dec), timings=timings)

    # ---- transparency -----------------------------------------------------
    def explain(self, batch: QueryBatch, *,
                t: float | None = None) -> list[QueryExplanation]:
        """Per-query routing explanation (r̂ per method, passing set,
        chosen method/ps, backing table row)."""
        t = self.t if t is None else t
        r_hat = self.predict(batch)
        decisions = self._decide(r_hat, batch, t)
        methods = self.router.methods
        pt = int(batch.pred)
        has_pass, _, _, _ = self.router.table.routing_arrays(
            self.ds.name, pt, methods, t)
        out = []
        for qi, (m, ps) in enumerate(decisions):
            row = self.router.table.entries.get(
                (self.ds.name, pt, m, ps)) if ps is not None else None
            out.append(QueryExplanation(
                query=qi, method=m, ps_id=ps,
                r_hat={name: float(r_hat[qi, j])
                       for j, name in enumerate(methods)},
                passing=[name for j, name in enumerate(methods)
                         if has_pass[j] and r_hat[qi, j] >= t],
                table_row=dict(row) if row else None,
                threshold=t))
        return out
