"""`RouterService` — the query-aware serving facade (paper's deployment
story): binds an `MLRouter` and a method registry to a `FilteredIndex` and
serves typed `QueryBatch` → `SearchResult` traffic.

* `search()` — route the whole batch with one fused forward (vectorised
  features + stacked-MLP + array-op Algorithm 2), then execute each
  chosen (method, ps) group as one batched search on the owned index.
* `search_chunked()` — the same pipeline micro-batched over fixed-size
  query chunks via `engine.run_chunked` (bounded per-chunk memory and
  latency for serving).
* `explain()` — per-query routing transparency: predicted recall r̂ per
  candidate, the threshold-passing set, the chosen (method, ps), and the
  offline benchmark-table row that justified it.

Scaling layers on top of the facade:

* `ShardedRouterService` — the same routed pipeline over a
  `repro.ann.sharded.ShardedFilteredIndex`: the batch is routed once
  (full-dataset features), each chosen (method, ps) group executes on
  every shard in parallel, and the per-shard candidates reduce through
  the `ops.merge_topk` kernel.
* `AsyncBatchQueue` — serves *concurrent single-query callers*: callers
  `submit()` one query each and get a `Future`; a background worker
  coalesces pending requests into micro-batches (flushing on `max_batch`
  or `max_wait_ms`, whichever trips first) so the device sees batched
  traffic without callers coordinating.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import NamedTuple

import numpy as np

from repro.ann import engine
from repro.ann import ledger as ledger_mod
from repro.ann import registry as registry_mod
from repro.ann import trace
from repro.ann.obslog import request_events
from repro.ann.index import (FilteredIndex, QueryBatch, RoutingDecision,
                             SearchResult, exact_distances)
from repro.ann.predicates import Predicate


@dataclasses.dataclass
class QueryExplanation:
    """Why one query was routed where it was."""
    query: int
    method: str
    ps_id: str | None
    r_hat: dict                 # candidate method -> predicted recall@10
    passing: list               # methods with r̂ ≥ T and a T-feasible setting
    table_row: dict | None      # offline B row for the chosen (method, ps)
    threshold: float


class RouterService:
    """Serving facade over (FilteredIndex, MLRouter, method registry).

    Args:
        index: the owned serving handle the service executes on — a
            `FilteredIndex`, or anything exposing its `ds`/`run_method`
            surface (`ShardedRouterService` passes a sharded handle).
        router: a trained `repro.core.router.MLRouter`.
        t: default recall threshold T for Algorithm 2 (per-call
            overridable via the `t=` kwarg on search/route/explain).
        methods: optional Mapping name -> Method overriding the default
            candidate-registry view (e.g. a trimmed pool).
        telemetry: optional `repro.ann.telemetry.TelemetrySink`; when
            set, every executed batch records per-query events (method,
            ps, predicate, k, latency share, live generation) and offers
            queries to the audit reservoir. None (default) keeps the hot
            path telemetry-free.
        tracer: optional `repro.ann.trace.Tracer`; when set, `search`
            opens a request-scoped span tree (route → execute →
            per-group / live-stage / store spans) with tail-based
            sampling and the flight recorder. None (default) keeps the
            hot path trace-free — the span calls below are no-ops.
        slo: optional `repro.ann.slo.SLOEngine`; when set, every
            executed batch folds latency/error observations into the
            engine's sliding windows and stamps the router's table
            version as alert provenance.
        obslog: optional `repro.ann.obslog.WideEventLog`; when set,
            every served query emits one wide event (trace id, route
            decision, timings, generation, table version, SLO state).
    """

    def __init__(self, index: FilteredIndex, router, *, t: float = 0.9,
                 methods=None, telemetry=None, tracer=None, slo=None,
                 obslog=None):
        self.index = index
        self.router = router
        self.t = float(t)
        self.methods = (methods if methods is not None
                        else registry_mod.candidate_methods())
        self.telemetry = telemetry
        self.tracer = tracer
        self.slo = slo
        self.obslog = obslog

    @property
    def ds(self):
        return self.index.ds

    # ---- routing ---------------------------------------------------------
    def predict(self, batch: QueryBatch) -> np.ndarray:
        """[Q, M] predicted recall per candidate method (one vectorised
        feature pass + one stacked-MLP forward for the whole batch)."""
        return self.router.predict_recalls(self.ds, batch.bitmaps,
                                           batch.pred, fx=self.index)

    def route(self, batch: QueryBatch, *,
              t: float | None = None) -> list[RoutingDecision]:
        """Per-query `RoutingDecision`s without executing the searches
        (Algorithm 2 at threshold `t`, default the service's)."""
        with trace.span("route", q=batch.q):
            r_hat = self.predict(batch)
            decisions = self._decide(r_hat, batch, t)
            trace.annotate(table_version=getattr(
                self.router.table, "version", None))
            return decisions

    def _decide(self, r_hat, batch, t):
        t = self.t if t is None else t
        dec = self.router.route_from_predictions(
            r_hat, self.ds.name, batch.pred, t)
        return [RoutingDecision(m, ps) for m, ps in dec]

    # ---- serving ---------------------------------------------------------
    def execute(self, batch: QueryBatch,
                decisions: list[RoutingDecision]) -> SearchResult:
        """Run already-routed decisions: each (method, ps) group executes
        as one batched search on the owned index. This is the second
        stage of the pipeline — `search` is `route` + `execute`, and the
        double-buffered `AsyncBatchQueue` worker calls the stages
        separately so batch t+1 routes while batch t executes.

        Live indexes report their per-call stage timings
        (`base_s`/`delta_s`/`merge_s`) through `pop_stage_timings()`;
        they are folded into the result's timings here. When the index
        exposes `snapshot()` (the live handles), one batch-wide snapshot
        pins every (method, ps) group to the same epoch — a compaction
        swapping mid-batch cannot make one result mix two id spaces.
        """
        with trace.span("execute", q=batch.q):
            try:
                return self._execute_impl(batch, decisions)
            except BaseException as e:
                # failed batches still count: availability SLOs and the
                # wide-event log see the error before it propagates
                if self.slo is not None:
                    self.slo.observe_batch(batch.q, errors=batch.q,
                                           pred=int(batch.pred))
                olog = self.obslog
                if olog is not None:
                    for ev in request_events(
                            batch, decisions, per_query_us=0.0,
                            trace_id=trace.trace_id(),
                            error=f"{type(e).__name__}: {e}"):
                        olog.emit(ev)
                raise

    def _execute_impl(self, batch: QueryBatch,
                      decisions: list[RoutingDecision]) -> SearchResult:
        t1 = time.perf_counter()
        ids = np.full((batch.q, batch.k), -1, dtype=np.int32)
        raw = np.full((batch.q, batch.k), np.inf, dtype=np.float32)
        pop = getattr(self.index, "pop_stage_timings", None)
        if callable(pop):
            pop()                        # clear this thread's stale slate
        snap_fn = getattr(self.index, "snapshot", None)
        if callable(snap_fn):
            with trace.span("snapshot_pin"):
                snap = snap_fn()
                trace.annotate(generation=int(getattr(
                    snap, "generation", 0)))
        else:
            snap = None
        groups: dict = {}
        for qi, d in enumerate(decisions):
            groups.setdefault(d, []).append(qi)
        try:
            for (m_name, ps_id), idxs in groups.items():
                method = self.methods[m_name]
                # B may not cover a brand-new deployment dataset yet: fall
                # back to the method's max-budget setting until benchmarked.
                setting = engine.resolve_setting(method, ps_id)
                idxs = np.asarray(idxs)
                sub = batch.take(idxs)
                with trace.span("group", method=m_name, ps=ps_id,
                                q=int(idxs.size)):
                    g_ids, g_raw = (
                        self.index.run_method(method, setting, sub,
                                              snapshot=snap)
                        if snap is not None
                        else self.index.run_method(method, setting, sub))
                ids[idxs] = g_ids
                raw[idxs] = g_raw
            # stable external keys resolve inside the batch snapshot, so
            # a compaction can't remap rows between search and key lookup
            kf = getattr(self.index, "keys_of", None)
            keys = None
            if callable(kf):
                with trace.span("resolve_keys"):
                    keys = (kf(ids, snapshot=snap) if snap is not None
                            else kf(ids))
        finally:
            if snap is not None:
                snap.release()
        t2 = time.perf_counter()
        timings = {"search_s": t2 - t1, "total_s": t2 - t1}
        if callable(pop):
            timings.update(pop())
        generation = getattr(self.index, "generation", 0)
        trace.annotate(
            decisions=sorted({f"{m}/{ps}" for (m, ps) in groups}),
            generation=int(generation),
            table_version=getattr(self.router.table, "version", None))
        sink = self.telemetry
        if sink is not None:
            sink.record_batch(
                batch, decisions, search_s=t2 - t1,
                generation=generation,
                keys=keys if keys is not None else ids)
            for stage in ("base_s", "delta_s", "merge_s", "shard_max_s"):
                if stage in timings:
                    sink.note(stage, timings[stage])
            # per-shard stage seconds (sharded handles emit shard{j}_s)
            # fold into the sink's (shard, stage) skew cells
            for stage, val in timings.items():
                if (stage.startswith("shard") and stage.endswith("_s")
                        and stage != "shard_max_s"):
                    try:
                        sh = int(stage[5:-2])
                    except ValueError:
                        continue
                    sink.note_shard(sh, "exec", val, batch.q)
        per_q_us = (t2 - t1) * 1e6 / max(batch.q, 1)
        slo_eng = self.slo
        if slo_eng is not None:
            slo_eng.observe_batch(batch.q, per_query_us=per_q_us,
                                  pred=int(batch.pred))
            tv = getattr(self.router.table, "version", None)
            if tv is not None:
                slo_eng.note_provenance(table_version=tv)
        olog = self.obslog
        if olog is not None:
            for ev in request_events(
                    batch, decisions, per_query_us=per_q_us,
                    trace_id=trace.trace_id(), timings=timings,
                    generation=int(generation),
                    table_version=getattr(self.router.table, "version",
                                          None),
                    slo_state=(slo_eng.state() if slo_eng is not None
                               else None)):
                olog.emit(ev)
        return SearchResult(
            ids=ids,
            distances=exact_distances(raw, ids, batch.vectors),
            decisions=list(decisions),
            timings=timings, keys=keys)

    def search(self, batch: QueryBatch, *,
               t: float | None = None) -> SearchResult:
        """Route the batch, then run each (method, ps) group as one
        batched search.

        Args:
            batch: the validated query batch.
            t: optional per-call recall threshold override.
        Returns: a `SearchResult` — [Q, k] ids, exact squared-L2
            distances, per-query `RoutingDecision`s, and stage timings
            (`route_s`, `search_s`, `total_s`; plus the live-index
            stages when the index is a `LiveFilteredIndex`).
        Raises: ValueError on batch/dataset shape mismatch; RuntimeError
            if the underlying index is closed.
        """
        with trace.maybe_trace(self.tracer, "search", q=batch.q,
                               k=batch.k, pred=int(batch.pred)):
            t0 = time.perf_counter()
            decisions = self.route(batch, t=t)
            t1 = time.perf_counter()
            res = self.execute(batch, decisions)
            res.timings["route_s"] = t1 - t0
            res.timings["total_s"] = res.timings["search_s"] + (t1 - t0)
            if self.telemetry is not None:
                self.telemetry.note("route_s", t1 - t0)
            return res

    def search_chunked(self, batch: QueryBatch, *,
                       chunk: int = engine.DEFAULT_QCHUNK,
                       t: float | None = None) -> SearchResult:
        """`search` micro-batched over fixed-size query chunks via
        `engine.run_chunked` (static shapes per chunk; the serving
        entry point for steady traffic).

        `chunk` bounds the routing/result granularity; methods still pad
        their kernels to their own internal chunk (`engine.
        DEFAULT_QCHUNK`), so values below that trade redundant kernel
        work for latency, not memory."""
        timings = {"route_s": 0.0, "search_s": 0.0, "total_s": 0.0}

        def fn(qv, qb):
            res = self.search(
                QueryBatch(qv, qb, batch.pred, batch.k), t=t)
            for key, val in res.timings.items():
                # live indexes add stage keys (base_s/delta_s/merge_s)
                # beyond the pre-seeded three
                timings[key] = timings.get(key, 0.0) + val
            dec = np.empty(len(res.decisions), dtype=object)
            dec[:] = res.decisions
            keys = (res.keys if res.keys is not None
                    else np.full(res.ids.shape, -1, np.int64))
            return res.ids, res.distances, dec, keys

        ids, dists, dec, keys = engine.run_chunked(
            fn, batch.q, batch.vectors, batch.bitmaps, chunk=chunk)
        return SearchResult(ids=ids, distances=dists,
                            decisions=list(dec), timings=timings,
                            keys=keys)

    # ---- transparency -----------------------------------------------------
    def explain(self, batch: QueryBatch, *,
                t: float | None = None) -> list[QueryExplanation]:
        """Per-query routing explanation (r̂ per method, passing set,
        chosen method/ps, backing table row)."""
        t = self.t if t is None else t
        r_hat = self.predict(batch)
        decisions = self._decide(r_hat, batch, t)
        methods = self.router.methods
        pt = int(batch.pred)
        has_pass, _, _, _ = self.router.table.routing_arrays(
            self.ds.name, pt, methods, t)
        out = []
        for qi, (m, ps) in enumerate(decisions):
            row = self.router.table.entries.get(
                (self.ds.name, pt, m, ps)) if ps is not None else None
            out.append(QueryExplanation(
                query=qi, method=m, ps_id=ps,
                r_hat={name: float(r_hat[qi, j])
                       for j, name in enumerate(methods)},
                passing=[name for j, name in enumerate(methods)
                         if has_pass[j] and r_hat[qi, j] >= t],
                table_row=dict(row) if row else None,
                threshold=t))
        return out


class ShardedRouterService(RouterService):
    """`RouterService` over a `repro.ann.sharded.ShardedFilteredIndex`.

    The routed pipeline is unchanged — and that is the point: the batch
    is routed **once** (one fused MLP forward over full-dataset features;
    on TPU the feature kernels read the sharded handle's `feature_index`
    tensors on shard-0's device), and only the execution of each chosen
    (method, ps) group fans out: every shard searches its own row
    partition in parallel and the per-shard candidates reduce through the
    `ops.merge_topk` kernel inside the handle's `run_method`.

    Args:
        index: a `ShardedFilteredIndex` or `ShardedLiveIndex` (TypeError
            otherwise — a plain `FilteredIndex`/`LiveFilteredIndex`
            belongs in `RouterService`).
        router / t / methods: as in `RouterService`.
    """

    def __init__(self, index, router, *, t: float = 0.9, methods=None,
                 telemetry=None, tracer=None, slo=None, obslog=None):
        from repro.ann.live import ShardedLiveIndex
        from repro.ann.sharded import ShardedFilteredIndex

        if not isinstance(index, (ShardedFilteredIndex, ShardedLiveIndex)):
            raise TypeError(
                f"ShardedRouterService needs a ShardedFilteredIndex or "
                f"ShardedLiveIndex; got {type(index).__name__} (use "
                f"RouterService for single-index handles)")
        super().__init__(index, router, t=t, methods=methods,
                         telemetry=telemetry, tracer=tracer, slo=slo,
                         obslog=obslog)


# ---------------------------------------------------------------------------
# async micro-batch queue — concurrent single-query callers
# ---------------------------------------------------------------------------

class QueryResult(NamedTuple):
    """One caller's slice of a batched `SearchResult`.

    * `ids` — [k] int32 base ids, −1 padded;
    * `distances` — [k] float32 exact squared-L2 (NaN at −1 pad);
    * `decision` — the query's `RoutingDecision` (None when the queue
      serves a fixed method instead of a routed service);
    * `keys` — [k] int64 stable external keys (−1 pad; None when the
      backend has no key layer). Hold these across compactions and
      restarts instead of `ids`.
    * `cache` — how the query was served when the backend is a
      `repro.ann.cache.SemanticResultCache`: ``"exact"`` (bit-identical
      cached result), ``"semantic"`` (near-duplicate cached result,
      re-scored), ``"transfer"`` (served from a looser-filter cached
      entry whose rows all pass this query's filter), or None (full
      routed search).
    """
    ids: np.ndarray
    distances: np.ndarray
    decision: RoutingDecision | None
    keys: np.ndarray | None = None
    cache: str | None = None


@dataclasses.dataclass
class _PendingQuery:
    vector: np.ndarray
    bitmap: np.ndarray
    pred: Predicate
    k: int
    t_submit: float
    future: Future


class _DaemonExecutor:
    """Single daemon worker running submitted calls in order — the
    execution stage of the queue's two-stage pipeline. Unlike a
    `ThreadPoolExecutor` (non-daemon threads since 3.9) its thread is a
    daemon, so a hung backend search can neither block interpreter exit
    nor make `AsyncBatchQueue.close(timeout=...)` wait forever."""

    def __init__(self, name: str):
        import queue

        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    def submit(self, fn, *args) -> Future:
        fut: Future = Future()
        self._q.put((fut, fn, args))
        return fut

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fut, fn, args = item
            try:
                fut.set_result(fn(*args))
            except BaseException as e:
                fut.set_exception(e)

    def shutdown(self, timeout: float | None = None) -> None:
        self._q.put(None)
        self._thread.join(timeout=timeout)


class AsyncBatchQueue:
    """Coalesces concurrent single-query `submit()` calls into
    micro-batches.

    A background worker drains the queue into one batched call per
    (predicate, k) group whenever either knob trips:

    * `max_batch` — this many requests are pending (flush immediately;
      latency-optimal under load);
    * `max_wait_ms` — the oldest pending request has waited this long
      (bounds tail latency when traffic is sparse).

    The worker is a **two-stage pipeline** (double-buffered): when the
    backend separates routing from execution (`RouterService.route` /
    `.execute`), the worker thread routes batch *t+1* while a dedicated
    single-thread executor is still executing batch *t* — the routing
    forward and the search kernels overlap instead of serialising.
    Backends without the split (a bare `FilteredIndex` with `method=`)
    run both stages on the executor unchanged.

    Callers get a `concurrent.futures.Future` resolving to a
    `QueryResult`; a failed batch propagates its exception to exactly
    the futures in that batch.

    When the backend is a `repro.ann.cache.SemanticResultCache` (it
    exposes `probe_one`), every `submit()` probes the cache *before*
    batching: a hit resolves the Future immediately — no queueing, no
    routing, no search — and only the misses flow through the pipeline,
    whose execute stage admits their results back into the cache.

    Args:
        service: the batched backend — a `RouterService` /
            `ShardedRouterService` (routed), or, with `method=`, any
            handle exposing `search(batch, method, setting)` such as
            `FilteredIndex` / `ShardedFilteredIndex` (direct
            single-method serving, no router needed).
        max_batch: flush threshold and per-batch size cap (>= 1).
        max_wait_ms: max age of the oldest pending request before a
            flush (>= 0; 0 means flush on every submit).
        method / setting: optional fixed method (+ optional setting)
            for router-less serving.

    Raises:
        ValueError: on non-positive `max_batch` or negative
            `max_wait_ms`.
    """

    def __init__(self, service, *, max_batch: int = 64,
                 max_wait_ms: float = 5.0, method=None, setting=None):
        if int(max_batch) < 1:
            raise ValueError(f"max_batch must be >= 1; got {max_batch}")
        if float(max_wait_ms) < 0:
            raise ValueError(
                f"max_wait_ms must be >= 0; got {max_wait_ms}")
        self.service = service
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        # request-scoped tracing: roots are created at batch assembly in
        # the worker thread and re-attached (explicit contextvar
        # propagation) on the execution stage's thread
        self._tracer = getattr(service, "tracer", None)
        if method is None:
            self._search = service.search
        else:
            self._search = lambda b: service.search(b, method, setting)
        # routed services expose route()/execute() separately — that is
        # what lets the worker route batch t+1 while t executes
        self._pipelined = (method is None
                           and callable(getattr(service, "route", None))
                           and callable(getattr(service, "execute", None)))
        self._cv = threading.Condition()
        self._pending: list[_PendingQuery] = []
        self._inflight: list[Future] = []
        self._flush_req = False
        self._closed = False
        self._stats = {"queries": 0, "batches": 0, "cache_hits": 0,
                       "max_batch_seen": 0, "max_queue_depth": 0,
                       "flush_reasons": {}}
        # queue depth is a pull gauge on the process ledger — the
        # /statusz + backpressure-health surface reads it from there
        self._ledger_key = f"queue:{id(self):x}"
        ledger_mod.get_ledger().register_collector(
            self._ledger_key, self._ledger_gauges)
        self._exec = _DaemonExecutor("async-batch-exec")
        self._exec_fut: Future | None = None
        self._worker = threading.Thread(
            target=self._run, name="async-batch-queue", daemon=True)
        self._worker.start()

    # ---- caller surface --------------------------------------------------
    def submit(self, vector, bitmap, pred, k: int = 10) -> Future:
        """Enqueue one query; returns a Future of `QueryResult`.

        Args:
            vector: [d] float query embedding.
            bitmap: [W] uint32 packed query label set.
            pred: the query's `Predicate` (or its int value).
            k: result width.
        Raises: RuntimeError if the queue is closed; ValueError on
            non-1-D vector/bitmap.
        """
        vector = np.asarray(vector, dtype=np.float32)
        bitmap = np.asarray(bitmap, dtype=np.uint32)
        if vector.ndim != 1 or bitmap.ndim != 1:
            raise ValueError(
                f"submit takes one query: vector [d] and bitmap [W]; got "
                f"shapes {vector.shape} / {bitmap.shape}")
        # reject dim mismatches here, per caller — inside the worker they
        # would fail the whole co-batched (pred, k) group's futures
        ds = getattr(self.service, "ds", None)
        if ds is not None:
            if vector.shape[0] != ds.dim:
                raise ValueError(
                    f"query vector dim {vector.shape[0]} does not match "
                    f"dataset dim {ds.dim}")
            if bitmap.shape[0] != ds.bitmaps.shape[1]:
                raise ValueError(
                    f"query bitmap width {bitmap.shape[0]} does not match "
                    f"dataset width {ds.bitmaps.shape[1]}")
        # cache probe before batching: a semantic-cache backend answers
        # hits here, synchronously — the pipeline only ever sees misses
        probe = getattr(self.service, "probe_one", None)
        if callable(probe):
            hit = probe(vector, bitmap, Predicate(pred), int(k))
            if hit is not None:
                with self._cv:
                    if self._closed:
                        raise RuntimeError("AsyncBatchQueue is closed")
                    self._stats["queries"] += 1
                    self._stats["cache_hits"] += 1
                fut: Future = Future()
                fut.set_result(hit)
                return fut
        req = _PendingQuery(vector, bitmap, Predicate(pred), int(k),
                            time.monotonic(), Future())
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncBatchQueue is closed")
            self._pending.append(req)
            self._stats["max_queue_depth"] = max(
                self._stats["max_queue_depth"], len(self._pending))
            self._cv.notify_all()
        return req.future

    def flush(self, timeout: float | None = 30.0) -> None:
        """Force-drain everything currently pending and block until those
        requests complete (their futures resolve; failures stay on the
        futures, flush itself doesn't raise them)."""
        import concurrent.futures as cf

        with self._cv:
            # pending + whatever the worker already took for execution —
            # snapshotting _pending alone would miss an in-flight batch
            futs = [p.future for p in self._pending] + list(self._inflight)
            self._flush_req = True
            self._cv.notify_all()
        cf.wait(futs, timeout=timeout)

    def close(self, timeout: float | None = 30.0) -> None:
        """Stop accepting work, drain what's pending (both pipeline
        stages), join the worker and the execution stage. The timeout
        bounds the whole call; both stage threads are daemons, so a
        hung backend search is abandoned rather than waited on.
        Idempotent."""
        t0 = time.monotonic()
        ledger_mod.get_ledger().deregister_collector(self._ledger_key)
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._worker.join(timeout=timeout)
        left = (None if timeout is None
                else max(0.0, timeout - (time.monotonic() - t0)))
        self._exec.shutdown(timeout=left)

    def __enter__(self) -> "AsyncBatchQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _ledger_gauges(self) -> dict:
        with self._cv:
            return {"pending": len(self._pending),
                    "inflight": len(self._inflight),
                    "max_queue_depth": self._stats["max_queue_depth"]}

    def stats(self) -> dict:
        """Counters: queries/batches served, cache hits answered at
        submit time (`cache_hits`, nonzero only over a semantic-cache
        backend), largest batch, the queue-depth high-water mark
        (`max_queue_depth` — how far submissions ran ahead of the
        pipeline), and a flush-reason histogram (max_batch / max_wait /
        flush / close)."""
        with self._cv:
            s = dict(self._stats)
            s["flush_reasons"] = dict(self._stats["flush_reasons"])
            s["pending"] = len(self._pending)
        sink = getattr(self.service, "telemetry", None)
        if sink is not None:
            s["telemetry"] = sink.stats()
        return s

    # ---- worker: stage 1 (collect + route), stage 2 (execute) ------------
    def _run(self) -> None:
        while True:
            with self._cv:
                reason = None
                while reason is None:
                    if self._pending:
                        if len(self._pending) >= self.max_batch:
                            reason = "max_batch"
                        elif self._closed:
                            reason = "close"
                        elif self._flush_req:
                            reason = "flush"
                        else:
                            left = (self._pending[0].t_submit
                                    + self.max_wait_s - time.monotonic())
                            if left <= 0:
                                reason = "max_wait"
                            else:
                                self._cv.wait(timeout=left)
                    else:
                        self._flush_req = False
                        if self._closed:
                            return
                        self._cv.wait()
                take = self._pending[: self.max_batch]
                del self._pending[: len(take)]
                self._inflight.extend(p.future for p in take)
                if not self._pending:
                    self._flush_req = False
            # stage 1 in this thread: batch assembly + routing. This
            # overlaps with the executor still running the previous
            # batch — the double buffer.
            staged = self._route_stage(take)
            prev = self._exec_fut
            if prev is not None:
                try:               # depth-1 pipeline: wait out batch t-1
                    prev.result()
                except BaseException:
                    pass           # its failures already reached callers
            self._exec_fut = self._exec.submit(
                self._exec_stage, staged, reason,
                [p.future for p in take])

    def _route_stage(self, take: list[_PendingQuery]) -> list:
        """Group requests into per-(pred, k) batches and, when the
        backend supports it, route them. Routing failures reject exactly
        their group's futures here, before the execute stage.

        With a tracer on the backend, each group gets a trace root
        spanning submit → result: an `enqueue_wait` child reconstructed
        from the oldest submit time, `batch_assembly`, the backend's
        `route` span, and (on the executor thread, via `trace.attach`)
        the whole execute subtree."""
        groups: dict = {}
        for req in take:
            groups.setdefault((req.pred, req.k), []).append(req)
        staged = []
        tracer = self._tracer
        for (pred, k), reqs in groups.items():
            root = None
            try:
                if tracer is not None:
                    t0 = min(r.t_submit for r in reqs)
                    now = time.monotonic()
                    root = tracer.start("request", q=len(reqs),
                                        pred=int(pred), k=int(k))
                    root.t0 = t0
                    root.child(
                        "enqueue_wait", t0=t0, t1=now,
                        max_wait_ms=round((now - t0) * 1e3, 3),
                        mean_wait_ms=round(sum(
                            now - r.t_submit for r in reqs)
                            / len(reqs) * 1e3, 3))
                with trace.attach(root):
                    with trace.span("batch_assembly", q=len(reqs)):
                        batch = QueryBatch(
                            np.stack([r.vector for r in reqs]),
                            np.stack([r.bitmap for r in reqs]),
                            pred, k)
                    decisions = (self.service.route(batch)
                                 if self._pipelined else None)
                staged.append((reqs, batch, decisions, root))
            except BaseException as e:
                if root is not None:
                    tracer.finish(root, error=repr(e))
                for req in reqs:
                    if not req.future.done():
                        req.future.set_exception(e)
        return staged

    def _exec_stage(self, staged: list, reason: str,
                    futs: list[Future]) -> None:
        try:
            with self._cv:
                n = sum(len(reqs) for reqs, *_ in staged)
                self._stats["queries"] += n
                self._stats["batches"] += 1
                self._stats["max_batch_seen"] = max(
                    self._stats["max_batch_seen"], len(futs))
                rs = self._stats["flush_reasons"]
                rs[reason] = rs.get(reason, 0) + 1
            sink = getattr(self.service, "telemetry", None)
            tracer = self._tracer
            for reqs, batch, decisions, root in staged:
                try:
                    # re-enter the group's trace on this thread — the
                    # contextvar does not cross the executor hop itself
                    with trace.attach(root):
                        res = (self.service.execute(batch, decisions)
                               if decisions is not None
                               else self._search(batch))
                        trace.annotate(flush_reason=reason)
                    if sink is not None:
                        # queue wait = submit -> result, folded as a
                        # counter pair (sum + count) per drain window
                        now = time.monotonic()
                        wait = sum(now - r.t_submit for r in reqs)
                        sink.note("queue_wait_s", wait)
                        sink.note("queue_waits", len(reqs))
                    if root is not None:
                        tracer.finish(root)
                    for j, req in enumerate(reqs):
                        dec = (res.decisions[j]
                               if res.decisions is not None else None)
                        if not req.future.done():   # caller may have cancelled
                            req.future.set_result(QueryResult(
                                ids=res.ids[j], distances=res.distances[j],
                                decision=dec,
                                keys=(res.keys[j] if res.keys is not None
                                      else None)))
                except BaseException as e:   # propagate to exactly this group
                    if root is not None and root.t1 is None:
                        tracer.finish(root, error=repr(e))
                    for req in reqs:
                        if not req.future.done():
                            req.future.set_exception(e)
        finally:
            with self._cv:
                for f in futs:
                    try:
                        self._inflight.remove(f)
                    except ValueError:
                        pass
