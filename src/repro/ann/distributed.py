"""Distributed filtered-ANN search: corpus sharded over the mesh.

This is how the engine scales past one host/pod: the base vectors (and
their label bitmaps) are sharded along the mesh `data` axis (composed with
`pod` on multi-pod meshes), queries are replicated, each shard computes a
*local* masked top-k with the same fused mask+distance+top-k hot loop, and
an `all_gather` of the tiny [k] per-shard results is merged into the
global top-k. Collective volume per query is `shards × k × 8` bytes —
independent of corpus size, which is what makes the scheme viable at
billion-vector scale.

Two layers share this row-partition scheme:

* `make_sharded_search` (here) — a single jitted shard_map over a
  `launch.mesh` mesh; exact brute force only, minimum dispatch overhead.
* `repro.ann.sharded.ShardedFilteredIndex` — host-orchestrated: one
  owned `FilteredIndex` per shard (any registered method, per-shard
  built indexes) with the cross-shard `ops.merge_topk` reduction. The
  `shard_bounds`/`shard_devices` helpers below are its partition/
  placement plumbing.
"""

from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.ann import engine, topk


def shard_bounds(n: int, n_shards: int) -> np.ndarray:
    """Balanced contiguous row partition: [S+1] boundaries with every
    shard size n//S or n//S + 1 (the first `n % S` shards take the extra
    row). Raises ValueError unless 1 <= n_shards <= n."""
    if not 1 <= n_shards <= n:
        raise ValueError(f"need 1 <= n_shards <= n; got {n_shards}, n={n}")
    base, extra = divmod(n, n_shards)
    sizes = np.full(n_shards, base, dtype=np.int64)
    sizes[:extra] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


def shard_devices(n_shards: int) -> list:
    """One jax device per shard, round-robin over the host's devices
    (every shard shares the single device of a CPU host)."""
    devs = jax.local_devices()
    return [devs[i % len(devs)] for i in range(n_shards)]


def make_sharded_search(mesh, *, k: int, data_axes=("data",)):
    """Build a jitted sharded brute-force filtered search for `mesh`.

    data_axes: mesh axis name(s) the corpus rows shard over (e.g.
    ("pod", "data") on the multi-pod mesh).
    """
    axes = data_axes if len(data_axes) > 1 else data_axes[0]

    def local_search(qvecs, qbms, pred_idx, vectors, norms, bitmaps):
        # local shard: fused mask + distance + top-k (Pallas kernel on TPU)
        scores = topk.score_all(qvecs, vectors, norms)
        mask = engine.mask_shared(bitmaps, qbms, pred_idx)
        scores = jnp.where(mask, scores, topk.INF)
        neg, idx = jax.lax.top_k(-scores, k)
        # globalise ids with the shard row offset
        offset = jnp.int32(0)
        size = vectors.shape[0]
        for i, ax in enumerate(data_axes):
            stride = 1
            for ax2 in data_axes[i + 1:]:
                stride *= jax.lax.axis_size(ax2)
            offset = offset + jax.lax.axis_index(ax) * stride
        gids = jnp.where(jnp.isinf(neg), -1, idx + offset * size).astype(jnp.int32)
        # gather every shard's [Q, k] candidates and merge
        all_ids = jax.lax.all_gather(gids, axes, tiled=False)      # [S, Q, k]
        all_neg = jax.lax.all_gather(neg, axes, tiled=False)
        s = all_ids.shape[0]
        all_ids = jnp.moveaxis(all_ids, 0, 1).reshape(gids.shape[0], s * k)
        all_sc = -jnp.moveaxis(all_neg, 0, 1).reshape(gids.shape[0], s * k)
        ids, _ = topk.topk_ids(all_sc, all_ids, k)
        return ids

    shard_axes = P(*data_axes) if len(data_axes) > 1 else P(data_axes[0])
    fn = jax.shard_map(
        local_search, mesh=mesh,
        in_specs=(P(), P(), P(), shard_axes, shard_axes, shard_axes),
        out_specs=P(),
        check_vma=False,
    )
    return jax.jit(fn)
