"""Structured wide-event log: one JSONL event per served request.

The "wide event" is the canonical observability-2.0 record: instead of
scattering a request across log lines, metrics and traces, every serve
emits *one* wide row carrying everything known about it — trace id,
route decision (method / param setting), cache provenance, shard
timings, live generation, table version, and the SLO state at serve
time.  Post-hoc debugging then is a ``jq`` filter, not a reproduction.

Hot-path discipline mirrors `TelemetrySink`: :meth:`WideEventLog.emit`
claims a slot from an atomic counter (``itertools.count`` — the GIL
makes ``next()`` atomic) and stores ``(seq, event)`` into a fixed ring;
no locks, no I/O.  A daemon writer thread drains the ring by sequence
watermark to a JSONL file with size-based rotation; if producers lap
the writer, the overrun is *counted*, never blocked on — load sheds
log rows, not requests.

:func:`install_postmortem` registers ``SIGUSR2`` + ``atexit`` handlers
that dump the flight recorder, ledger snapshot and SLO status to
``artifacts/serve/postmortem-<ts>.json`` so a crashed or killed server
still leaves evidence.
"""

from __future__ import annotations

import atexit
import itertools
import json
import os
import signal
import threading
import time
from typing import Any, Callable, Iterator

__all__ = [
    "WideEventLog",
    "read_events",
    "install_postmortem",
    "PostmortemDumper",
]


class WideEventLog:
    """Lock-free ring → background JSONL writer with rotation.

    Args:
        path: output JSONL file; rotated siblings get ``.1`` … ``.N``.
        capacity: ring slots; producers overrun the writer at most this
            far before rows drop (counted in ``stats()['dropped']``).
        rotate_bytes: rotate when the active file exceeds this size.
        rotate_keep: rotated generations kept (older ones deleted).
        flush_interval_s: writer wake period.
        autostart: start the writer thread immediately.
    """

    def __init__(self, path: str, *, capacity: int = 4096,
                 rotate_bytes: int = 32 << 20, rotate_keep: int = 3,
                 flush_interval_s: float = 0.2, autostart: bool = True):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.path = str(path)
        self.capacity = int(capacity)
        self.rotate_bytes = int(rotate_bytes)
        self.rotate_keep = int(rotate_keep)
        self.flush_interval_s = float(flush_interval_s)
        self._ring: list = [None] * self.capacity
        self._seq = itertools.count()
        self._head = 0              # racy publish of emit progress
        self._written = 0           # next seq the writer will drain
        self._drain_mu = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._counters = {"emitted": 0, "written": 0, "dropped": 0,
                          "rotations": 0, "write_errors": 0}
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self.path, "a", encoding="utf-8")
        self._bytes = self._f.tell()
        self._thread: threading.Thread | None = None
        if autostart:
            self.start()

    # -- hot path ----------------------------------------------------------
    def emit(self, event: dict) -> int:
        """Store one event; returns its sequence number.  No locks, no
        I/O — safe on the serve path and from any thread."""
        seq = next(self._seq)
        self._ring[seq % self.capacity] = (seq, event)
        # racy watermark: may briefly regress under contention, which
        # only delays (never loses) the regressed rows by one tick
        self._head = seq + 1
        return seq

    # -- writer ------------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self._wake.wait(self.flush_interval_s)
                self._wake.clear()
                self._drain()
            self._drain()           # final sweep on stop

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="obslog-writer")
        self._thread.start()

    def _drain(self) -> None:
        with self._drain_mu:
            head = max(self._head, self._written)
            lo = self._written
            if head - lo > self.capacity:   # writer lapped: shed oldest
                dropped = head - lo - self.capacity
                self._counters["dropped"] += dropped
                lo = head - self.capacity
            lines: list[str] = []
            for s in range(lo, head):
                slot = self._ring[s % self.capacity]
                if slot is None or slot[0] != s:
                    continue                # reserved-but-unfilled slot
                try:
                    lines.append(json.dumps(slot[1], default=str))
                except (TypeError, ValueError):
                    self._counters["write_errors"] += 1
            self._written = head
            if not lines:
                return
            try:
                self._f.write("\n".join(lines) + "\n")
                self._f.flush()
                self._bytes = self._f.tell()
                self._counters["written"] += len(lines)
                if self._bytes >= self.rotate_bytes:
                    self._rotate()
            except OSError:
                self._counters["write_errors"] += 1

    def _rotate(self) -> None:
        self._f.close()
        # shift path.N-1 -> path.N, ... , path -> path.1
        oldest = f"{self.path}.{self.rotate_keep}"
        if os.path.exists(oldest):
            os.unlink(oldest)
        for i in range(self.rotate_keep - 1, 0, -1):
            src = f"{self.path}.{i}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i + 1}")
        if self.rotate_keep > 0:
            os.replace(self.path, f"{self.path}.1")
        else:
            os.unlink(self.path)
        self._f = open(self.path, "a", encoding="utf-8")
        self._bytes = 0
        self._counters["rotations"] += 1

    def flush(self) -> None:
        """Synchronously drain everything emitted so far."""
        self._drain()

    def close(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._wake.set()
            self._thread.join(timeout=5.0)
            self._thread = None
        else:
            self._drain()
        with self._drain_mu:
            if not self._f.closed:
                self._f.close()

    def __enter__(self) -> "WideEventLog":
        return self

    def __exit__(self, et, ev, tb) -> bool:
        self.close()
        return False

    def stats(self) -> dict:
        with self._drain_mu:
            out = dict(self._counters)
            out["emitted"] = self._head
            out["file_bytes"] = self._bytes
            out["path"] = self.path
        return out


def read_events(path: str, *, include_rotated: bool = True
                ) -> Iterator[dict]:
    """Parse a wide-event JSONL file (rotated generations first, so
    iteration order is oldest → newest).  Skips torn lines."""
    paths: list[str] = []
    if include_rotated:
        i = 1
        rotated = []
        while os.path.exists(f"{path}.{i}"):
            rotated.append(f"{path}.{i}")
            i += 1
        paths.extend(reversed(rotated))   # .N is oldest
    if os.path.exists(path):
        paths.append(path)
    for p in paths:
        with open(p, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    yield json.loads(line)
                except ValueError:
                    continue


# ---------------------------------------------------------------------------
# Serve-path event construction (kept here so `service.py` stays lean)
# ---------------------------------------------------------------------------

def request_events(batch, decisions, *, per_query_us: float,
                   trace_id: str | None, timings: dict | None = None,
                   generation: int | None = None,
                   table_version: int | None = None,
                   slo_state: str | None = None,
                   cache: list | None = None,
                   error: str | None = None) -> list[dict]:
    """Build one wide event per query of a served batch.  The batch
    shares a trace root, timings and serve-time state; per-query fields
    are the route decision and cache provenance."""
    now = time.time()
    shared: dict[str, Any] = {"ts": round(now, 6), "trace": trace_id,
                              "pred": int(batch.pred), "k": int(batch.k),
                              "batch_q": int(batch.q),
                              "lat_us": round(per_query_us, 1)}
    if generation is not None:
        shared["generation"] = int(generation)
    if table_version is not None:
        shared["table_version"] = int(table_version)
    if slo_state is not None:
        shared["slo"] = slo_state
    if error is not None:
        shared["error"] = error
    if timings:
        shared["timings_ms"] = {k[:-2]: round(v * 1e3, 3)
                                for k, v in timings.items()
                                if k.endswith("_s")}
    events: list[dict] = []
    for i in range(batch.q):
        ev = dict(shared)
        ev["qi"] = i
        d = decisions[i] if decisions is not None else None
        if d is not None:
            ev["method"] = d.method
            ev["ps"] = d.ps_id   # int or named setting like "g1"
        ev["cache"] = cache[i] if cache is not None else None
        events.append(ev)
    return events


# ---------------------------------------------------------------------------
# Post-mortem dumps: SIGUSR2 + atexit
# ---------------------------------------------------------------------------

class PostmortemDumper:
    """Writes flight-recorder + ledger + SLO evidence on demand, on
    ``SIGUSR2``, and at interpreter exit."""

    def __init__(self, *, tracer=None, ledger=None, slo=None, obslog=None,
                 out_dir: str | None = None,
                 extra: Callable[[], dict] | None = None):
        self.tracer = tracer
        self.ledger = ledger
        self.slo = slo
        self.obslog = obslog
        self.extra = extra
        if out_dir is None:
            from repro.common import artifacts_dir
            out_dir = artifacts_dir("serve")
        self.out_dir = out_dir
        self._prev_handler: Any = None
        self._installed_signal = False
        self._installed_atexit = False
        self._dumped_atexit = False

    # -- payload -----------------------------------------------------------
    def payload(self, reason: str) -> dict:
        out: dict[str, Any] = {"reason": reason, "t_wall": time.time(),
                               "pid": os.getpid()}
        if self.tracer is not None:
            try:
                out["flight"] = json.loads(
                    self.tracer.dump_flight_json(indent=None))["flight"]
                out["tracer_stats"] = self.tracer.stats()
            except Exception as e:
                out["flight_error"] = str(e)
        if self.ledger is not None:
            try:
                out["ledger"] = self.ledger.snapshot()
            except Exception as e:
                out["ledger_error"] = str(e)
        if self.slo is not None:
            try:
                out["slo"] = self.slo.status()
            except Exception as e:
                out["slo_error"] = str(e)
        if self.obslog is not None:
            try:
                self.obslog.flush()
                out["obslog"] = self.obslog.stats()
            except Exception as e:
                out["obslog_error"] = str(e)
        if self.extra is not None:
            try:
                out["extra"] = self.extra()
            except Exception as e:
                out["extra_error"] = str(e)
        return out

    def dump(self, reason: str = "manual") -> str:
        ts = time.strftime("%Y%m%d-%H%M%S")
        path = os.path.join(self.out_dir,
                            f"postmortem-{ts}-{os.getpid()}.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.payload(reason), f, indent=2, default=str)
        return path

    # -- installation ------------------------------------------------------
    def install(self, *, install_signal: bool = True,
                install_atexit: bool = True) -> "PostmortemDumper":
        if install_signal and hasattr(signal, "SIGUSR2") \
                and threading.current_thread() is threading.main_thread():
            def on_usr2(signum, frame):
                try:
                    self.dump("SIGUSR2")
                except Exception:
                    pass
                prev = self._prev_handler
                if callable(prev):
                    prev(signum, frame)

            self._prev_handler = signal.signal(signal.SIGUSR2, on_usr2)
            self._installed_signal = True
        if install_atexit:
            atexit.register(self._atexit_dump)
            self._installed_atexit = True
        return self

    def _atexit_dump(self) -> None:
        if self._dumped_atexit:
            return
        self._dumped_atexit = True
        try:
            self.dump("atexit")
        except Exception:
            pass

    def uninstall(self) -> None:
        if self._installed_signal:
            signal.signal(signal.SIGUSR2, self._prev_handler
                          if self._prev_handler is not None
                          else signal.SIG_DFL)
            self._installed_signal = False
        if self._installed_atexit:
            try:
                atexit.unregister(self._atexit_dump)
            except Exception:
                pass
            self._installed_atexit = False


def install_postmortem(*, tracer=None, ledger=None, slo=None, obslog=None,
                       out_dir: str | None = None,
                       extra: Callable[[], dict] | None = None,
                       install_signal: bool = True,
                       install_atexit: bool = True) -> PostmortemDumper:
    """Convenience: build + install a :class:`PostmortemDumper`."""
    return PostmortemDumper(tracer=tracer, ledger=ledger, slo=slo,
                            obslog=obslog, out_dir=out_dir,
                            extra=extra).install(
        install_signal=install_signal, install_atexit=install_atexit)
