"""`repro.ann.store` — the durable storage subsystem for live indexes.

An `IndexStore` is a directory that makes the whole serving state of a
`LiveFilteredIndex` / `ShardedLiveIndex` survive process restarts and
crashes:

* **segment files** — the sealed base dataset of each generation,
  written once (`ANNDataset.save_segment`) and opened zero-copy via
  `np.memmap`, together with the per-row stable-key map (`keys.npy`)
  and the persistable built method indexes (``indexes/*.npz`` through
  `Method.index_arrays`);
* **manifest** — one version-stamped JSON (`MANIFEST.json`) committed
  by atomic rename. The manifest is the *only* commit point: whatever
  it references is a complete, consistent state, and `open()` deletes
  any segment/WAL files it does not reference (the debris of a crash
  mid-checkpoint or mid-compaction);
* **write-ahead log** — every `upsert`/`delete` appends a CRC-framed
  record *before* the in-memory state mutates (`fsync` batched by the
  ``sync_every`` knob), and `compact_async` logs a barrier record at
  its snapshot point, so replay reproduces compactions exactly. A torn
  tail (crash mid-write) is detected by length/CRC and truncated on
  recovery — every complete record before it is kept;
* **stable external keys** — the per-generation key map rides in the
  segment, WAL upsert records carry their keys, and compaction barriers
  replay deterministically, so the keys a client saw before a crash
  resolve to the same vectors after `open()`.

`IndexStore.open()` recovers base + WAL into a serving-ready live
handle (`store.index`); `checkpoint()` folds the current WAL into a new
segment generation; `compact()` runs a live compaction and commits the
new generation through the manifest before retiring the old segment.
`link_router()` records the router artifact + benchmark-table version
stamps, and `open()` refuses to serve when the artifact on disk no
longer matches (see docs/persistence.md).
"""

from __future__ import annotations

import json
import os
import shutil
import struct
import threading
import time
import zlib

import numpy as np

from repro.ann import labels as lb
from repro.ann import ledger as ledger_mod
from repro.ann import registry as registry_mod
from repro.ann import trace
from repro.ann.dataset import ANNDataset, fsync_path
from repro.ann.live import (DEFAULT_DELTA_CHUNK, ChunkIndex,
                            LiveFilteredIndex, ShardedLiveIndex)

STORE_FORMAT = "repro.index-store"
STORE_VERSION = 1
MANIFEST = "MANIFEST.json"
_SEGMENTS_DIR = "segments"
_WAL_DIR = "wal"
_KEYS_FILE = "keys.npy"
_INDEX_DIR = "indexes"
_CHUNK_DIR = "delta_chunks"

# ---------------------------------------------------------------------------
# write-ahead log
# ---------------------------------------------------------------------------

_WAL_MAGIC = b"RPWAL001"
_WAL_HEADER = struct.Struct("<IIQ")          # dim, width, generation
_REC_HEADER = struct.Struct("<IBQII")        # magic, type, gen, len, crc
_REC_MAGIC = 0x52574C52
REC_UPSERT, REC_DELETE, REC_COMPACT = 1, 2, 3


class WalRecord:
    """One replayed WAL operation (`kind` ∈ upsert/delete/compact)."""

    __slots__ = ("kind", "gen", "keys", "vectors", "bitmaps", "ids")

    def __init__(self, kind, gen, keys=None, vectors=None, bitmaps=None,
                 ids=None):
        self.kind = kind
        self.gen = gen
        self.keys = keys
        self.vectors = vectors
        self.bitmaps = bitmaps
        self.ids = ids


class WriteAheadLog:
    """Append-only CRC-framed operation log with group-commit fsync.

    Record frame: ``<IBQII`` header (magic, type, generation,
    payload_len, crc32(payload)) + payload. The file starts with a
    24-byte header (magic, dim, width, creation generation).

    Durability is split from appending so callers can log under their
    own write lock but fsync *off* it: `log_*` writes the record to the
    OS (`flush`) and returns its sequence number; `commit(seq)` then
    makes it durable before the operation is acknowledged. With
    ``sync_every == 1`` every commit waits for an fsync, but concurrent
    committers share one: the first caller into `wait_durable` becomes
    the fsync leader and its single fsync covers every record appended
    so far, so followers return without touching the disk
    (group commit). Larger ``sync_every`` values skip the wait until
    that many records accumulate — the same crash-loss window as
    before, minus the inline fsync.
    """

    def __init__(self, path: str, file, *, dim: int, width: int,
                 sync_every: int = 1):
        self.path = path
        self.dim = int(dim)
        self.width = int(width)
        self.sync_every = max(1, int(sync_every))
        self._f = file
        self._mu = threading.Lock()        # serializes appends
        self._fsync_mu = threading.Lock()  # serializes fsync leaders
        self._seq = 0                      # records appended (and flushed)
        self._durable_seq = 0              # records covered by an fsync
        self._bytes = 0                    # payload+frame bytes appended
        self._durable_bytes = 0            # bytes covered by an fsync
        self._closed = False
        # fsync backlog (unsynced records + bytes) as a pull gauge on
        # the process ledger — the backpressure-health surface reads it
        self._ledger_key = f"wal:{os.path.basename(path)}:{id(self):x}"
        ledger_mod.get_ledger().register_collector(
            self._ledger_key, self.backlog)

    # ---- lifecycle ------------------------------------------------------
    @classmethod
    def create(cls, path: str, *, dim: int, width: int, generation: int,
               sync_every: int = 1) -> "WriteAheadLog":
        """Start a fresh WAL file (truncates an existing one)."""
        f = open(path, "wb")
        f.write(_WAL_MAGIC + _WAL_HEADER.pack(dim, width, int(generation)))
        f.flush()
        os.fsync(f.fileno())
        return cls(path, f, dim=dim, width=width, sync_every=sync_every)

    @classmethod
    def open_append(cls, path: str, *, dim: int, width: int,
                    sync_every: int = 1) -> "WriteAheadLog":
        """Append to an existing (already replayed/truncated) WAL."""
        f = open(path, "ab")
        return cls(path, f, dim=dim, width=width, sync_every=sync_every)

    def sync(self) -> None:
        """Force every appended record to durable storage."""
        self.wait_durable(self._seq)

    def wait_durable(self, seq: int) -> None:
        """Block until record `seq` is fsynced. One concurrent caller
        becomes the leader whose single fsync covers every record
        flushed so far; the rest find `_durable_seq` already past their
        seq and return without an fsync of their own."""
        if self._durable_seq >= seq:
            return
        with self._fsync_mu:
            if self._durable_seq >= seq or self._closed:
                return
            with self._mu:
                target = self._seq        # all appended records are flushed
                target_bytes = self._bytes
            with trace.span("wal.fsync", covered=target):
                os.fsync(self._f.fileno())
            self._durable_seq = max(self._durable_seq, target)
            self._durable_bytes = max(self._durable_bytes, target_bytes)

    def commit(self, seq: int) -> None:
        """The ack point for record `seq`: durable before returning when
        ``sync_every == 1``, otherwise fsync only once a batch of
        ``sync_every`` records has accumulated. Call *outside* any lock
        readers contend on — that is the point of the split."""
        if self.sync_every == 1 or seq - self._durable_seq >= self.sync_every:
            self.wait_durable(seq)

    def backlog(self) -> dict:
        """Fsync backlog: records flushed to the OS but not yet durable,
        and the byte span they cover. Both are the crash-loss window."""
        with self._mu:
            return {"records": self._seq - self._durable_seq,
                    "bytes": self._bytes - self._durable_bytes}

    def close(self) -> None:
        ledger_mod.get_ledger().deregister_collector(self._ledger_key)
        if not self._closed:
            self.sync()
            with self._fsync_mu, self._mu:
                self._f.close()
                self._closed = True

    # ---- append ---------------------------------------------------------
    def _append(self, rtype: int, gen: int, payload: bytes) -> int:
        crc = zlib.crc32(payload) & 0xFFFFFFFF
        with trace.span("wal.append", rtype=rtype,
                        payload_bytes=len(payload)):
            with self._mu:
                if self._closed:
                    raise RuntimeError(f"WAL {self.path!r} is closed")
                self._f.write(_REC_HEADER.pack(_REC_MAGIC, rtype,
                                               int(gen),
                                               len(payload), crc))
                self._f.write(payload)
                self._f.flush()
                self._seq += 1
                self._bytes += _REC_HEADER.size + len(payload)
                return self._seq

    def log_upsert(self, gen: int, keys: np.ndarray, vectors: np.ndarray,
                   bitmaps: np.ndarray) -> int:
        n = int(vectors.shape[0])
        payload = (struct.pack("<I", n)
                   + np.ascontiguousarray(keys, np.int64).tobytes()
                   + np.ascontiguousarray(vectors, np.float32).tobytes()
                   + np.ascontiguousarray(bitmaps, np.uint32).tobytes())
        return self._append(REC_UPSERT, gen, payload)

    def log_delete(self, gen: int, ids: np.ndarray) -> int:
        ids = np.ascontiguousarray(ids, np.int64)
        payload = struct.pack("<I", ids.size) + ids.tobytes()
        return self._append(REC_DELETE, gen, payload)

    def log_compact(self, gen: int) -> int:
        return self._append(REC_COMPACT, gen, b"")

    # ---- replay ---------------------------------------------------------
    @staticmethod
    def replay(path: str, *, dim: int, width: int,
               truncate: bool = True) -> list[WalRecord]:
        """Parse every complete record; detect a torn tail (short or
        CRC-failing trailing bytes — the signature of a crash mid-write)
        and, with ``truncate=True``, cut the file back to the last good
        record so subsequent appends extend a clean log.

        A bad record *followed by another valid one* is not a torn tail
        — it is mid-log corruption (bit rot, bad sector), and truncating
        there would silently discard fsync-acknowledged operations.
        That case raises ValueError instead; restore the log from a
        replica or recover the tail manually."""
        with open(path, "rb") as f:
            data = f.read()
        head = len(_WAL_MAGIC) + _WAL_HEADER.size
        if len(data) < head or data[: len(_WAL_MAGIC)] != _WAL_MAGIC:
            raise ValueError(f"{path!r} is not a write-ahead log")
        fdim, fwidth, _ = _WAL_HEADER.unpack(
            data[len(_WAL_MAGIC): head])
        if (fdim, fwidth) != (dim, width):
            raise ValueError(
                f"WAL {path!r} was written for dim={fdim}/width={fwidth}; "
                f"store expects dim={dim}/width={width}")
        records: list[WalRecord] = []
        off = head
        good = off
        while True:
            if off + _REC_HEADER.size > len(data):
                break                          # torn or clean end
            magic, rtype, gen, plen, crc = _REC_HEADER.unpack(
                data[off: off + _REC_HEADER.size])
            body_at = off + _REC_HEADER.size
            if (magic != _REC_MAGIC or body_at + plen > len(data)):
                break
            payload = data[body_at: body_at + plen]
            if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
                break
            rec = WriteAheadLog._parse(rtype, gen, payload, dim, width)
            if rec is None:
                break
            records.append(rec)
            off = body_at + plen
            good = off
        if good < len(data):
            if WriteAheadLog._valid_record_after(data, good, dim, width):
                raise ValueError(
                    f"WAL {path!r} is corrupt at byte {good}: a valid "
                    f"record follows the damaged one, so this is mid-log "
                    f"corruption, not a torn tail — truncating would "
                    f"silently discard acknowledged operations. Restore "
                    f"the log from a replica or recover the tail "
                    f"manually.")
            if truncate:
                with open(path, "r+b") as f:
                    f.truncate(good)
                    f.flush()
                    os.fsync(f.fileno())
        return records

    @staticmethod
    def _valid_record_after(data: bytes, off: int, dim: int,
                            width: int) -> bool:
        """True if a complete, CRC-valid record starts anywhere after
        `off` — the discriminator between a torn tail (nothing valid
        follows) and mid-log corruption (acknowledged records do)."""
        magic = struct.pack("<I", _REC_MAGIC)
        pos = data.find(magic, off + 1)
        while pos != -1:
            if pos + _REC_HEADER.size <= len(data):
                m, rtype, gen, plen, crc = _REC_HEADER.unpack(
                    data[pos: pos + _REC_HEADER.size])
                body = pos + _REC_HEADER.size
                if body + plen <= len(data):
                    payload = data[body: body + plen]
                    if ((zlib.crc32(payload) & 0xFFFFFFFF) == crc
                            and WriteAheadLog._parse(
                                rtype, gen, payload, dim, width)
                            is not None):
                        return True
            pos = data.find(magic, pos + 1)
        return False

    @staticmethod
    def _parse(rtype, gen, payload, dim, width):
        try:
            if rtype == REC_COMPACT:
                return WalRecord("compact", gen)
            (n,) = struct.unpack_from("<I", payload, 0)
            body = payload[4:]
            if rtype == REC_DELETE:
                if len(body) != 8 * n:
                    return None
                return WalRecord("delete", gen,
                                 ids=np.frombuffer(body, np.int64).copy())
            if rtype == REC_UPSERT:
                kb, vb = 8 * n, 4 * n * dim
                if len(body) != kb + vb + 4 * n * width:
                    return None
                return WalRecord(
                    "upsert", gen,
                    keys=np.frombuffer(body[:kb], np.int64).copy(),
                    vectors=np.frombuffer(
                        body[kb: kb + vb], np.float32
                    ).reshape(n, dim).copy(),
                    bitmaps=np.frombuffer(
                        body[kb + vb:], np.uint32
                    ).reshape(n, width).copy())
        except struct.error:
            return None
        return None                            # unknown record type


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class IndexStore:
    """Directory-backed durable home of one live index.

    Use the classmethod constructors — `create` for a fresh directory,
    `open` to recover an existing one — and serve through
    ``store.index`` (a WAL-attached `LiveFilteredIndex` or
    `ShardedLiveIndex`). The store owns the handle and the WAL;
    `close()` releases both.
    """

    def __init__(self, path: str, index, manifest: dict,
                 wal: WriteAheadLog | None, *, registry=None,
                 sync_every: int = 1):
        self.path = os.path.abspath(path)
        self._index = index
        self._manifest = manifest
        self._wal = wal
        self._registry = registry
        self._sync_every = int(sync_every)
        self._closed = False

    # ---- constructors ---------------------------------------------------
    @classmethod
    def create(cls, path: str, source=None, *, name: str | None = None,
               dim: int | None = None, universe: int | None = None,
               n_shards: int = 1, router_dir: str | None = None,
               registry=None, device=None, devices=None,
               sync_every: int = 1,
               delta_chunk: int = DEFAULT_DELTA_CHUNK,
               parallel: bool = True) -> "IndexStore":
        """Initialise a store directory and write generation 0.

        Args:
            path: target directory (created if missing; must not already
                be a store).
            source: what to persist — an `ANNDataset` (wrapped in a live
                handle), an existing `LiveFilteredIndex` /
                `ShardedLiveIndex` (current state captured, including
                delta + tombstones + keys), or None for an empty index
                (then `name`/`dim`/`universe` are required).
            n_shards: shard count when `source` is a dataset or None
                (ignored for live handles — their own layout wins).
            router_dir: optional router artifact directory to link and
                version-stamp (see `link_router`).
        Returns: the open store; `store.index` is the WAL-attached
            serving handle (the store owns `source` from here on).
        Raises: ValueError if `path` already holds a store or the
            source/naming arguments are inconsistent.
        """
        path = os.path.abspath(path)
        if os.path.exists(os.path.join(path, MANIFEST)):
            raise ValueError(
                f"{path!r} is already an index store; use IndexStore.open")
        os.makedirs(os.path.join(path, _SEGMENTS_DIR), exist_ok=True)
        os.makedirs(os.path.join(path, _WAL_DIR), exist_ok=True)
        index = cls._coerce_source(source, name=name, dim=dim,
                                   universe=universe, n_shards=n_shards,
                                   registry=registry, device=device,
                                   devices=devices,
                                   delta_chunk=delta_chunk,
                                   parallel=parallel)
        store = cls(path, index, {}, None, registry=registry,
                    sync_every=sync_every)
        store._store_generation = -1
        store.checkpoint()
        if router_dir is not None:
            store.link_router(router_dir)
        return store

    @staticmethod
    def _coerce_source(source, *, name, dim, universe, n_shards, registry,
                       device, devices, delta_chunk, parallel):
        if isinstance(source, (LiveFilteredIndex, ShardedLiveIndex)):
            return source
        if isinstance(source, ANNDataset):
            if n_shards > 1:
                return ShardedLiveIndex(source, n_shards,
                                        registry=registry, devices=devices,
                                        delta_chunk=delta_chunk,
                                        parallel=parallel)
            return LiveFilteredIndex(source, registry=registry,
                                     device=device, delta_chunk=delta_chunk)
        if source is None:
            if name is None or dim is None or universe is None:
                raise ValueError(
                    "an empty IndexStore needs name=, dim= and universe= "
                    "(or pass a dataset / live handle as source)")
            if n_shards > 1:
                return ShardedLiveIndex(
                    None, n_shards, name=name, dim=dim, universe=universe,
                    registry=registry, devices=devices,
                    delta_chunk=delta_chunk, parallel=parallel)
            return LiveFilteredIndex.empty(
                name, dim, universe, registry=registry, device=device,
                delta_chunk=delta_chunk)
        raise TypeError(
            f"source must be an ANNDataset, LiveFilteredIndex, "
            f"ShardedLiveIndex or None; got {type(source).__name__}")

    @classmethod
    def open(cls, path: str, *, registry=None, device=None, devices=None,
             sync_every: int = 1, delta_chunk: int = DEFAULT_DELTA_CHUNK,
             parallel: bool = True, mmap: bool = True,
             verify: bool = False,
             router_dir: str | None = None) -> "IndexStore":
        """Recover a store into a serving-ready live handle.

        Recovery = read the manifest (the commit point), delete
        unreferenced segment/WAL debris, memmap the base segment, restore
        the stable-key map and the persisted built indexes, then replay
        the WAL (truncating a torn tail; compaction barriers re-run the
        compaction so ids and keys come back exactly).

        Args:
            mmap: memmap the segment arrays (default) instead of
                loading them into RAM.
            verify: re-hash the segment files against their recorded
                sha1 checksums (full read; default checks sizes only).
            router_dir: re-link the router artifact at this path
                (records its current version stamps) instead of
                validating the previously linked one — the migration
                override for a moved or re-saved artifact.
        Raises:
            ValueError: not a store, a newer store version, a corrupt
                segment, or a linked router/table whose version stamps
                no longer match the manifest (the error names both
                version pairs and the migration options).
        """
        path = os.path.abspath(path)
        mpath = os.path.join(path, MANIFEST)
        if not os.path.exists(mpath):
            raise ValueError(f"{path!r} is not an index store (no "
                             f"{MANIFEST})")
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("format") != STORE_FORMAT:
            raise ValueError(
                f"{path!r} is not a {STORE_FORMAT} directory "
                f"(format={manifest.get('format')!r})")
        if int(manifest.get("version", -1)) > STORE_VERSION:
            raise ValueError(
                f"store version {manifest['version']} is newer than "
                f"supported version {STORE_VERSION}")

        store = cls(path, None, manifest, None, registry=registry,
                    sync_every=sync_every)
        store._store_generation = int(manifest["store_generation"])
        if router_dir is not None:
            store.link_router(router_dir)
        elif manifest.get("router"):
            store._validate_router(manifest["router"])
        store._clean_stale()

        seg_dir = os.path.join(path, manifest["segment"])
        ds = ANNDataset.load_segment(seg_dir, mmap=mmap, verify=verify)
        base_keys = np.load(os.path.join(seg_dir, _KEYS_FILE))
        live_gen = int(manifest["live_generation"])
        next_key = int(manifest["next_key"])
        if manifest["kind"] == "sharded":
            index = ShardedLiveIndex(
                ds if ds.n else None, int(manifest["n_shards"]),
                name=manifest["name"], dim=int(manifest["dim"]),
                universe=int(manifest["universe"]), registry=registry,
                devices=devices, delta_chunk=delta_chunk,
                parallel=parallel,
                base_keys=base_keys if ds.n else None,
                next_key=next_key, generation=live_gen)
        else:
            index = LiveFilteredIndex(
                ds if ds.n else None, name=manifest["name"],
                dim=int(manifest["dim"]),
                universe=int(manifest["universe"]), registry=registry,
                device=device, delta_chunk=delta_chunk,
                base_keys=base_keys if ds.n else None,
                next_key=next_key, generation=live_gen)
        store._index = index
        store._restore_built(index, seg_dir, manifest.get("built", []))

        wal_path = os.path.join(path, manifest["wal"])
        width = int(manifest["width"])
        records = WriteAheadLog.replay(wal_path, dim=int(manifest["dim"]),
                                       width=width, truncate=True)
        store._apply_records(index, records)
        store._adopt_chunk_indexes(index, seg_dir, manifest, records)
        wal = WriteAheadLog.open_append(wal_path, dim=int(manifest["dim"]),
                                        width=width, sync_every=sync_every)
        store._wal = wal
        index.attach_wal(wal)
        store._replayed_records = len(records)
        return store

    # ---- recovery internals ---------------------------------------------
    def _clean_stale(self) -> None:
        """Delete segment dirs / WAL files the manifest does not
        reference — the debris of a crash between writing a new
        generation and committing the manifest rename."""
        keep_seg = os.path.basename(self._manifest["segment"])
        seg_root = os.path.join(self.path, _SEGMENTS_DIR)
        if os.path.isdir(seg_root):
            for entry in os.listdir(seg_root):
                if entry != keep_seg:
                    shutil.rmtree(os.path.join(seg_root, entry),
                                  ignore_errors=True)
        keep_wal = os.path.basename(self._manifest["wal"])
        wal_root = os.path.join(self.path, _WAL_DIR)
        if os.path.isdir(wal_root):
            for entry in os.listdir(wal_root):
                if entry != keep_wal:
                    try:
                        os.remove(os.path.join(wal_root, entry))
                    except OSError:
                        pass

    def _restore_built(self, index, seg_dir: str, built: list) -> None:
        """Rebuild `built_keys()` on load: adopt the persisted index
        files (single or per-shard), re-run the offline build for the
        rest."""
        reg = self._registry or registry_mod.default_registry()
        if isinstance(index, ShardedLiveIndex):
            all_fx = [s._base_fx for s in index.shards]
        else:
            all_fx = [index._base_fx]

        def adopt(fx, fname, method, bp_t):
            with np.load(os.path.join(seg_dir, fname)) as z:
                arrays = {k: z[k] for k in z.files}
            fx.adopt_index(
                method, bp_t,
                registry_mod.deserialize_index(
                    method, fx.ds, dict(bp_t), arrays))

        for entry in built:
            m_name, bp, fname = entry
            bp_t = tuple((k, v) for k, v in bp)
            try:
                method = reg.get(m_name)
            except KeyError:
                continue                      # method no longer registered
            if isinstance(fname, list):       # per-shard persisted files
                files = (fname if len(fname) == len(all_fx)
                         else [None] * len(all_fx))
                for fx, fn in zip(all_fx, files):
                    if fx is None:
                        continue
                    if fn is not None:
                        adopt(fx, fn, method, bp_t)
                    else:
                        fx.get_index(method, bp_t)
                continue
            for fx in all_fx:
                if fx is None:
                    continue
                if fname is not None and len(all_fx) == 1:
                    adopt(fx, fname, method, bp_t)
                else:
                    fx.get_index(method, bp_t)

    def _adopt_chunk_indexes(self, index, seg_dir: str, manifest: dict,
                             records: list[WalRecord]) -> None:
        """Install the checkpointed sealed-chunk mini-IVF structures on
        the recovered handle. WAL replay reproduces the delta rows in
        their original insertion order, so chunk i covers the same rows
        it did at checkpoint time — unless a compact barrier replayed
        (the delta was rebuilt) or the handle was opened with a
        different `delta_chunk` (the boundaries moved); both cases skip
        adoption and fall back to the lazy rebuild."""
        entry = manifest.get("delta_chunks")
        if (not entry or not isinstance(index, LiveFilteredIndex)
                or int(entry.get("chunk", -1)) != index._delta_chunk
                or any(r.kind == "compact" for r in records)):
            return
        adopt: dict[int, ChunkIndex] = {}
        for i, fn in enumerate(entry["files"]):
            with np.load(os.path.join(seg_dir, fn)) as z:
                adopt[i] = ChunkIndex.from_arrays({k: z[k] for k in z.files})
        index._delta.adopt_chunk_indexes(adopt)

    def _apply_records(self, index, records: list[WalRecord]) -> None:
        """Replay WAL operations onto the freshly loaded handle.

        Generations in the records are absolute; the handle was
        constructed at the manifest's generation, a ``compact`` barrier
        re-runs the compaction synchronously (reproducing the original
        fold — same rows, same remap, same new ids), and a record tagged
        one generation behind (an op that raced the original compaction)
        is translated the way the live handle translated it at swap
        time: snapshot-covered ids through `last_remap`, tail ids (rows
        upserted after the compaction snapshot) by their preserved
        insertion order in the new delta.
        """
        # (n_total before the replayed compact, remap, n_total after it)
        ctx: tuple | None = None
        for rec in records:
            cur = index.generation
            if rec.kind == "upsert":
                if rec.gen not in (cur, cur - 1):
                    raise ValueError(
                        f"WAL upsert for generation {rec.gen} cannot apply "
                        f"at generation {cur} (corrupt log)")
                index.upsert(rec.vectors, rec.bitmaps, keys=rec.keys)
            elif rec.kind == "delete":
                ids = rec.ids
                if rec.gen == cur - 1:
                    if ctx is None:
                        raise ValueError(
                            "WAL delete predates a compaction the handle "
                            "has no remap for (corrupt log)")
                    prev_total, remap, post_total = ctx
                    tail = ids >= prev_total
                    out = np.empty_like(ids)
                    out[~tail] = remap[ids[~tail]]
                    # tail rows re-enter the delta in their original
                    # insertion order right after the compaction's
                    # survivors, so id prev_total + j became
                    # post_total + j (post_total, not base_n: a sharded
                    # compaction whose survivors fall below the shard
                    # count replays them as delta with base_n = 0)
                    out[tail] = post_total + (ids[tail] - prev_total)
                    ids = out[out >= 0]
                elif rec.gen != cur:
                    raise ValueError(
                        f"WAL delete for generation {rec.gen} cannot apply "
                        f"at generation {cur} (corrupt log)")
                if ids.size:
                    index.delete(ids)
            elif rec.kind == "compact":
                if rec.gen == cur:
                    prev_total = index.n_total
                    index.compact()
                    ctx = (prev_total, index.last_remap(), index.n_total)
                elif rec.gen != cur - 1:
                    raise ValueError(
                        f"WAL compact barrier for generation {rec.gen} "
                        f"cannot apply at generation {cur} (corrupt log)")

    # ---- serving surface -------------------------------------------------
    @property
    def index(self):
        """The WAL-attached live handle (serve through this)."""
        self._check_open()
        return self._index

    @property
    def manifest(self) -> dict:
        """The committed manifest (a copy)."""
        return dict(self._manifest)

    @property
    def store_generation(self) -> int:
        return self._store_generation

    def load_dataset(self, *, mmap: bool = True) -> ANNDataset:
        """The current generation's base dataset straight from its
        segment (independent of the live handle — e.g. to build a
        sealed `FilteredIndex`)."""
        self._check_open()
        return ANNDataset.load_segment(
            os.path.join(self.path, self._manifest["segment"]), mmap=mmap)

    # ---- router linkage --------------------------------------------------
    def link_router(self, router_dir: str) -> dict:
        """Record (and version-stamp) the router artifact this store
        serves with. `open()` re-validates the stamps every time, so a
        re-trained or swapped artifact fails loudly instead of routing
        with a stale benchmark table. Returns the recorded entry."""
        from repro.core.router import artifact_versions

        self._check_open()
        router_dir = os.path.abspath(router_dir)
        versions = artifact_versions(router_dir)
        rel = os.path.relpath(router_dir, self.path)
        entry = {"path": rel if not rel.startswith("..") else router_dir,
                 **versions}
        manifest = dict(self._manifest)
        manifest["router"] = entry
        self._commit_manifest(manifest)
        return entry

    def _router_path(self, entry: dict) -> str:
        p = entry["path"]
        return p if os.path.isabs(p) else os.path.join(self.path, p)

    def _validate_router(self, entry: dict) -> None:
        from repro.core.router import artifact_versions

        rpath = self._router_path(entry)
        try:
            cur = artifact_versions(rpath)
        except ValueError as e:
            raise ValueError(
                f"store-linked router artifact is unreadable: {e}; "
                f"re-save the router with MLRouter.save() and re-link it "
                f"(IndexStore.link_router, or router_dir= on open)"
            ) from None
        hint = ("the artifact was re-saved or swapped under the store. "
                "Migrate by re-linking the intended artifact — "
                "IndexStore.link_router(dir) or IndexStore.open(..., "
                "router_dir=dir) — or restore the original artifact "
                "directory.")
        if (cur["router_version"] != int(entry["router_version"])
                or cur["table_version"] != int(entry["table_version"])):
            raise ValueError(
                f"router artifact at {rpath!r} carries (router "
                f"v{cur['router_version']}, table "
                f"v{cur['table_version']}) but this store was linked "
                f"against (router v{entry['router_version']}, table "
                f"v{entry['table_version']}); {hint}")
        want_sha = entry.get("content_sha1")
        if want_sha and cur["content_sha1"] != want_sha:
            raise ValueError(
                f"router artifact at {rpath!r} matches the linked format "
                f"versions (router v{cur['router_version']}, table "
                f"v{cur['table_version']}) but its content changed "
                f"(sha1 {want_sha[:12]} -> "
                f"{cur['content_sha1'][:12]}) — a re-trained router or a "
                f"swapped benchmark table; {hint}")

    def load_router(self):
        """Load the linked (and just-validated) `MLRouter`."""
        from repro.core.router import MLRouter

        self._check_open()
        entry = self._manifest.get("router")
        if not entry:
            raise ValueError(
                f"store {self.path!r} has no linked router artifact "
                f"(IndexStore.link_router first)")
        self._validate_router(entry)
        return MLRouter.load(self._router_path(entry))

    # ---- durability ------------------------------------------------------
    def sync(self) -> None:
        """fsync any WAL records still in the batching window."""
        self._check_open()
        if self._wal is not None:
            self._wal.sync()

    def checkpoint(self) -> int:
        """Fold the current state into a fresh segment generation.

        Writes the base segment (+ keys + persistable built indexes)
        outside the write lock, then — under the lock, so no operation
        can fall between the two — starts a new WAL seeded with the
        residual delta/tombstone state, commits the manifest by atomic
        rename, and swaps the live handle onto the new WAL. Only after
        the commit are the old segment and WAL deleted; a crash at any
        earlier point leaves the previous generation fully intact.
        Returns the new store generation.
        """
        self._check_open()
        with trace.span("store.checkpoint"):
            return self._checkpoint_impl()

    def _checkpoint_impl(self) -> int:
        index = self._index
        dim = index._dim if hasattr(index, "_dim") else index.ds.dim
        width = lb.n_words(index._universe)
        for _ in range(5):          # retry if a compaction swaps mid-write
            old_seg_rel = self._manifest.get("segment")
            store_gen = self._store_generation + 1
            seg_rel = os.path.join(_SEGMENTS_DIR, f"gen-{store_gen:06d}")
            wal_rel = os.path.join(_WAL_DIR, f"wal-{store_gen:06d}.log")
            seg_dir = os.path.join(self.path, seg_rel)
            committed = raced = False
            wal = None
            snap = index.snapshot()
            try:
                state = index.export_state(snap)
                gen = state["generation"]
                base_ds = state["base_ds"]
                if base_ds is None:
                    base_ds = ANNDataset.from_packed(
                        index._name, np.zeros((0, dim), np.float32),
                        np.zeros((0, width), np.uint32), index._universe)
                base_ds.save_segment(seg_dir)
                np.save(os.path.join(seg_dir, _KEYS_FILE),
                        np.ascontiguousarray(state["base_keys"], np.int64))
                built = self._persist_indexes(index, seg_dir)
                chunk_files = self._persist_chunk_indexes(index, seg_dir)
                extras = [_KEYS_FILE] + list(chunk_files)
                for b in built:
                    fs = b[2] if isinstance(b[2], list) else [b[2]]
                    extras.extend(f for f in fs if f)
                for extra in extras:
                    fsync_path(os.path.join(seg_dir, extra))
                fsync_path(seg_dir)
                with index._lock:
                    if index.generation != gen:
                        raced = True
                        continue          # finally releases the snapshot
                    snap2 = index.snapshot()
                    try:
                        state2 = index.export_state(snap2)
                        wal = WriteAheadLog.create(
                            os.path.join(self.path, wal_rel), dim=dim,
                            width=width, generation=gen,
                            sync_every=self._sync_every)
                        self._seed_wal(wal, gen, state2)
                        wal.sync()
                        manifest = self._manifest_dict(
                            index, store_gen, seg_rel, wal_rel, gen,
                            state2["next_key"], base_ds.n, built,
                            chunk_files)
                        self._commit_manifest(manifest)
                        old_wal, self._wal = self._wal, wal
                        index.attach_wal(wal)
                        self._store_generation = store_gen
                        committed = True
                    finally:
                        snap2.release()
            finally:
                snap.release()
                if not committed:
                    # failed (or raced) attempt: the old generation is
                    # still the committed state — drop the half-written
                    # files instead of leaking them and the snapshot pin
                    if wal is not None:
                        wal.close()
                        try:
                            os.remove(wal.path)
                        except OSError:
                            pass
                    shutil.rmtree(seg_dir, ignore_errors=True)
            if old_wal is not None:
                old_path = old_wal.path
                old_wal.close()
                try:
                    os.remove(old_path)
                except OSError:
                    pass
            if old_seg_rel and old_seg_rel != seg_rel:
                shutil.rmtree(os.path.join(self.path, old_seg_rel),
                              ignore_errors=True)
            return store_gen
        raise RuntimeError(
            "checkpoint kept losing the generation race against "
            "concurrent compactions; quiesce compact() and retry")

    @staticmethod
    def _seed_wal(wal: WriteAheadLog, gen: int, state: dict,
                  chunk: int = 1024) -> None:
        """Write the residual (non-segment) state as ordinary records:
        the delta rows in insertion order, then one delete record for
        every tombstone. Replaying them onto the freshly loaded base
        reproduces the checkpointed state exactly."""
        dvec, dbm = state["delta_vectors"], state["delta_bitmaps"]
        dkeys = state["delta_keys"]
        for s in range(0, dvec.shape[0], chunk):
            e = min(s + chunk, dvec.shape[0])
            wal.log_upsert(gen, dkeys[s:e], dvec[s:e], dbm[s:e])
        if state["dead_ids"].size:
            wal.log_delete(gen, state["dead_ids"])

    def _persist_indexes(self, index, seg_dir: str) -> list:
        """Serialize the built method indexes that support it. Returns
        the manifest's `built` list: [method, build_params, file-spec]
        where file-spec is a filename (single index), a per-shard list
        of filenames/nulls (sharded), or null (rebuild on open)."""
        built: list = []
        reg = self._registry or registry_mod.default_registry()
        idx_dir = os.path.join(seg_dir, _INDEX_DIR)
        if isinstance(index, ShardedLiveIndex):
            shards = list(index.shards)
            seen: list = []
            for s in shards:
                for key in s.built_keys():
                    if key not in seen:
                        seen.append(key)
            for i, (m_name, bp) in enumerate(seen):
                try:
                    method = reg.get(m_name)
                except KeyError:
                    continue
                files: list = []
                for j, s in enumerate(shards):
                    fx = s._base_fx
                    arrays = None
                    if fx is not None and (m_name, bp) in fx._indexes:
                        arrays = registry_mod.serialize_index(
                            method, fx._indexes[(m_name, bp)])
                    if arrays is None:
                        files.append(None)
                        continue
                    os.makedirs(idx_dir, exist_ok=True)
                    fname = os.path.join(_INDEX_DIR,
                                         f"{m_name}-{i}-s{j}.npz")
                    np.savez(os.path.join(seg_dir, fname), **arrays)
                    files.append(fname)
                built.append([m_name, [list(kv) for kv in bp],
                              files if any(files) else None])
            return built
        fx = index._base_fx
        if fx is None:
            return built
        for i, (m_name, bp) in enumerate(fx.built_keys()):
            fname = None
            try:
                method = reg.get(m_name)
                arrays = registry_mod.serialize_index(
                    method, fx._indexes[(m_name, bp)])
            except KeyError:
                continue
            if arrays is not None:
                os.makedirs(idx_dir, exist_ok=True)
                fname = os.path.join(_INDEX_DIR, f"{m_name}-{i}.npz")
                np.savez(os.path.join(seg_dir, fname), **arrays)
            built.append([m_name, [list(kv) for kv in bp], fname])
        return built

    def _persist_chunk_indexes(self, index, seg_dir: str) -> list:
        """Write the already-built sealed-chunk mini-IVF structures
        (`live.ChunkIndex`) next to the segment so `open()` adopts them
        instead of re-clustering the replayed delta. Single handles
        only — a sharded delta re-derives per shard lazily."""
        if not isinstance(index, LiveFilteredIndex):
            return []
        chunks = index._delta.built_chunk_indexes()
        if not chunks:
            return []
        cdir = os.path.join(seg_dir, _CHUNK_DIR)
        os.makedirs(cdir, exist_ok=True)
        files = []
        for i, ci in enumerate(chunks):
            fname = os.path.join(_CHUNK_DIR, f"chunk-{i:04d}.npz")
            np.savez(os.path.join(seg_dir, fname), **ci.arrays())
            files.append(fname)
        return files

    def _manifest_dict(self, index, store_gen, seg_rel, wal_rel, live_gen,
                       next_key, n_base, built, chunk_files=()) -> dict:
        return {
            "format": STORE_FORMAT,
            "version": STORE_VERSION,
            "name": index._name,
            "dim": int(index._dim),
            "universe": int(index._universe),
            "width": lb.n_words(index._universe),
            "kind": ("sharded" if isinstance(index, ShardedLiveIndex)
                     else "live"),
            "n_shards": (index.n_shards
                         if isinstance(index, ShardedLiveIndex) else 1),
            "store_generation": int(store_gen),
            "live_generation": int(live_gen),
            "segment": seg_rel,
            "wal": wal_rel,
            "next_key": int(next_key),
            "n_base": int(n_base),
            "router": self._manifest.get("router"),
            "built": built,
            "delta_chunks": ({"chunk": int(index._delta_chunk),
                              "files": list(chunk_files)}
                             if chunk_files else None),
            "updated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }

    def _commit_manifest(self, manifest: dict) -> None:
        """Atomic manifest replace — the store's only commit point."""
        with trace.span("store.commit_manifest",
                        store_generation=manifest.get("store_generation")):
            tmp = os.path.join(self.path, MANIFEST + ".tmp")
            with open(tmp, "w") as f:
                json.dump(manifest, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, os.path.join(self.path, MANIFEST))
            fsync_path(self.path)                  # durable rename
            self._manifest = manifest

    def compact(self, timeout: float | None = None) -> int:
        """Live compaction + checkpoint: fold base+delta−tombstones into
        a fresh sealed generation, then commit it through the manifest
        before the old segment is retired. Returns the new live
        generation."""
        self._check_open()
        gen = self._index.compact(timeout=timeout)
        self.checkpoint()
        return gen

    # ---- lifecycle -------------------------------------------------------
    def stats(self) -> dict:
        """Store + handle state snapshot."""
        self._check_open()
        return {
            "path": self.path,
            "store_generation": self._store_generation,
            "segment": self._manifest.get("segment"),
            "wal": self._manifest.get("wal"),
            "router": self._manifest.get("router"),
            "replayed_records": getattr(self, "_replayed_records", 0),
            "index": self._index.stats(),
        }

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(f"IndexStore({self.path!r}) is closed")

    def close(self) -> None:
        """fsync + detach the WAL and close the owned handle.
        Idempotent; everything needed for `open()` is already on disk."""
        if self._closed:
            return
        self._closed = True
        if self._index is not None:
            try:
                self._index.attach_wal(None)
            except BaseException:
                pass
        if self._wal is not None:
            self._wal.close()
        if self._index is not None:
            self._index.close()

    def __enter__(self) -> "IndexStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
