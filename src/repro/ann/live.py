"""Live index subsystem — streaming upserts/deletes over a sealed base.

`LiveFilteredIndex` turns the frozen `FilteredIndex` serving handle into
a mutable one without giving up the batched read path:

* **delta segment** (`DeltaSegment`) — an append-only, host-growable
  store of upserted vectors/bitmaps, mirrored to the device in fixed
  `chunk`-row blocks (sealed chunks upload once; only the partial tail
  chunk re-uploads per search);
* **tombstone bitmap** — one bool per id over base + delta; `delete()`
  marks ids dead and bumps a version so snapshots stay consistent;
* **snapshot epochs** (`LiveSnapshot`) — a cheap consistent read view:
  the delta high-watermark plus a tombstone copy, pinned to its base
  *generation* so an in-flight batch keeps its base alive across a
  concurrent `compact()`;
* **background compaction** — `compact()` folds the surviving base and
  delta rows into a fresh group-sorted `ANNDataset` (the same
  construction `ANNDataset.build` uses, so upsert-everything-then-compact
  is bit-identical to building the index directly), rebuilds the old
  base's method indexes in a worker thread, and atomically swaps the
  base under the generation counter while old-epoch readers drain.

The read path runs the routed method on the base (overfetched by the
base tombstone count, capped at k — so up to k deletions ranked above a
query's live matches cannot crowd them out of the top-k; beyond that
the base segment degrades gracefully until `compact()` folds the
tombstones away, which is the intended cadence), a brute-force
`ops.masked_topk` pass on the delta segment (overfetched by the *exact*
delta tombstone count — the delta stays exact at any deletion load),
masks tombstones in both candidate sets, and folds them through
`ops.merge_topk`. Ids are per-generation row ids: base rows keep their
dataset row id, delta rows take `base_n + insertion_order`; compaction
remaps both (`stats()["generation"]` tells epochs apart).

`ShardedLiveIndex` scales the same surface across row shards: upserts
round-robin over per-shard delta segments, per-shard ids globalise
through the shard row offsets (base) and a global insertion-order map
(delta), and `RouterService`/`AsyncBatchQueue` serve either handle
unchanged. Routing features stay fresh through the `live_stats()`
protocol `repro.core.features` consumes (live per-label counts and
exact live selectivity corrections).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

from repro.ann import labels as lb
from repro.ann import registry as registry_mod
from repro.ann.dataset import ANNDataset
from repro.ann.engine import ParamSetting, resolve_setting
from repro.ann.index import (FilteredIndex, QueryBatch, SearchResult,
                             exact_distances)
from repro.ann.predicates import Predicate
from repro.ann.sharded import merge_candidates, stack_candidates

DEFAULT_DELTA_CHUNK = 512


def _bucket(k: int, mult: int = 8) -> int:
    """Round up to a multiple of `mult` — the overfetch width follows the
    tombstone count, and bucketing it bounds jit recompilations."""
    return ((int(k) + mult - 1) // mult) * mult


def _label_counts(bitmaps: np.ndarray, universe: int,
                  weights: np.ndarray | None = None) -> np.ndarray:
    """[U] per-label carrier counts from packed [N, W] bitmaps."""
    if bitmaps.shape[0] == 0:
        return np.zeros(universe, dtype=np.int64)
    shifts = np.arange(32, dtype=np.uint32)
    bits = ((bitmaps[:, :, None] >> shifts) & np.uint32(1)).astype(np.int64)
    bits = bits.reshape(bitmaps.shape[0], -1)[:, :universe]
    if weights is not None:
        bits = weights[:, None] * bits
    return bits.sum(0)


@dataclasses.dataclass(frozen=True)
class LiveStats:
    """Live-set summary the routing features consume (see
    `repro.core.features`): exact live size, per-label carrier
    fractions, and the bitmap rows needed to correct base selectivity
    counts (subtract tombstoned base rows, add live delta rows).
    `base_ds` is the sealed base the tombstone rows refer to — the
    feature layer counts base matches against *it*, so a compaction
    racing the feature pass can't pair generation-g corrections with a
    generation-g+1 base."""
    n_live: int
    label_freq: np.ndarray          # [U] live per-label carrier fractions
    base_tomb_bitmaps: np.ndarray   # [Tb, W] bitmaps of dead base rows
    delta_bitmaps: np.ndarray       # [Dl, W] bitmaps of live delta rows
    base_ds: object = None          # ANNDataset of this snapshot's base


class DeltaSegment:
    """Append-only host store with a chunked device mirror.

    Host arrays grow by doubling; rows never mutate once appended, so
    concurrent readers can slice up to their snapshot watermark without
    locking. The device mirror covers whole `chunk`-row blocks of
    appended data and is extended (one upload per new block) under a
    private lock; `device_view` pads the partial tail chunk with
    sentinel rows (zero vector + `PAD_SCORE` norm — never selected by
    `masked_topk`) so the kernel sees shapes that change only at chunk
    boundaries.
    """

    def __init__(self, dim: int, width: int, *,
                 chunk: int = DEFAULT_DELTA_CHUNK):
        self.dim = int(dim)
        self.width = int(width)
        self.chunk = max(1, int(chunk))
        self._vec = np.empty((0, self.dim), np.float32)
        self._bm = np.empty((0, self.width), np.uint32)
        self._norms = np.empty((0,), np.float32)
        self._rows = 0
        self._dev = None            # (vectors, norms, bitmaps) jax arrays
        self._dev_rows = 0          # rows covered by the mirror
        self._dev_lock = threading.Lock()

    @property
    def rows(self) -> int:
        return self._rows

    def _grow(self, need: int) -> None:
        cap = self._vec.shape[0]
        if need <= cap:
            return
        new_cap = max(need, max(self.chunk, 2 * cap))
        for name, fill_shape in (("_vec", (new_cap, self.dim)),
                                 ("_bm", (new_cap, self.width)),
                                 ("_norms", (new_cap,))):
            old = getattr(self, name)
            new = np.zeros(fill_shape, old.dtype)
            new[: self._rows] = old[: self._rows]
            setattr(self, name, new)

    def append(self, vectors: np.ndarray,
               bitmaps: np.ndarray) -> tuple[int, int]:
        """Append rows; returns the local id range [start, stop)."""
        n = vectors.shape[0]
        start = self._rows
        self._grow(start + n)
        self._vec[start: start + n] = vectors
        self._bm[start: start + n] = bitmaps
        self._norms[start: start + n] = np.sum(
            vectors.astype(np.float64) ** 2, axis=1).astype(np.float32)
        self._rows = start + n
        return start, start + n

    def host_view(self, rows: int):
        """(vectors, bitmaps, norms) for the first `rows` rows (views —
        valid for any watermark that was reached before the call)."""
        return self._vec[:rows], self._bm[:rows], self._norms[:rows]

    def device_view(self, rows: int, scope):
        """Device tensors covering the first `rows` rows, padded to a
        chunk multiple with never-selected sentinel rows. `scope` is a
        zero-arg context factory placing uploads (the owning handle's
        `_device_scope`)."""
        import jax.numpy as jnp

        from repro.kernels import masked_topk as mk

        full = (rows // self.chunk) * self.chunk
        with self._dev_lock:
            if full > self._dev_rows:
                with scope():
                    vec = jnp.asarray(self._vec[self._dev_rows: full])
                    bm = jnp.asarray(self._bm[self._dev_rows: full])
                    nm = jnp.asarray(self._norms[self._dev_rows: full])
                    if self._dev is None:
                        self._dev = (vec, nm, bm)
                    else:
                        self._dev = (
                            jnp.concatenate([self._dev[0], vec]),
                            jnp.concatenate([self._dev[1], nm]),
                            jnp.concatenate([self._dev[2], bm]))
                self._dev_rows = full
            dev = self._dev
        parts_v = [dev[0][:full]] if full else []
        parts_n = [dev[1][:full]] if full else []
        parts_b = [dev[2][:full]] if full else []
        tail = rows - full
        if tail:
            tv = np.zeros((self.chunk, self.dim), np.float32)
            tb = np.zeros((self.chunk, self.width), np.uint32)
            tn = np.full((self.chunk,), mk.PAD_SCORE, np.float32)
            tv[:tail] = self._vec[full:rows]
            tb[:tail] = self._bm[full:rows]
            tn[:tail] = self._norms[full:rows]
            with scope():
                parts_v.append(jnp.asarray(tv))
                parts_n.append(jnp.asarray(tn))
                parts_b.append(jnp.asarray(tb))
        if not parts_v:
            return (jnp.zeros((0, self.dim), jnp.float32),
                    jnp.zeros((0,), jnp.float32),
                    jnp.zeros((0, self.width), jnp.uint32))
        if len(parts_v) == 1:
            return parts_v[0], parts_n[0], parts_b[0]
        return (jnp.concatenate(parts_v), jnp.concatenate(parts_n),
                jnp.concatenate(parts_b))

    def device_rows(self) -> int:
        return self._dev_rows

    def drop_device(self) -> None:
        with self._dev_lock:
            self._dev = None
            self._dev_rows = 0


class _StageTimings:
    """Thread-local stage-timing accumulator shared by the live handles:
    `run_method` calls `_stage_add`, the service layer drains with
    `pop_stage_timings` (per thread, so pipelined queue workers don't
    cross-contaminate). Subclasses set `self._local = threading.local()`
    in __init__."""

    def _stage_add(self, d: dict) -> None:
        acc = getattr(self._local, "timings", None)
        if acc is None:
            acc = self._local.timings = {}
        for key, val in d.items():
            acc[key] = acc.get(key, 0.0) + val

    def pop_stage_timings(self) -> dict:
        """Return and clear this thread's accumulated stage timings."""
        acc = getattr(self._local, "timings", None) or {}
        self._local.timings = {}
        return acc


class LiveSnapshot:
    """Consistent read epoch over a `LiveFilteredIndex`.

    Captures the delta high-watermark, a tombstone copy, the external-key
    prefix, and the base generation — and *pins* that generation (the
    sealed base handle stays open) until `release()` / the context
    manager exits. Searches that are handed a snapshot see exactly this
    state regardless of concurrent `upsert`/`delete`/`compact` calls.
    """

    __slots__ = ("generation", "base_n", "delta_rows", "tombstones",
                 "tombstone_version", "delta", "keys", "next_key",
                 "_owner", "_released")

    def __init__(self, owner, generation, base_n, delta_rows, tombstones,
                 tombstone_version, delta, keys, next_key):
        self.generation = generation
        self.base_n = base_n
        self.delta_rows = delta_rows
        self.tombstones = tombstones
        self.tombstone_version = tombstone_version
        self.delta = delta
        self.keys = keys
        self.next_key = next_key
        self._owner = owner
        self._released = False

    @property
    def n_total(self) -> int:
        return self.base_n + self.delta_rows

    @property
    def n_live(self) -> int:
        return self.n_total - int(self.tombstones.sum())

    def release(self) -> None:
        """Unpin the snapshot's generation (idempotent, thread-safe). A
        drained, superseded generation frees its base handle here."""
        with self._owner._lock:        # flag flip atomic wrt double release
            if self._released:
                return
            self._released = True
        self._owner._release_reader(self.generation)

    def __enter__(self) -> "LiveSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return (f"LiveSnapshot(gen={self.generation}, base_n={self.base_n}, "
                f"delta_rows={self.delta_rows}, "
                f"tombstones={int(self.tombstones.sum())})")


class LiveFilteredIndex(_StageTimings):
    """Mutable serving handle: sealed base + delta segment + tombstones.

    Args:
        ds: the sealed base dataset, or None for an empty live index
            (then `name`/`dim`/`universe` are required — e.g. via the
            `empty` constructor). Routed serving (`RouterService`) needs
            a non-empty base for its dataset-level features; direct
            method search works from empty.
        registry: optional `MethodRegistry` for method-name resolution.
        device: optional jax device pin (forwarded to the base handle
            and the delta mirror uploads).
        delta_chunk: delta device-mirror block size in rows.
        base_keys: optional [N] int64 stable external keys for the base
            rows (defaults to the row ids 0..N-1). `repro.ann.store`
            passes the persisted per-generation key map here on reopen.
        next_key: first key `upsert` auto-assigns (defaults past the
            largest base key).
        generation: starting generation counter (restored stores resume
            at the persisted generation instead of 0).
    """

    def __init__(self, ds: ANNDataset | None = None, *, name: str | None = None,
                 dim: int | None = None, universe: int | None = None,
                 registry=None, device=None,
                 delta_chunk: int = DEFAULT_DELTA_CHUNK,
                 base_keys: np.ndarray | None = None,
                 next_key: int | None = None, generation: int = 0):
        if ds is None:
            if name is None or dim is None or universe is None:
                raise ValueError(
                    "an empty LiveFilteredIndex needs name=, dim= and "
                    "universe= (or pass a base ANNDataset)")
            self._name, self._dim = str(name), int(dim)
            self._universe = int(universe)
            self._width = lb.n_words(self._universe)
            self._base_fx: FilteredIndex | None = None
            self._base_n = 0
            base_counts = np.zeros(self._universe, dtype=np.int64)
        else:
            self._name, self._dim = ds.name, ds.dim
            self._universe = ds.universe
            self._width = ds.bitmaps.shape[1]
            self._base_fx = FilteredIndex(ds, registry=registry,
                                          device=device)
            self._base_n = ds.n
            base_counts = _label_counts(
                ds.group_bitmaps, ds.universe,
                weights=ds.group_size.astype(np.int64))
        self._registry = registry
        self._placement = device
        self._delta_chunk = int(delta_chunk)
        self._delta = DeltaSegment(self._dim, self._width, chunk=delta_chunk)
        self._tomb = np.zeros(self._base_n, bool)
        self._tomb_version = 0
        self._live_label_counts = base_counts
        self._generation = int(generation)
        if base_keys is None:
            self._keys = np.arange(self._base_n, dtype=np.int64)
        else:
            self._keys = np.asarray(base_keys, dtype=np.int64).copy()
            if self._keys.shape != (self._base_n,):
                raise ValueError(
                    f"base_keys must be [{self._base_n}]; got shape "
                    f"{self._keys.shape}")
        self._next_key = int(next_key) if next_key is not None else \
            (int(self._keys.max()) + 1 if self._base_n else 0)
        self._key_rows: dict | None = None    # key -> row, built lazily
        self._wal = None                      # attached write-ahead log
        self._lock = threading.RLock()
        self._readers: dict[int, int] = {}      # generation -> pin count
        self._retired: dict[int, FilteredIndex | None] = {}
        self._compact_pool: ThreadPoolExecutor | None = None
        self._compacting: Future | None = None
        self._last_remap: np.ndarray | None = None
        self._features = None       # repro.core.features cache slot
        self._local = threading.local()
        self._closed = False

    @classmethod
    def empty(cls, name: str, dim: int, universe: int,
              **kw) -> "LiveFilteredIndex":
        """A live index with no sealed base — everything starts as delta."""
        return cls(None, name=name, dim=dim, universe=universe, **kw)

    # ---- lifecycle ------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def ds(self) -> ANNDataset | None:
        """The current generation's sealed base dataset (None when the
        index started empty and has not compacted yet)."""
        fx = self._base_fx
        return None if fx is None else fx.ds

    @property
    def generation(self) -> int:
        return self._generation

    @property
    def base_n(self) -> int:
        return self._base_n

    @property
    def n_total(self) -> int:
        return self._base_n + self._delta.rows

    @property
    def n_live(self) -> int:
        with self._lock:
            return self.n_total - int(self._tomb.sum())

    @property
    def device(self):
        """Base device tensors (routing-feature kernels). Requires a
        non-empty base."""
        if self._base_fx is None:
            raise RuntimeError(
                f"LiveFilteredIndex({self._name!r}) has no sealed base yet "
                f"(compact() first, or serve it unrouted)")
        return self._base_fx.device

    def close(self) -> None:
        """Stop the handle: wait out a running compaction (its swap is
        skipped once closed), close the base of every generation, drop
        the delta device mirror. Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            comp = self._compacting
        if comp is not None:
            try:
                comp.result(timeout=300)
            except BaseException:
                pass
        with self._lock:
            if self._base_fx is not None:
                self._base_fx.close()
            for fx in self._retired.values():
                if fx is not None:
                    fx.close()
            self._retired.clear()
            self._delta.drop_device()
            self._features = None
        if self._compact_pool is not None:
            self._compact_pool.shutdown(wait=True)
            self._compact_pool = None

    def __enter__(self) -> "LiveFilteredIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"LiveFilteredIndex({self._name!r}) is closed")

    def _device_scope(self):
        import contextlib

        if self._placement is None:
            return contextlib.nullcontext()
        import jax

        return jax.default_device(self._placement)

    # ---- write path -----------------------------------------------------
    def upsert(self, vectors, bitmaps, *, keys=None) -> np.ndarray:
        """Append rows to the delta segment.

        Args:
            vectors: [R, d] (or [d]) float embeddings.
            bitmaps: [R, W] (or [W]) packed uint32 label sets.
            keys: optional [R] int64 stable external keys for the rows
                (auto-assigned sequentially when omitted). A key that
                already names a *live* row is rejected — delete the old
                row first to re-point a key.
        Returns: [R] int64 assigned ids (valid for this generation;
            `compact()` remaps them — `keys_of` gives the stable keys).
        Raises: RuntimeError if closed; ValueError on shape mismatch or
            a duplicate live key.
        """
        vectors = np.asarray(vectors, dtype=np.float32)
        bitmaps = np.asarray(bitmaps, dtype=np.uint32)
        if vectors.ndim == 1:
            vectors = vectors[None]
        if bitmaps.ndim == 1:
            bitmaps = bitmaps[None]
        if vectors.ndim != 2 or vectors.shape[1] != self._dim:
            raise ValueError(
                f"upsert vectors must be [R, {self._dim}]; got "
                f"{vectors.shape}")
        if bitmaps.shape != (vectors.shape[0], self._width):
            raise ValueError(
                f"upsert bitmaps must be [{vectors.shape[0]}, "
                f"{self._width}]; got {bitmaps.shape}")
        # the bit expansion only depends on the arguments — keep it out
        # of the lock so big ingest batches don't stall readers
        counts = _label_counts(bitmaps, self._universe)
        with self._lock:
            self._check_open()
            ks = self._claim_keys(keys, vectors.shape[0])
            if self._wal is not None:        # durable before applied
                self._wal.log_upsert(self._generation, ks, vectors, bitmaps)
            start, stop = self._delta.append(vectors, bitmaps)
            self._tomb = np.concatenate(
                [self._tomb, np.zeros(stop - start, bool)])
            self._keys = np.concatenate([self._keys, ks])
            if self._key_rows is not None:
                self._key_rows.update(zip(
                    ks.tolist(), range(self._base_n + start,
                                       self._base_n + stop)))
            self._live_label_counts = self._live_label_counts + counts
            return np.arange(self._base_n + start, self._base_n + stop,
                             dtype=np.int64)

    def _claim_keys(self, keys, n: int) -> np.ndarray:
        """Validate/assign [n] external keys (caller holds the lock)."""
        if keys is None:
            ks = np.arange(self._next_key, self._next_key + n,
                           dtype=np.int64)
        else:
            ks = np.atleast_1d(np.asarray(keys, dtype=np.int64))
            if ks.shape != (n,):
                raise ValueError(
                    f"upsert keys must be [{n}]; got shape {ks.shape}")
            if np.unique(ks).size != n:
                raise ValueError("upsert keys must be unique per batch")
            key_rows = self._key_index()
            for k in ks.tolist():
                row = key_rows.get(k)
                if row is not None and not self._tomb[row]:
                    raise ValueError(
                        f"key {k} already names a live row (id {row}); "
                        f"delete it first to re-point the key")
        self._next_key = max(self._next_key, int(ks.max()) + 1) if n else \
            self._next_key
        return ks

    def _key_index(self) -> dict:
        """key -> current-generation row map (caller holds the lock).
        Built lazily, then maintained incrementally by `upsert`;
        compaction invalidates it. Re-used keys map to their newest
        row."""
        if self._key_rows is None:
            self._key_rows = dict(zip(
                self._keys[: self.n_total].tolist(), range(self.n_total)))
        return self._key_rows

    def delete(self, ids) -> int:
        """Tombstone ids (base or delta rows of the current generation).
        Returns the number of *newly* deleted rows; already-dead ids are
        no-ops. Raises IndexError on out-of-range ids."""
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        with self._lock:
            self._check_open()
            n_tot = self.n_total
            if ids.size and (ids.min() < 0 or ids.max() >= n_tot):
                raise IndexError(
                    f"delete ids must be in [0, {n_tot}); got range "
                    f"[{ids.min()}, {ids.max()}]")
            if self._wal is not None:        # replay is idempotent
                self._wal.log_delete(self._generation, ids)
            fresh = ids[~self._tomb[ids]]
            fresh = np.unique(fresh)
            if fresh.size:
                self._tomb[fresh] = True
                self._tomb_version += 1
                self._live_label_counts = (
                    self._live_label_counts
                    - _label_counts(self._bitmaps_of(fresh), self._universe))
            return int(fresh.size)

    # ---- stable external keys -------------------------------------------
    def keys_of(self, ids, snapshot: LiveSnapshot | None = None
                ) -> np.ndarray:
        """Stable external keys for (current-generation or snapshot) ids.

        Returns an int64 array of `ids`' shape with −1 where the id is
        −1. Keys survive `compact()` and a `repro.ann.store` round trip;
        per-generation ids do not.
        """
        ids = np.asarray(ids, dtype=np.int64)
        if snapshot is not None:
            keys = snapshot.keys
        else:
            with self._lock:
                keys = self._keys[: self.n_total]
        out = np.full(ids.shape, -1, dtype=np.int64)
        valid = ids >= 0
        if valid.any():
            out[valid] = keys[ids[valid]]
        return out

    def rows_of(self, keys) -> np.ndarray:
        """Current-generation ids for external keys (−1 for a key that
        has never been assigned). A re-used key maps to its newest
        row."""
        keys = np.atleast_1d(np.asarray(keys, dtype=np.int64))
        with self._lock:
            key_rows = self._key_index()
            return np.array([key_rows.get(int(k), -1) for k in keys],
                            dtype=np.int64)

    def delete_keys(self, keys) -> int:
        """Tombstone rows by stable external key; unknown keys raise
        KeyError. Returns the number of newly deleted rows."""
        keys = np.atleast_1d(np.asarray(keys, dtype=np.int64))
        with self._lock:
            rows = self.rows_of(keys)
            if (rows < 0).any():
                missing = keys[rows < 0].tolist()
                raise KeyError(f"unknown external keys: {missing}")
            return self.delete(rows)

    # ---- durability hook (repro.ann.store) -------------------------------
    def attach_wal(self, wal) -> None:
        """Attach a write-ahead log: every subsequent `upsert`/`delete`
        appends a record *before* the state mutates, and `compact_async`
        logs a compaction barrier at its snapshot point. Pass None to
        detach. The store owns the WAL lifecycle (rotation, fsync,
        close); the live handle only appends."""
        with self._lock:
            self._wal = wal

    def _bitmaps_of(self, gids: np.ndarray) -> np.ndarray:
        """[R, W] packed bitmaps for current-generation global ids."""
        out = np.zeros((gids.size, self._width), np.uint32)
        base = gids < self._base_n
        if base.any():
            out[base] = self._base_fx.ds.bitmaps[gids[base]]
        if (~base).any():
            out[~base] = self._delta._bm[gids[~base] - self._base_n]
        return out

    def fetch(self, ids, snapshot: LiveSnapshot | None = None) -> np.ndarray:
        """[R, d] vectors for result ids (−1 rows come back as NaN).
        With a snapshot, ids are interpreted in that epoch's id space."""
        snap = snapshot or self.snapshot()
        try:
            ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
            out = np.full((ids.size, self._dim), np.nan, np.float32)
            fx = self._base_for(snap)
            base = (ids >= 0) & (ids < snap.base_n)
            if base.any():
                out[base] = fx.ds.vectors[ids[base]]
            delta = ids >= snap.base_n
            if delta.any():
                dvec, _, _ = snap.delta.host_view(snap.delta_rows)
                out[delta] = dvec[ids[delta] - snap.base_n]
            return out
        finally:
            if snapshot is None:
                snap.release()

    # ---- snapshots / epochs ---------------------------------------------
    def snapshot(self) -> LiveSnapshot:
        """Pin a consistent read epoch (see `LiveSnapshot`). Callers that
        hold one across writes must `release()` it (context manager
        supported); searches without an explicit snapshot take and
        release one internally."""
        with self._lock:
            self._check_open()
            rows = self._delta.rows
            gen = self._generation
            self._readers[gen] = self._readers.get(gen, 0) + 1
            # keys: a view is enough — _keys is only ever *reassigned*
            # (concatenate on upsert, fresh array at the compaction
            # swap), never written in place, so the sliced object stays
            # frozen; tombstones mutate in place and must copy
            return LiveSnapshot(self, gen, self._base_n, rows,
                                self._tomb[: self._base_n + rows].copy(),
                                self._tomb_version, self._delta,
                                self._keys[: self._base_n + rows],
                                self._next_key)

    def _release_reader(self, gen: int) -> None:
        with self._lock:
            left = self._readers.get(gen, 0) - 1
            if left > 0:
                self._readers[gen] = left
                return
            self._readers.pop(gen, None)
            fx = self._retired.pop(gen, None)
        if fx is not None:
            fx.close()

    def _base_for(self, snap: LiveSnapshot) -> FilteredIndex | None:
        with self._lock:
            if snap.generation == self._generation:
                return self._base_fx
            if snap.generation in self._retired:
                return self._retired[snap.generation]
        raise RuntimeError(
            f"snapshot generation {snap.generation} has been released "
            f"(current generation {self._generation})")

    # ---- read path -------------------------------------------------------
    def _resolve(self, method):
        if isinstance(method, str):
            reg = self._registry or registry_mod.default_registry()
            return reg.get(method)
        return method

    def run_method(self, method, setting: ParamSetting, batch: QueryBatch,
                   *, snapshot: LiveSnapshot | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Raw live execution of one (method, setting): routed method on
        the base, brute-force `masked_topk` on the delta, tombstones
        masked in both, candidates folded through `merge_topk`.

        Returns the `FilteredIndex.run_method` contract: ([Q, k] int32
        ids with −1 pad, [Q, k] float32 ranking scores with +inf at −1).
        Stage timings (`base_s`/`delta_s`/`merge_s`) accumulate on a
        thread-local, drained by `pop_stage_timings()`.
        """
        self._check_open()
        snap = snapshot
        if snap is None:
            snap = self.snapshot()
        try:
            return self._run(method, setting, batch, snap)
        finally:
            if snapshot is None:
                snap.release()

    def _run(self, method, setting, batch: QueryBatch, snap: LiveSnapshot):
        import jax.numpy as jnp

        from repro.kernels import ops

        k = batch.k
        tomb = snap.tombstones
        base_dead = int(tomb[: snap.base_n].sum())
        delta_dead = int(tomb[snap.base_n:].sum())
        parts = []
        t0 = time.perf_counter()
        fx = self._base_for(snap) if snap.base_n else None
        if fx is not None:
            # overfetch by the tombstone count (capped at k, bucketed to
            # bound recompiles) so deletions can't crowd out live rows
            kb = _bucket(k + min(base_dead, k)) if base_dead else k
            b_ids, b_raw = fx.run_method(
                self._resolve(method), setting,
                QueryBatch(batch.vectors, batch.bitmaps, batch.pred, kb))
            b_ids = np.asarray(b_ids, dtype=np.int32)
            b_raw = np.asarray(b_raw, dtype=np.float32)
            if base_dead:
                valid = b_ids >= 0
                dead = np.zeros_like(valid)
                dead[valid] = tomb[b_ids[valid]]
                b_ids = np.where(dead, np.int32(-1), b_ids)
                b_raw = np.where(dead, np.float32(np.inf), b_raw)
            parts.append((b_ids, b_raw))
        t1 = time.perf_counter()
        if snap.delta_rows:
            # exact overfetch: top-(k + dead) over the delta always
            # contains the live top-k
            kd = _bucket(k + min(delta_dead, snap.delta_rows))
            dvec, dnorm, dbm = snap.delta.device_view(
                snap.delta_rows, self._device_scope)
            d_ids, d_raw = ops.masked_topk(
                jnp.asarray(batch.vectors), jnp.asarray(batch.bitmaps),
                dvec, dnorm, dbm, pred=int(batch.pred), k=kd)
            d_ids = np.asarray(d_ids, dtype=np.int32)
            d_raw = np.asarray(d_raw, dtype=np.float32)
            # sentinel/pad rows are already −1; rows past the watermark
            # (appended since the snapshot) and tombstoned rows drop here
            valid = (d_ids >= 0) & (d_ids < snap.delta_rows)
            dead = ~valid
            dead[valid] |= tomb[snap.base_n + d_ids[valid]]
            d_ids = np.where(dead, np.int32(-1),
                             d_ids + np.int32(snap.base_n))
            d_raw = np.where(dead, np.float32(np.inf), d_raw)
            parts.append((d_ids, d_raw))
        t2 = time.perf_counter()
        if not parts:
            ids = np.full((batch.q, k), -1, np.int32)
            raw = np.full((batch.q, k), np.inf, np.float32)
        else:
            ids, raw = merge_candidates(*stack_candidates(parts), k=k)
        t3 = time.perf_counter()
        self._stage_add({"base_s": t1 - t0, "delta_s": t2 - t1,
                         "merge_s": t3 - t2})
        return ids, raw

    def search(self, batch: QueryBatch, method,
               setting: ParamSetting | str | None = None, *,
               snapshot: LiveSnapshot | None = None) -> SearchResult:
        """Direct single-method live search (no routing). Args/semantics
        match `FilteredIndex.search`, plus `snapshot=` to read a pinned
        epoch; timings gain `base_s`/`delta_s`/`merge_s`."""
        self._check_open()
        method = self._resolve(method)
        if not isinstance(setting, ParamSetting):
            setting = resolve_setting(method, setting)
        self.pop_stage_timings()
        t0 = time.perf_counter()
        snap = snapshot if snapshot is not None else self.snapshot()
        try:
            ids, raw = self.run_method(method, setting, batch,
                                       snapshot=snap)
            keys = self.keys_of(ids, snapshot=snap)
        finally:
            if snapshot is None:
                snap.release()
        dt = time.perf_counter() - t0
        timings = {"search_s": dt, "total_s": dt}
        timings.update(self.pop_stage_timings())
        return SearchResult(
            ids=ids, distances=exact_distances(raw, ids, batch.vectors),
            decisions=None, timings=timings, keys=keys)

    # ---- routing-feature freshness ---------------------------------------
    def live_stats(self) -> LiveStats:
        """Current live-set summary for the routing features (exact live
        size, live per-label fractions, correction bitmaps)."""
        with self._lock:
            rows = self._delta.rows
            tomb = self._tomb
            n_live = self._base_n + rows - int(tomb.sum())
            base_dead = np.nonzero(tomb[: self._base_n])[0]
            base_bm = (self._base_fx.ds.bitmaps[base_dead]
                       if base_dead.size else
                       np.zeros((0, self._width), np.uint32))
            delta_live = ~tomb[self._base_n: self._base_n + rows]
            delta_bm = self._delta._bm[:rows][delta_live]
            return LiveStats(
                n_live=n_live,
                label_freq=(self._live_label_counts.astype(np.float64)
                            / max(n_live, 1)),
                base_tomb_bitmaps=base_bm,
                delta_bitmaps=delta_bm.copy(),
                base_ds=self.ds)

    # ---- compaction ------------------------------------------------------
    def compact(self, timeout: float | None = None) -> int:
        """Merge base + delta (minus tombstones) into a fresh sealed base
        and swap it in. Blocks until done; returns the new generation.
        See `compact_async` for the non-blocking form."""
        return self.compact_async().result(timeout=timeout)

    def compact_async(self) -> Future:
        """Start (or join) a background compaction.

        The worker thread gathers the surviving rows under a snapshot,
        builds the new group-sorted `ANNDataset` + `FilteredIndex`,
        replays the old base's built method indexes, then swaps
        atomically under the write lock: rows upserted and tombstones
        set *during* the rebuild are carried over (tail rows become the
        new delta; late deletes are translated through the id remap).
        Old-generation readers keep their base until their snapshots
        release. Returns a Future of the new generation; a second call
        while one runs returns the same Future.
        """
        with self._lock:
            self._check_open()
            if self._compacting is not None and not self._compacting.done():
                return self._compacting
            if self._compact_pool is None:
                self._compact_pool = ThreadPoolExecutor(
                    max_workers=1,
                    thread_name_prefix=f"compact-{self._name}")
            snap = self.snapshot()
            if self._wal is not None:
                # barrier record: replay compacts synchronously at this
                # point, reproducing the snapshot's fold exactly
                self._wal.log_compact(self._generation)
            fut = self._compact_pool.submit(self._compact_job, snap)
            self._compacting = fut
            return fut

    def _compact_job(self, snap: LiveSnapshot) -> int:
        try:
            keep_base = ~snap.tombstones[: snap.base_n]
            keep_delta = ~snap.tombstones[snap.base_n:]
            dvec, dbm, _ = snap.delta.host_view(snap.delta_rows)
            base_ds = None if snap.base_n == 0 else self._base_for(snap).ds
            vec_parts, bm_parts = [], []
            if base_ds is not None:
                vec_parts.append(base_ds.vectors[keep_base])
                bm_parts.append(base_ds.bitmaps[keep_base])
            vec_parts.append(dvec[keep_delta])
            bm_parts.append(dbm[keep_delta])
            vectors = np.concatenate(vec_parts) if vec_parts else \
                np.zeros((0, self._dim), np.float32)
            bitmaps = np.concatenate(bm_parts) if bm_parts else \
                np.zeros((0, self._width), np.uint32)
            kept = np.concatenate([
                np.nonzero(keep_base)[0],
                snap.base_n + np.nonzero(keep_delta)[0]])
            new_ds, order = ANNDataset.from_packed(
                self._name, vectors, bitmaps, self._universe,
                return_order=True)
            inv = np.empty(order.size, np.int64)
            inv[order] = np.arange(order.size)
            remap = np.full(snap.n_total, -1, np.int64)
            remap[kept] = inv
            # stable keys follow their rows through the remap
            new_keys = np.empty(new_ds.n, np.int64)
            new_keys[remap[kept]] = snap.keys[kept]
            new_fx = FilteredIndex(new_ds, registry=self._registry,
                                   device=self._placement)
            old_fx = self._base_for(snap) if snap.base_n else None
            if old_fx is not None:
                for m_name, build in old_fx.built_keys():
                    try:
                        new_fx.get_index(m_name, build)
                    except KeyError:
                        pass        # method no longer registered
            with self._lock:
                if self._closed:
                    new_fx.close()
                    return self._generation
                rows_now = self._delta.rows
                tvec, tbm, _ = self._delta.host_view(rows_now)
                tail = slice(snap.delta_rows, rows_now)
                new_delta = DeltaSegment(self._dim, self._width,
                                         chunk=self._delta_chunk)
                n_tail = rows_now - snap.delta_rows
                if n_tail:
                    new_delta.append(tvec[tail], tbm[tail])
                new_tomb = np.zeros(new_ds.n + n_tail, bool)
                # deletes that landed after the compaction snapshot
                newly = self._tomb[: snap.n_total] & ~snap.tombstones
                ng = remap[np.nonzero(newly)[0]]
                new_tomb[ng[ng >= 0]] = True
                new_tomb[new_ds.n:] = self._tomb[snap.n_total:
                                                 snap.n_total + n_tail]
                old_gen = self._generation
                old_base = self._base_fx
                self._base_fx = new_fx
                self._base_n = new_ds.n
                self._delta = new_delta
                self._tomb = new_tomb
                self._keys = np.concatenate(
                    [new_keys, self._keys[snap.n_total:
                                          snap.n_total + n_tail]])
                self._key_rows = None
                self._tomb_version += 1
                self._generation = old_gen + 1
                self._features = None       # dataset features went stale
                self._last_remap = remap
                if self._readers.get(old_gen):
                    # record the retirement even for an empty base (None)
                    # so pinned snapshots of generation 0 stay resolvable
                    self._retired[old_gen] = old_base
                elif old_base is not None:
                    old_base.close()
                return self._generation
        finally:
            snap.release()
            with self._lock:
                self._compacting = None

    # ---- maintenance -----------------------------------------------------
    def export_state(self, snap: LiveSnapshot) -> dict:
        """Full logical state of a pinned snapshot — what a
        `repro.ann.store` checkpoint persists: the sealed base dataset,
        per-row stable keys, the delta rows in insertion order (with
        keys), and the tombstoned ids of the epoch."""
        base_fx = self._base_for(snap) if snap.base_n else None
        dvec, dbm, _ = snap.delta.host_view(snap.delta_rows)
        return {
            "generation": snap.generation,
            "base_ds": None if base_fx is None else base_fx.ds,
            "base_keys": snap.keys[: snap.base_n],
            "delta_vectors": dvec,
            "delta_bitmaps": dbm,
            "delta_keys": snap.keys[snap.base_n:],
            "dead_ids": np.nonzero(snap.tombstones)[0].astype(np.int64),
            "next_key": snap.next_key,
        }

    def last_remap(self) -> np.ndarray | None:
        """Id translation of the most recent `compact()`: `remap[old_id]`
        is the row's id in the new generation, −1 if it was deleted.
        None before the first compaction. Ids are per-generation, so
        clients holding ids across a compaction re-resolve through
        this."""
        return self._last_remap

    def built_keys(self) -> list[tuple]:
        return [] if self._base_fx is None else self._base_fx.built_keys()

    def stats(self) -> dict:
        """State snapshot: generation, live/total row counts, delta and
        tombstone sizes, mirror coverage, compaction status."""
        with self._lock:
            rows = self._delta.rows
            return {
                "dataset": self._name,
                "generation": self._generation,
                "base_n": self._base_n,
                "delta_rows": rows,
                "delta_device_rows": self._delta.device_rows(),
                "tombstones": int(self._tomb.sum()),
                "n_live": self._base_n + rows - int(self._tomb.sum()),
                "tombstone_version": self._tomb_version,
                "next_key": self._next_key,
                "wal_attached": self._wal is not None,
                "compacting": (self._compacting is not None
                               and not self._compacting.done()),
                "retired_generations": sorted(self._retired),
                "closed": self._closed,
            }


# ---------------------------------------------------------------------------
# sharded live index — round-robin upserts over per-shard delta segments
# ---------------------------------------------------------------------------

class ShardedLiveSnapshot:
    """Consistent cross-shard read epoch: one pinned `LiveSnapshot` per
    shard plus the shard list / bounds / gid maps / global key prefix of
    the epoch, all captured under the sharded index's write lock. Pins
    the epoch (old shard lists survive a compaction swap) until
    `release()`."""

    __slots__ = ("epoch", "shards", "bounds", "snaps", "gmaps", "keys",
                 "next_key", "locs", "base_ds", "_owner", "_released")

    def __init__(self, owner, epoch, shards, bounds, snaps, gmaps,
                 keys, next_key, locs, base_ds):
        self.epoch = epoch
        self.shards = shards
        self.bounds = bounds
        self.snaps = snaps
        self.gmaps = gmaps
        self.keys = keys
        self.next_key = next_key
        self.locs = locs
        self.base_ds = base_ds
        self._owner = owner
        self._released = False

    def release(self) -> None:
        """Unpin this epoch (idempotent, thread-safe)."""
        with self._owner._lock:
            if self._released:
                return
            self._released = True
        for snap in self.snaps:
            snap.release()
        self._owner._release_epoch(self.epoch)

    def __enter__(self) -> "ShardedLiveSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShardedLiveIndex(_StageTimings):
    """Row-sharded live handle: one `LiveFilteredIndex` per shard.

    Upserts round-robin row-by-row across shards; global delta ids are
    assigned in insertion order (`total_base_n + j`) and mapped to
    (shard, local-row) so `delete()` and result globalisation agree.
    `run_method` snapshots every shard under one lock (a consistent
    cross-shard epoch), fans out, globalises per-shard ids, and reduces
    through `merge_topk`. `compact()` rebuilds **globally**: all
    surviving rows merge into one fresh dataset that is re-sharded
    contiguously, so the result is exactly a `ShardedFilteredIndex`
    over the compacted data.

    Args mirror `ShardedFilteredIndex` (+ the empty-base form of
    `LiveFilteredIndex` via `name`/`dim`/`universe`).
    """

    def __init__(self, ds: ANNDataset | None = None, n_shards: int = 1, *,
                 name: str | None = None, dim: int | None = None,
                 universe: int | None = None, devices=None, registry=None,
                 parallel: bool = True,
                 delta_chunk: int = DEFAULT_DELTA_CHUNK,
                 base_keys: np.ndarray | None = None,
                 next_key: int | None = None, generation: int = 0):
        from repro.ann.distributed import shard_bounds, shard_devices

        n_shards = int(n_shards)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1; got {n_shards}")
        if devices is None:
            devices = shard_devices(n_shards)
        self._registry = registry
        self._delta_chunk = int(delta_chunk)
        self._devices = devices
        if ds is None:
            if name is None or dim is None or universe is None:
                raise ValueError(
                    "an empty ShardedLiveIndex needs name=, dim= and "
                    "universe= (or pass a base ANNDataset)")
            self._name, self._dim = str(name), int(dim)
            self._universe = int(universe)
            self._base_ds: ANNDataset | None = None
            self.bounds = np.zeros(n_shards + 1, dtype=np.int64)
            self.shards = [
                LiveFilteredIndex.empty(
                    f"{self._name}/shard{i}", self._dim, self._universe,
                    registry=registry, device=devices[i],
                    delta_chunk=delta_chunk)
                for i in range(n_shards)]
        else:
            self._name, self._dim = ds.name, ds.dim
            self._universe = ds.universe
            self._base_ds = ds
            self.bounds = shard_bounds(ds.n, n_shards)
            self.shards = [
                LiveFilteredIndex(
                    ds.row_slice(int(s), int(e),
                                 name=f"{ds.name}/shard{i}"),
                    registry=registry, device=devices[i],
                    delta_chunk=delta_chunk)
                for i, (s, e) in enumerate(zip(self.bounds[:-1],
                                               self.bounds[1:]))]
        self._total_base = 0 if ds is None else ds.n
        self._delta_loc: list[tuple[int, int]] = []  # gid-j -> (shard, row)
        self._shard_gids: list[list[int]] = [[] for _ in self.shards]
        self._gid_arrays: list[np.ndarray] | None = None   # search cache
        self._last_remap: np.ndarray | None = None
        self._next_shard = 0
        if base_keys is None:
            self._keys = np.arange(self._total_base, dtype=np.int64)
        else:
            self._keys = np.asarray(base_keys, dtype=np.int64).copy()
            if self._keys.shape != (self._total_base,):
                raise ValueError(
                    f"base_keys must be [{self._total_base}]; got shape "
                    f"{self._keys.shape}")
        self._next_key = int(next_key) if next_key is not None else \
            (int(self._keys.max()) + 1 if self._total_base else 0)
        self._key_rows: dict | None = None    # key -> gid, built lazily
        self._wal = None
        self._wal_quiet = False               # compaction's internal replay
        self._parallel = bool(parallel) and n_shards > 1
        self._pool = (ThreadPoolExecutor(
            max_workers=n_shards,
            thread_name_prefix=f"live-shard-{self._name}")
            if self._parallel else None)
        self._lock = threading.RLock()
        self._epoch = int(generation)
        self._epoch_readers: dict[int, int] = {}
        self._old_shards: dict[int, list] = {}
        self._feature_fx: FilteredIndex | None = None
        self._compact_pool: ThreadPoolExecutor | None = None
        self._compacting: Future | None = None
        self._features = None
        self._local = threading.local()
        self._closed = False

    # ---- lifecycle ------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def ds(self) -> ANNDataset | None:
        """The current generation's full base dataset (None before the
        first compact of an empty-started index)."""
        return self._base_ds

    @property
    def generation(self) -> int:
        return self._epoch

    @property
    def n_live(self) -> int:
        with self._lock:
            return sum(s.n_live for s in self.shards)

    @property
    def base_n(self) -> int:
        return self._total_base

    @property
    def n_total(self) -> int:
        with self._lock:
            return self._total_base + len(self._delta_loc)

    @property
    def feature_index(self) -> FilteredIndex:
        """Full-base `FilteredIndex` on shard-0's device for the TPU
        routing-feature kernels (lazy, like `ShardedFilteredIndex`)."""
        self._check_open()
        if self._base_ds is None:
            raise RuntimeError(
                f"ShardedLiveIndex({self._name!r}) has no sealed base yet")
        if self._feature_fx is None:
            self._feature_fx = FilteredIndex(
                self._base_ds, registry=self._registry,
                device=self._devices[0])
        return self._feature_fx

    @property
    def device(self):
        return self.feature_index.device

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            comp = self._compacting
        if comp is not None:
            try:
                comp.result(timeout=300)
            except BaseException:
                pass
        with self._lock:
            for s in self.shards:
                s.close()
            for old in self._old_shards.values():
                for s in old:
                    s.close()
            self._old_shards.clear()
            if self._feature_fx is not None:
                self._feature_fx.close()
                self._feature_fx = None
            self._features = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        if self._compact_pool is not None:
            self._compact_pool.shutdown(wait=True)
            self._compact_pool = None

    def __enter__(self) -> "ShardedLiveIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"ShardedLiveIndex({self._name!r}) is closed")

    # ---- write path -----------------------------------------------------
    def upsert(self, vectors, bitmaps, *, keys=None) -> np.ndarray:
        """Append rows, round-robin across shards. Returns [R] global
        ids (current generation); `keys=` as in
        `LiveFilteredIndex.upsert` (stable global keys, auto-assigned
        when omitted)."""
        vectors = np.asarray(vectors, dtype=np.float32)
        bitmaps = np.asarray(bitmaps, dtype=np.uint32)
        if vectors.ndim == 1:
            vectors = vectors[None]
        if bitmaps.ndim == 1:
            bitmaps = bitmaps[None]
        if vectors.ndim != 2 or vectors.shape[1] != self._dim:
            raise ValueError(
                f"upsert vectors must be [R, {self._dim}]; got "
                f"{vectors.shape}")
        width = lb.n_words(self._universe)
        if bitmaps.shape != (vectors.shape[0], width):
            raise ValueError(
                f"upsert bitmaps must be [{vectors.shape[0]}, {width}]; "
                f"got {bitmaps.shape}")
        with self._lock:
            self._check_open()
            n = vectors.shape[0]
            ks = self._claim_keys(keys, n)
            if self._wal is not None and not self._wal_quiet:
                self._wal.log_upsert(self._epoch, ks, vectors, bitmaps)
            nsh = self.n_shards
            shard_of = (self._next_shard + np.arange(n)) % nsh
            gid0 = self._total_base + len(self._delta_loc)
            d0 = len(self._delta_loc)
            self._delta_loc.extend([None] * n)
            for s in range(nsh):
                rows = np.nonzero(shard_of == s)[0]
                if rows.size == 0:
                    continue
                start_local = self.shards[s]._delta.rows
                self.shards[s].upsert(vectors[rows], bitmaps[rows])
                for off, j in enumerate(rows):
                    self._delta_loc[d0 + int(j)] = (s, start_local + off)
                    self._shard_gids[s].append(gid0 + int(j))
            self._keys = np.concatenate([self._keys, ks])
            if self._key_rows is not None:
                self._key_rows.update(zip(ks.tolist(),
                                          range(gid0, gid0 + n)))
            self._gid_arrays = None           # searches rebuild lazily
            self._next_shard = (self._next_shard + n) % nsh
            return np.arange(gid0, gid0 + n, dtype=np.int64)

    def _claim_keys(self, keys, n: int) -> np.ndarray:
        """Validate/assign [n] global external keys (lock held)."""
        if keys is None:
            ks = np.arange(self._next_key, self._next_key + n,
                           dtype=np.int64)
        else:
            ks = np.atleast_1d(np.asarray(keys, dtype=np.int64))
            if ks.shape != (n,):
                raise ValueError(
                    f"upsert keys must be [{n}]; got shape {ks.shape}")
            if np.unique(ks).size != n:
                raise ValueError("upsert keys must be unique per batch")
            key_rows = self._key_index()
            for k in ks.tolist():
                gid = key_rows.get(k)
                if gid is not None and self._gid_live(gid):
                    raise ValueError(
                        f"key {k} already names a live row (id {gid}); "
                        f"delete it first to re-point the key")
        if n:
            self._next_key = max(self._next_key, int(ks.max()) + 1)
        return ks

    def _key_index(self) -> dict:
        if self._key_rows is None:
            n_tot = self._total_base + len(self._delta_loc)
            self._key_rows = dict(zip(self._keys[:n_tot].tolist(),
                                      range(n_tot)))
        return self._key_rows

    def _shard_local(self, gid: int) -> tuple[int, int]:
        """(shard, shard-local id) for a current-generation global id."""
        if gid < self._total_base:
            s = int(np.searchsorted(self.bounds, gid, side="right")) - 1
            return s, gid - int(self.bounds[s])
        s, row = self._delta_loc[gid - self._total_base]
        return s, self.shards[s].base_n + row

    def _gid_live(self, gid: int) -> bool:
        s, lid = self._shard_local(int(gid))
        return not self.shards[s]._tomb[lid]

    def delete(self, ids) -> int:
        """Tombstone global ids; returns the number newly deleted."""
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        with self._lock:
            self._check_open()
            n_tot = self._total_base + len(self._delta_loc)
            if ids.size and (ids.min() < 0 or ids.max() >= n_tot):
                raise IndexError(
                    f"delete ids must be in [0, {n_tot}); got range "
                    f"[{ids.min()}, {ids.max()}]")
            if self._wal is not None and not self._wal_quiet:
                self._wal.log_delete(self._epoch, ids)
            per: dict[int, list] = {}
            for gid in ids.tolist():
                s, lid = self._shard_local(gid)
                per.setdefault(s, []).append(lid)
            return sum(self.shards[s].delete(lids)
                       for s, lids in per.items())

    # ---- stable external keys -------------------------------------------
    def keys_of(self, ids, snapshot: "ShardedLiveSnapshot | None" = None
                ) -> np.ndarray:
        """Stable external keys for global ids (−1 stays −1); semantics
        as in `LiveFilteredIndex.keys_of`."""
        ids = np.asarray(ids, dtype=np.int64)
        if snapshot is not None:
            keys = snapshot.keys
        else:
            with self._lock:
                keys = self._keys[: self._total_base
                                  + len(self._delta_loc)]
        out = np.full(ids.shape, -1, dtype=np.int64)
        valid = ids >= 0
        if valid.any():
            out[valid] = keys[ids[valid]]
        return out

    def rows_of(self, keys) -> np.ndarray:
        """Current-generation global ids for external keys (−1 if never
        assigned)."""
        keys = np.atleast_1d(np.asarray(keys, dtype=np.int64))
        with self._lock:
            key_rows = self._key_index()
            return np.array([key_rows.get(int(k), -1) for k in keys],
                            dtype=np.int64)

    def delete_keys(self, keys) -> int:
        """Tombstone rows by stable key; unknown keys raise KeyError."""
        keys = np.atleast_1d(np.asarray(keys, dtype=np.int64))
        with self._lock:
            rows = self.rows_of(keys)
            if (rows < 0).any():
                missing = keys[rows < 0].tolist()
                raise KeyError(f"unknown external keys: {missing}")
            return self.delete(rows)

    # ---- durability hook (repro.ann.store) -------------------------------
    def attach_wal(self, wal) -> None:
        """Attach a write-ahead log at the sharded level (global ids and
        keys; per-shard handles stay WAL-less). See
        `LiveFilteredIndex.attach_wal`."""
        with self._lock:
            self._wal = wal

    # ---- read path -------------------------------------------------------
    def _map_shards(self, fn, items):
        if self._pool is not None:
            return list(self._pool.map(fn, items))
        return [fn(it) for it in items]

    def snapshot(self) -> ShardedLiveSnapshot:
        """Pin a consistent cross-shard read epoch (see
        `ShardedLiveSnapshot`); callers must `release()` it."""
        with self._lock:
            self._check_open()
            epoch = self._epoch
            shards = list(self.shards)
            bounds = self.bounds.copy()
            snaps = [s.snapshot() for s in shards]
            if self._gid_arrays is None:      # invalidated by upsert
                self._gid_arrays = [np.asarray(g, dtype=np.int64)
                                    for g in self._shard_gids]
            gmaps = self._gid_arrays
            n_tot = self._total_base + len(self._delta_loc)
            self._epoch_readers[epoch] = \
                self._epoch_readers.get(epoch, 0) + 1
            # keys slice is a view: _keys is reassigned, never mutated
            # in place (see LiveFilteredIndex.snapshot)
            return ShardedLiveSnapshot(self, epoch, shards, bounds,
                                       snaps, gmaps,
                                       self._keys[:n_tot],
                                       self._next_key,
                                       list(self._delta_loc),
                                       self._base_ds)

    def run_method(self, method, setting: ParamSetting, batch: QueryBatch,
                   *, snapshot: ShardedLiveSnapshot | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Raw sharded live execution: consistent per-shard snapshots,
        parallel fan-out, id globalisation (base via shard offsets,
        delta via the insertion-order map), `merge_topk` reduction.
        Pass `snapshot=` to pin several calls to one epoch."""
        self._check_open()
        snap = snapshot if snapshot is not None else self.snapshot()
        shards, bounds = snap.shards, snap.bounds
        snaps, gmaps = snap.snaps, snap.gmaps
        try:
            def shard_run(sv):
                # drain the shard's stage timings *in the worker thread*
                # (they live on a thread-local) and return them alongside
                out = sv[0].run_method(method, setting, batch,
                                       snapshot=sv[1])
                return out, sv[0].pop_stage_timings()

            ran = self._map_shards(shard_run, list(zip(shards, snaps)))
            per = [r for r, _ in ran]
            # shards overlap in wall-clock: report the slowest stage
            for key in ("base_s", "delta_s"):
                vals = [t.get(key, 0.0) for _, t in ran]
                if any(vals):
                    self._stage_add({key: max(vals)})
            t0 = time.perf_counter()
            parts = []
            for s, ((ids, raw), ssnap) in enumerate(zip(per, snaps)):
                ids = np.asarray(ids, dtype=np.int64)
                raw = np.asarray(raw, dtype=np.float32)
                out = np.full(ids.shape, -1, np.int64)
                is_base = (ids >= 0) & (ids < ssnap.base_n)
                out[is_base] = ids[is_base] + int(bounds[s])
                is_delta = ids >= ssnap.base_n
                if is_delta.any():
                    out[is_delta] = gmaps[s][ids[is_delta] - ssnap.base_n]
                parts.append((out.astype(np.int32), raw))
            gids, graw = merge_candidates(*stack_candidates(parts),
                                          k=batch.k)
            self._stage_add({"merge_s": time.perf_counter() - t0})
            return gids, graw
        finally:
            if snapshot is None:
                snap.release()

    def _release_epoch(self, epoch: int) -> None:
        with self._lock:
            left = self._epoch_readers.get(epoch, 0) - 1
            if left > 0:
                self._epoch_readers[epoch] = left
                return
            self._epoch_readers.pop(epoch, None)
            old = (self._old_shards.pop(epoch, None)
                   if epoch != self._epoch else None)
        if old:
            for s in old:
                s.close()

    def search(self, batch: QueryBatch, method,
               setting: ParamSetting | str | None = None) -> SearchResult:
        """Direct single-method sharded live search (no routing)."""
        self._check_open()
        if isinstance(method, str):
            reg = self._registry or registry_mod.default_registry()
            method = reg.get(method)
        if not isinstance(setting, ParamSetting):
            setting = resolve_setting(method, setting)
        self.pop_stage_timings()
        t0 = time.perf_counter()
        snap = self.snapshot()
        try:
            ids, raw = self.run_method(method, setting, batch,
                                       snapshot=snap)
            keys = self.keys_of(ids, snapshot=snap)
        finally:
            snap.release()
        dt = time.perf_counter() - t0
        timings = {"search_s": dt, "total_s": dt}
        timings.update(self.pop_stage_timings())
        return SearchResult(
            ids=ids, distances=exact_distances(raw, ids, batch.vectors),
            decisions=None, timings=timings, keys=keys)

    # ---- routing-feature freshness ---------------------------------------
    def live_stats(self) -> LiveStats:
        """Aggregate live-set summary across shards (one consistent
        epoch: shard stats and the base dataset are read under the same
        lock a compaction swap takes)."""
        with self._lock:
            per = [s.live_stats() for s in self.shards]
            base_ds = self._base_ds
        n_live = sum(p.n_live for p in per)
        counts = sum((p.label_freq * p.n_live for p in per),
                     np.zeros(self._universe))
        return LiveStats(
            n_live=n_live,
            label_freq=counts / max(n_live, 1),
            base_tomb_bitmaps=np.concatenate(
                [p.base_tomb_bitmaps for p in per]),
            delta_bitmaps=np.concatenate([p.delta_bitmaps for p in per]),
            base_ds=base_ds)

    # ---- compaction ------------------------------------------------------
    def compact(self, timeout: float | None = None) -> int:
        """Global rebuild + re-shard; blocks, returns the new epoch."""
        return self.compact_async().result(timeout=timeout)

    def compact_async(self) -> Future:
        """Background global compaction: merge every shard's surviving
        base + delta rows (in global id order) into one fresh dataset,
        re-shard it contiguously, swap the shard list atomically, and
        drain old-epoch readers before closing the old shards. Writes
        during the rebuild carry over exactly as in
        `LiveFilteredIndex.compact_async`."""
        with self._lock:
            self._check_open()
            if self._compacting is not None and not self._compacting.done():
                return self._compacting
            if self._compact_pool is None:
                self._compact_pool = ThreadPoolExecutor(
                    max_workers=1,
                    thread_name_prefix=f"compact-{self._name}")
            fut = self._compact_pool.submit(self._compact_job)
            self._compacting = fut
            return fut

    def _gather(self, snaps, locs):
        """Surviving rows in global id order + the kept-gid list."""
        vec_parts, bm_parts, kept = [], [], []
        for s, snap in enumerate(snaps):
            if snap.base_n == 0:
                continue
            keep = ~snap.tombstones[: snap.base_n]
            ds = self.shards[s]._base_for(snap).ds
            vec_parts.append(ds.vectors[keep])
            bm_parts.append(ds.bitmaps[keep])
            kept.append(int(self.bounds[s]) + np.nonzero(keep)[0])
        n_delta = len(locs)
        if n_delta:
            dvec = np.zeros((n_delta, self._dim), np.float32)
            dbm = np.zeros((n_delta, lb.n_words(self._universe)), np.uint32)
            alive = np.zeros(n_delta, bool)
            loc_shard = np.array([l[0] for l in locs], np.int64)
            loc_row = np.array([l[1] for l in locs], np.int64)
            for s, snap in enumerate(snaps):
                mine = loc_shard == s
                if not mine.any():
                    continue
                sv, sb, _ = snap.delta.host_view(snap.delta_rows)
                rows = loc_row[mine]
                dvec[mine] = sv[rows]
                dbm[mine] = sb[rows]
                alive[mine] = ~snap.tombstones[snap.base_n + rows]
            vec_parts.append(dvec[alive])
            bm_parts.append(dbm[alive])
            kept.append(self._total_base + np.nonzero(alive)[0])
        if vec_parts:
            return (np.concatenate(vec_parts), np.concatenate(bm_parts),
                    np.concatenate(kept))
        width = lb.n_words(self._universe)
        return (np.zeros((0, self._dim), np.float32),
                np.zeros((0, width), np.uint32),
                np.zeros(0, np.int64))

    def _compact_job(self) -> int:
        from repro.ann.distributed import shard_bounds

        snaps = None
        try:
            with self._lock:
                snaps = [s.snapshot() for s in self.shards]
                locs = list(self._delta_loc)
                old_total = self._total_base + len(locs)
                old_keys = self._keys[:old_total].copy()
                if self._wal is not None:
                    self._wal.log_compact(self._epoch)
            vectors, bitmaps, kept = self._gather(snaps, locs)
            new_ds, order = ANNDataset.from_packed(
                self._name, vectors, bitmaps, self._universe,
                return_order=True)
            inv = np.empty(order.size, np.int64)
            inv[order] = np.arange(order.size)
            remap = np.full(old_total, -1, np.int64)
            remap[kept] = inv
            new_keys = np.empty(new_ds.n, np.int64)
            new_keys[remap[kept]] = old_keys[kept]
            nsh = self.n_shards
            built = []
            for s in self.shards:
                built.extend(k for k in s.built_keys() if k not in built)
            if new_ds.n >= nsh:
                new_bounds = shard_bounds(new_ds.n, nsh)
                new_shards = [
                    LiveFilteredIndex(
                        new_ds.row_slice(int(a), int(b),
                                         name=f"{self._name}/shard{i}"),
                        registry=self._registry, device=self._devices[i],
                        delta_chunk=self._delta_chunk)
                    for i, (a, b) in enumerate(zip(new_bounds[:-1],
                                                   new_bounds[1:]))]
                new_base: ANNDataset | None = new_ds
            else:
                # fewer surviving rows than shards: restart from empty
                # shards and replay the rows as delta below
                new_bounds = np.zeros(nsh + 1, dtype=np.int64)
                new_shards = [
                    LiveFilteredIndex.empty(
                        f"{self._name}/shard{i}", self._dim,
                        self._universe, registry=self._registry,
                        device=self._devices[i],
                        delta_chunk=self._delta_chunk)
                    for i in range(nsh)]
                new_base = None
            for shard in new_shards:
                if shard._base_fx is None:
                    continue
                for m_name, build in built:
                    try:
                        shard._base_fx.get_index(m_name, build)
                    except KeyError:
                        pass
            with self._lock:
                if self._closed:
                    for s in new_shards:
                        s.close()
                    return self._epoch
                old_shards = self.shards
                old_locs_n = len(locs)
                tail = self._delta_loc[old_locs_n:]
                late_tomb: list[int] = []       # old gids deleted late
                for s, snap in enumerate(snaps):
                    cur = old_shards[s]._tomb
                    newly = cur[: snap.n_total] & ~snap.tombstones
                    lids = np.nonzero(newly)[0]
                    for lid in lids:
                        if lid < snap.base_n:
                            late_tomb.append(int(self.bounds[s]) + int(lid))
                        else:
                            row = int(lid) - snap.base_n
                            gid = self._shard_gids[s][row]
                            late_tomb.append(int(gid))
                # collect tail rows (upserted during the rebuild) in
                # global insertion order, with their current tombstones
                tail_rows = []
                for j, (s, row) in enumerate(tail):
                    shard = old_shards[s]
                    vec = shard._delta._vec[row]
                    bm = shard._delta._bm[row]
                    dead = bool(shard._tomb[shard.base_n + row])
                    tail_rows.append((vec, bm, dead))
                tail_keys = self._keys[old_total: old_total + len(tail)]
                old_epoch = self._epoch
                self.shards = new_shards
                self.bounds = new_bounds
                self._base_ds = new_base
                self._total_base = new_ds.n if new_base is not None else 0
                self._delta_loc = []
                self._shard_gids = [[] for _ in new_shards]
                self._gid_arrays = None
                self._next_shard = 0
                self._keys = (new_keys if new_base is not None
                              else np.zeros(0, np.int64))
                self._key_rows = None
                self._epoch = old_epoch + 1
                self._last_remap = remap
                self._features = None
                if self._feature_fx is not None:
                    self._feature_fx.close()
                    self._feature_fx = None
                # replay: rows that didn't make the snapshot (and every
                # row when the base fell below the shard count), carrying
                # their stable keys; the WAL stays quiet — these rows'
                # original upsert/delete records already cover them
                replay = []
                if new_base is None and new_ds.n:
                    replay.append((new_ds.vectors, new_ds.bitmaps, None,
                                   new_keys))
                if tail_rows:
                    replay.append((
                        np.stack([t[0] for t in tail_rows]),
                        np.stack([t[1] for t in tail_rows]),
                        np.array([t[2] for t in tail_rows], bool),
                        tail_keys))
                self._wal_quiet = True
                try:
                    for vecs, bms, dead, ks in replay:
                        gids = self.upsert(vecs, bms, keys=ks)
                        if dead is not None and dead.any():
                            self.delete(gids[dead])
                    if late_tomb:
                        ng = remap[np.asarray(late_tomb, np.int64)]
                        ng = ng[(ng >= 0) & (ng < self._total_base
                                             + len(self._delta_loc))]
                        if ng.size:
                            self.delete(ng)
                finally:
                    self._wal_quiet = False
                if self._epoch_readers.get(old_epoch):
                    self._old_shards[old_epoch] = old_shards
                else:
                    for s in old_shards:
                        s.close()
                return self._epoch
        finally:
            if snaps is not None:
                for snap in snaps:
                    snap.release()
            with self._lock:
                self._compacting = None

    # ---- maintenance -----------------------------------------------------
    def export_state(self, snap: ShardedLiveSnapshot) -> dict:
        """Full logical state of a pinned cross-shard epoch, in *global*
        id order — the same contract as `LiveFilteredIndex.export_state`
        (what a `repro.ann.store` checkpoint persists)."""
        base_n = int(snap.bounds[-1])
        n_delta = len(snap.locs)
        width = lb.n_words(self._universe)
        dvec = np.zeros((n_delta, self._dim), np.float32)
        dbm = np.zeros((n_delta, width), np.uint32)
        delta_dead = np.zeros(n_delta, bool)
        if n_delta:
            loc_shard = np.array([l[0] for l in snap.locs], np.int64)
            loc_row = np.array([l[1] for l in snap.locs], np.int64)
            for s, ssnap in enumerate(snap.snaps):
                mine = loc_shard == s
                if not mine.any():
                    continue
                sv, sb, _ = ssnap.delta.host_view(ssnap.delta_rows)
                rows = loc_row[mine]
                dvec[mine] = sv[rows]
                dbm[mine] = sb[rows]
                delta_dead[mine] = ssnap.tombstones[ssnap.base_n + rows]
        dead = [base_n + np.nonzero(delta_dead)[0]]
        for s, ssnap in enumerate(snap.snaps):
            lids = np.nonzero(ssnap.tombstones[: ssnap.base_n])[0]
            if lids.size:
                dead.append(int(snap.bounds[s]) + lids)
        return {
            "generation": snap.epoch,
            "base_ds": snap.base_ds,
            "base_keys": snap.keys[:base_n],
            "delta_vectors": dvec,
            "delta_bitmaps": dbm,
            "delta_keys": snap.keys[base_n:],
            "dead_ids": np.sort(np.concatenate(dead)).astype(np.int64),
            "next_key": snap.next_key,
        }

    def last_remap(self) -> np.ndarray | None:
        """Global-id translation of the most recent `compact()` (see
        `LiveFilteredIndex.last_remap`)."""
        return self._last_remap

    def stats(self) -> dict:
        with self._lock:
            return {
                "dataset": self._name,
                "generation": self._epoch,
                "n_shards": self.n_shards,
                "base_n": self._total_base,
                "delta_rows": len(self._delta_loc),
                "n_live": sum(s.n_live for s in self.shards),
                "next_key": self._next_key,
                "wal_attached": self._wal is not None,
                "compacting": (self._compacting is not None
                               and not self._compacting.done()),
                "closed": self._closed,
                "shards": [s.stats() for s in self.shards],
            }
