"""Live index subsystem — streaming upserts/deletes over a sealed base.

`LiveFilteredIndex` turns the frozen `FilteredIndex` serving handle into
a mutable one without giving up the batched read path:

* **delta segment** (`DeltaSegment`) — an append-only, host-growable
  store of upserted vectors/bitmaps, mirrored to the device in fixed
  `chunk`-row blocks (sealed chunks upload once; only the partial tail
  chunk re-uploads per search);
* **tombstone bitmap** — one bool per id over base + delta; `delete()`
  marks ids dead and bumps a version so snapshots stay consistent;
* **snapshot epochs** (`LiveSnapshot`) — a cheap consistent read view:
  the delta high-watermark plus a tombstone copy, pinned to its base
  *generation* so an in-flight batch keeps its base alive across a
  concurrent `compact()`;
* **background compaction** — `compact()` folds the surviving base and
  delta rows into a fresh group-sorted `ANNDataset` (the same
  construction `ANNDataset.build` uses, so upsert-everything-then-compact
  is bit-identical to building the index directly), rebuilds the old
  base's method indexes in a worker thread, and atomically swaps the
  base under the generation counter while old-epoch readers drain.

The read path runs the routed method on the base (overfetched by the
base tombstone count, capped at k — so up to k deletions ranked above a
query's live matches cannot crowd them out of the top-k; beyond that
the base segment degrades gracefully until `compact()` folds the
tombstones away, which is the intended cadence), then folds the base
candidates and the delta segment through **one fused Pallas launch**
(`ops.fused_live_topk`): the kernel scans the delta mirror block by
block, applies the packed tombstone bitmap to *both* candidate sets
in-kernel, and carries the running top-k in VMEM — no per-stage
overfetch on the delta, no `[S, Q, K]` HBM intermediate, no host merge.
Once the delta outgrows `delta_prune_min_rows`, sealed chunks' mini-IVF
indexes (`ChunkIndex`, built once at chunk-seal time) prune clusters
whose exact ball bound proves they cannot reach any query's top-k, so
the scan stops being full brute force; the partial tail chunk is always
scanned. The pre-PR-6 three-stage path (`masked_topk` overfetch + host
tombstone mask + `merge_topk`) survives as `_run_staged` — a parity
reference, bit-identical to the fused path. Ids are per-generation row
ids: base rows keep their dataset row id, delta rows take
`base_n + insertion_order`; compaction remaps both
(`stats()["generation"]` tells epochs apart).

Compaction **grafts** instead of rebuilding where it can: each built
method index of the old base is spliced onto the compacted dataset via
`Method.graft_index` (IVF posting lists carry surviving rows through
the id remap with frozen centroids; graph methods remap their edge
lists and attach the delta rows), falling back to a full build for
methods that don't implement grafting — making compaction cost
sublinear in base size for the grafted methods.

`ShardedLiveIndex` scales the same surface across row shards: upserts
round-robin over per-shard delta segments, per-shard ids globalise
through the shard row offsets (base) and a global insertion-order map
(delta), and `RouterService`/`AsyncBatchQueue` serve either handle
unchanged. Routing features stay fresh through the `live_stats()`
protocol `repro.core.features` consumes (live per-label counts and
exact live selectivity corrections).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

from repro.ann import engine as engine_mod
from repro.ann import labels as lb
from repro.ann import ledger as ledger_mod
from repro.ann import registry as registry_mod
from repro.ann import trace
from repro.ann.dataset import ANNDataset
from repro.ann.engine import ParamSetting, resolve_setting
from repro.ann.index import (FilteredIndex, QueryBatch, SearchResult,
                             exact_distances)
from repro.ann.predicates import Predicate
from repro.ann.sharded import merge_candidates, stack_candidates

DEFAULT_DELTA_CHUNK = 512


def _bucket(k: int, mult: int = 8) -> int:
    """Round up to a multiple of `mult` — the overfetch width follows the
    tombstone count, and bucketing it bounds jit recompilations."""
    return ((int(k) + mult - 1) // mult) * mult


def _label_counts(bitmaps: np.ndarray, universe: int,
                  weights: np.ndarray | None = None) -> np.ndarray:
    """[U] per-label carrier counts from packed [N, W] bitmaps."""
    if bitmaps.shape[0] == 0:
        return np.zeros(universe, dtype=np.int64)
    shifts = np.arange(32, dtype=np.uint32)
    bits = ((bitmaps[:, :, None] >> shifts) & np.uint32(1)).astype(np.int64)
    bits = bits.reshape(bitmaps.shape[0], -1)[:, :universe]
    if weights is not None:
        bits = weights[:, None] * bits
    return bits.sum(0)


class KeyTable:
    """Vectorised open-addressing map: int64 external key -> int64 row.

    Replaces the per-key Python dict in the live key→row table. Lookups
    and inserts run as numpy linear-probe loops over whole batches, so
    `rows_of`/`delete_keys` stay flat (a handful of vectorised probe
    rounds) for multi-million-row deltas instead of one dict op per key.
    Power-of-two table kept at ≤ 0.5 load; re-inserting an existing key
    overwrites its row (a re-used key maps to its newest row).
    """

    __slots__ = ("_keys", "_rows", "_used", "_mask", "_count")

    def __init__(self, capacity_hint: int = 64):
        size = 1 << max(4, int(2 * max(capacity_hint, 1) - 1).bit_length())
        self._keys = np.zeros(size, np.int64)
        self._rows = np.zeros(size, np.int64)
        self._used = np.zeros(size, bool)
        self._mask = size - 1
        self._count = 0

    def __len__(self) -> int:
        return self._count

    @staticmethod
    def _hash(keys: np.ndarray, mask: int) -> np.ndarray:
        """splitmix64 finalizer — avalanche for sequential key ranges."""
        h = keys.astype(np.uint64)
        h = (h ^ (h >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        h = (h ^ (h >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        h = h ^ (h >> np.uint64(31))
        return (h & np.uint64(mask)).astype(np.int64)

    def _grow_to(self, need: int) -> None:
        if 2 * need <= self._mask + 1:
            return
        old_keys = self._keys[self._used]
        old_rows = self._rows[self._used]
        size = 1 << int(2 * need - 1).bit_length()
        self._keys = np.zeros(size, np.int64)
        self._rows = np.zeros(size, np.int64)
        self._used = np.zeros(size, bool)
        self._mask = size - 1
        self._count = 0
        if old_keys.size:
            self.insert(old_keys, old_rows)

    def insert(self, keys, rows) -> None:
        """Batch upsert. Duplicate keys *within* one batch resolve
        last-wins (callers pass unique keys; upsert validates)."""
        keys = np.ascontiguousarray(keys, dtype=np.int64)
        rows = np.ascontiguousarray(rows, dtype=np.int64)
        if keys.size == 0:
            return
        self._grow_to(self._count + keys.size)
        idx = self._hash(keys, self._mask)
        pending = np.arange(keys.size)
        guard = 0
        while pending.size:
            cur = idx[pending]
            used = self._used[cur]
            ours = used & (self._keys[cur] == keys[pending])
            attempt = ~used | ours
            if attempt.any():
                a = pending[attempt]
                c = cur[attempt]
                was_free = ~used[attempt]
                self._keys[c] = keys[a]
                self._rows[c] = rows[a]
                self._used[c] = True
                # entries that lost a same-slot race re-probe; numpy
                # duplicate-index assignment leaves the last writer's key
                won = self._keys[c] == keys[a]
                self._rows[c[won]] = rows[a[won]]
                self._count += int((was_free & won).sum())
                done = np.zeros(pending.size, bool)
                done[np.nonzero(attempt)[0][won]] = True
                pending = pending[~done]
            idx[pending] = (idx[pending] + 1) & self._mask
            guard += 1
            if guard > self._mask + 2:       # load ≤ 0.5 makes this unreachable
                raise RuntimeError("KeyTable probe loop did not terminate")

    def lookup(self, keys) -> np.ndarray:
        """[R] rows for keys; −1 where the key was never inserted."""
        keys = np.ascontiguousarray(keys, dtype=np.int64)
        out = np.full(keys.shape, -1, np.int64)
        if keys.size == 0 or self._count == 0:
            return out
        idx = self._hash(keys, self._mask)
        pending = np.arange(keys.size)
        guard = 0
        while pending.size:
            cur = idx[pending]
            used = self._used[cur]
            hit = used & (self._keys[cur] == keys[pending])
            out[pending[hit]] = self._rows[cur[hit]]
            pending = pending[used & ~hit]    # empty slot ⇒ key absent
            idx[pending] = (idx[pending] + 1) & self._mask
            guard += 1
            if guard > self._mask + 2:
                raise RuntimeError("KeyTable probe loop did not terminate")
        return out


@dataclasses.dataclass(frozen=True)
class ChunkIndex:
    """Mini-IVF over one sealed delta chunk: coarse k-means centroids
    plus chunk-local posting lists, built once at chunk-seal time.

    `radius[c]` upper-bounds (in f64, rounded up) the L2 distance from
    `centroids[c]` to every member, so `max(0, ‖q−c‖ − radius)²` is an
    exact lower bound on any member's squared distance to q — the
    pruning test the fused read path uses. Chunks are immutable once
    sealed, so the index never updates.

    `label_union[c]` / `label_inter[c]` are the bitwise OR / AND of the
    members' label bitmaps — exact label bounds, so a cluster that
    *cannot* contain a predicate-matching row is pruned even when the
    distance bound can't fire (e.g. a query with < k live base
    candidates). Both are None on indexes persisted before the fields
    existed; such chunks simply skip label pruning."""

    centroids: np.ndarray   # [C, d] f32
    cnorms: np.ndarray      # [C] f64 squared centroid norms
    radius: np.ndarray      # [C] f64 cover radii (rounded up)
    members: np.ndarray     # [chunk] i32 chunk-local rows, cluster-grouped
    starts: np.ndarray      # [C+1] i32 posting-list offsets into members
    label_union: np.ndarray | None = None   # [C, W] u32 OR of member labels
    label_inter: np.ndarray | None = None   # [C, W] u32 AND of member labels

    def arrays(self) -> dict:
        out = {"centroids": self.centroids, "cnorms": self.cnorms,
               "radius": self.radius, "members": self.members,
               "starts": self.starts}
        if self.label_union is not None:
            out["label_union"] = self.label_union
            out["label_inter"] = self.label_inter
        return out

    @classmethod
    def from_arrays(cls, arrays: dict) -> "ChunkIndex":
        out = {f: np.asarray(arrays[f])
               for f in ("centroids", "cnorms", "radius",
                         "members", "starts")}
        # label bounds are optional: pre-existing persisted chunk
        # indexes lack them and just forgo label pruning
        for f in ("label_union", "label_inter"):
            if f in arrays:
                out[f] = np.asarray(arrays[f])
        return cls(**out)


def build_chunk_index(vectors: np.ndarray, *, bitmaps: np.ndarray = None,
                      n_clusters: int = 8, seed: int = 0) -> ChunkIndex:
    """Build the mini-IVF for one sealed chunk (deterministic per seed,
    so a persisted chunk index equals a rebuilt one). With `bitmaps`
    ([n, W] u32 member label bitmaps) the index also carries exact
    per-cluster label union/intersection bounds for predicate pruning."""
    from repro.ann.ivf import assign_to_centroids, kmeans

    n = vectors.shape[0]
    c = max(1, min(int(n_clusters), n))
    cent = kmeans(vectors, c, iters=4, seed=seed)
    assign = assign_to_centroids(vectors, cent)
    order = np.argsort(assign, kind="stable").astype(np.int32)
    lens = np.bincount(assign, minlength=cent.shape[0])
    starts = np.zeros(cent.shape[0] + 1, np.int32)
    starts[1:] = np.cumsum(lens)
    centf = cent.astype(np.float64)
    diff = vectors.astype(np.float64) - centf[assign]
    dist = np.sqrt((diff ** 2).sum(axis=1))
    radius = np.zeros(cent.shape[0], np.float64)
    np.maximum.at(radius, assign, dist)
    radius = radius * (1.0 + 1e-9) + 1e-9    # round up: bound must hold
    union = inter = None
    if bitmaps is not None:
        nc = cent.shape[0]
        w = bitmaps.shape[1]
        union = np.zeros((nc, w), np.uint32)
        # empty clusters read as union=0 / inter=~0: every label test
        # then prunes them, which is safe (their posting list is empty)
        inter = np.full((nc, w), np.uint32(0xFFFFFFFF))
        np.bitwise_or.at(union, assign, bitmaps.astype(np.uint32))
        np.bitwise_and.at(inter, assign, bitmaps.astype(np.uint32))
    return ChunkIndex(cent.astype(np.float32), (centf ** 2).sum(axis=1),
                      radius, order, starts, union, inter)


@dataclasses.dataclass(frozen=True)
class LiveStats:
    """Live-set summary the routing features consume (see
    `repro.core.features`): exact live size, per-label carrier
    fractions, and the bitmap rows needed to correct base selectivity
    counts (subtract tombstoned base rows, add live delta rows).
    `base_ds` is the sealed base the tombstone rows refer to — the
    feature layer counts base matches against *it*, so a compaction
    racing the feature pass can't pair generation-g corrections with a
    generation-g+1 base."""
    n_live: int
    label_freq: np.ndarray          # [U] live per-label carrier fractions
    base_tomb_bitmaps: np.ndarray   # [Tb, W] bitmaps of dead base rows
    delta_bitmaps: np.ndarray       # [Dl, W] bitmaps of live delta rows
    base_ds: object = None          # ANNDataset of this snapshot's base


class DeltaSegment:
    """Append-only host store with a chunked device mirror.

    Host arrays grow by doubling; rows never mutate once appended, so
    concurrent readers can slice up to their snapshot watermark without
    locking. The device mirror covers whole `chunk`-row blocks of
    appended data and is extended (one upload per new block) under a
    private lock; `device_view` pads the partial tail chunk with
    sentinel rows (zero vector + `PAD_SCORE` norm — never selected by
    `masked_topk`) so the kernel sees shapes that change only at chunk
    boundaries.
    """

    def __init__(self, dim: int, width: int, *,
                 chunk: int = DEFAULT_DELTA_CHUNK):
        self.dim = int(dim)
        self.width = int(width)
        self.chunk = max(1, int(chunk))
        self._vec = np.empty((0, self.dim), np.float32)
        self._bm = np.empty((0, self.width), np.uint32)
        self._norms = np.empty((0,), np.float32)
        self._rows = 0
        self._dev = None            # (vectors, norms, bitmaps) jax arrays
        self._dev_rows = 0          # rows covered by the mirror
        self._dev_lock = threading.Lock()
        self._view_cache = None     # (rows, assembled device triple)
        self._chunk_idx: list[ChunkIndex] = []   # mini-IVF per sealed chunk

    @property
    def rows(self) -> int:
        return self._rows

    def _grow(self, need: int) -> None:
        cap = self._vec.shape[0]
        if need <= cap:
            return
        new_cap = max(need, max(self.chunk, 2 * cap))
        for name, fill_shape in (("_vec", (new_cap, self.dim)),
                                 ("_bm", (new_cap, self.width)),
                                 ("_norms", (new_cap,))):
            old = getattr(self, name)
            new = np.zeros(fill_shape, old.dtype)
            new[: self._rows] = old[: self._rows]
            setattr(self, name, new)

    def append(self, vectors: np.ndarray,
               bitmaps: np.ndarray) -> tuple[int, int]:
        """Append rows; returns the local id range [start, stop)."""
        n = vectors.shape[0]
        start = self._rows
        self._grow(start + n)
        self._vec[start: start + n] = vectors
        self._bm[start: start + n] = bitmaps
        self._norms[start: start + n] = np.sum(
            vectors.astype(np.float64) ** 2, axis=1).astype(np.float32)
        self._rows = start + n
        return start, start + n

    def host_view(self, rows: int):
        """(vectors, bitmaps, norms) for the first `rows` rows (views —
        valid for any watermark that was reached before the call)."""
        return self._vec[:rows], self._bm[:rows], self._norms[:rows]

    def device_view(self, rows: int, scope):
        """Device tensors covering the first `rows` rows, padded to a
        chunk multiple with never-selected sentinel rows. `scope` is a
        zero-arg context factory placing uploads (the owning handle's
        `_device_scope`)."""
        import jax.numpy as jnp

        from repro.kernels import masked_topk as mk

        full = (rows // self.chunk) * self.chunk
        with self._dev_lock:
            # read-mostly fast path: the assembled triple (including the
            # padded tail) only depends on the watermark, so repeated
            # searches between writes skip the tail rebuild + re-upload
            if self._view_cache is not None and self._view_cache[0] == rows:
                return self._view_cache[1]
            if full > self._dev_rows:
                with scope():
                    vec = jnp.asarray(self._vec[self._dev_rows: full])
                    bm = jnp.asarray(self._bm[self._dev_rows: full])
                    nm = jnp.asarray(self._norms[self._dev_rows: full])
                    if self._dev is None:
                        self._dev = (vec, nm, bm)
                    else:
                        self._dev = (
                            jnp.concatenate([self._dev[0], vec]),
                            jnp.concatenate([self._dev[1], nm]),
                            jnp.concatenate([self._dev[2], bm]))
                self._dev_rows = full
            dev = self._dev
        parts_v = [dev[0][:full]] if full else []
        parts_n = [dev[1][:full]] if full else []
        parts_b = [dev[2][:full]] if full else []
        tail = rows - full
        if tail:
            tv = np.zeros((self.chunk, self.dim), np.float32)
            tb = np.zeros((self.chunk, self.width), np.uint32)
            tn = np.full((self.chunk,), mk.PAD_SCORE, np.float32)
            tv[:tail] = self._vec[full:rows]
            tb[:tail] = self._bm[full:rows]
            tn[:tail] = self._norms[full:rows]
            with scope():
                parts_v.append(jnp.asarray(tv))
                parts_n.append(jnp.asarray(tn))
                parts_b.append(jnp.asarray(tb))
        if not parts_v:
            view = (jnp.zeros((0, self.dim), jnp.float32),
                    jnp.zeros((0,), jnp.float32),
                    jnp.zeros((0, self.width), jnp.uint32))
        elif len(parts_v) == 1:
            view = (parts_v[0], parts_n[0], parts_b[0])
        else:
            view = (jnp.concatenate(parts_v), jnp.concatenate(parts_n),
                    jnp.concatenate(parts_b))
        with self._dev_lock:
            # the row prefix below `rows` is immutable, so the view only
            # depends on the watermark — safe to reuse until it moves
            self._view_cache = (rows, view)
        return view

    def device_rows(self) -> int:
        return self._dev_rows

    def host_bytes(self) -> int:
        """Allocated host backing (includes growth headroom)."""
        return self._vec.nbytes + self._bm.nbytes + self._norms.nbytes

    def device_bytes(self) -> int:
        """Mirror footprint: vectors + norms + bitmaps per covered row."""
        return self._dev_rows * (self.dim * 4 + 4 + self.width * 4)

    def drop_device(self) -> None:
        with self._dev_lock:
            self._dev = None
            self._dev_rows = 0
            self._view_cache = None

    # ---- per-chunk mini-IVF ---------------------------------------------
    def chunk_indexes(self, rows: int) -> list[ChunkIndex]:
        """ChunkIndex list covering the sealed chunks below `rows`.

        Built lazily on first request after a chunk seals (≈ one tiny
        k-means per `chunk` appended rows) and cached forever — sealed
        chunks are immutable. Store restores short-circuit the build via
        `adopt_chunk_indexes`."""
        want = int(rows) // self.chunk
        if want <= 0:
            return []
        with self._dev_lock:
            vec = self._vec        # row prefix is immutable; see host_view
            bm = self._bm
            while len(self._chunk_idx) < want:
                i = len(self._chunk_idx)
                lo = i * self.chunk
                self._chunk_idx.append(build_chunk_index(
                    vec[lo: lo + self.chunk],
                    bitmaps=bm[lo: lo + self.chunk], seed=i))
            return self._chunk_idx[:want]

    def adopt_chunk_indexes(self, indexes: dict[int, ChunkIndex]) -> None:
        """Install persisted chunk indexes (the store's restore path).
        Only a contiguous prefix extension of already-built chunks is
        accepted; anything else is rebuilt lazily instead."""
        with self._dev_lock:
            sealed = self._rows // self.chunk
            for i in sorted(indexes):
                if i == len(self._chunk_idx) and i < sealed:
                    self._chunk_idx.append(indexes[i])

    def built_chunk_indexes(self) -> list[ChunkIndex]:
        """The chunk indexes built so far (no building)."""
        with self._dev_lock:
            return list(self._chunk_idx)


class _StageTimings:
    """Instance facade over the engine-level thread-local stage-timing
    accumulator (`repro.ann.engine.StageTimings`): `run_method` calls
    `_stage_add`, the service layer drains with `pop_stage_timings`
    (per thread, so pipelined queue workers don't cross-contaminate).
    The accumulator itself lives in `engine` so kernel wrappers and
    other layers can contribute stages without importing this module."""

    def _stage_add(self, d: dict) -> None:
        for key, val in d.items():
            engine_mod.stage_add(key, val)

    def pop_stage_timings(self) -> dict:
        """Return and clear this thread's accumulated stage timings."""
        return engine_mod.pop_stage_timings()


class _LabelClockMixin:
    """Monotone per-label write clock shared by `LiveFilteredIndex` and
    `ShardedLiveIndex` — the invalidation signal the semantic result
    cache (`repro.ann.cache`) keys on.

    Every `upsert`/`delete` bumps a global write counter and stamps the
    labels present in the written rows with it. A cached entry recorded
    at clock `c` for query labels `L` is provably unaffected by later
    writes iff `label_clock(L) <= c`: any row that can match an
    EQUALITY/AND/OR predicate over a non-empty query label set carries
    at least one of those labels, so writing it stamps them. Entries
    with an *empty* query bitmap (AND matches every row) compare
    against the global clock instead (`label_clock(None)`).

    Concrete classes provide `_lock` and `_universe` and call
    `_clock_init()` in `__init__` and `_clock_touch(counts)` under the
    lock on every write. Compaction does not touch the clock: it remaps
    ids but never changes the live row set."""

    def _clock_init(self) -> None:
        self._label_stamps = np.zeros(self._universe, dtype=np.int64)
        self._write_clock = 0

    def _clock_touch(self, counts: np.ndarray) -> None:
        """Stamp the labels with nonzero `counts` ([U] per-label row
        counts of the written rows); caller holds the lock."""
        self._write_clock += 1
        touched = np.nonzero(counts)[0]
        if touched.size:
            self._label_stamps[touched] = self._write_clock

    def label_clock(self, labels=None) -> int:
        """The latest write clock that touched any of `labels` (int
        indices), or the global write clock when `labels` is None/empty.
        Monotone; 0 means "never written"."""
        with self._lock:
            if labels is None:
                return self._write_clock
            labels = np.asarray(labels, dtype=np.int64)
            if labels.size == 0:
                return self._write_clock
            return int(self._label_stamps[labels].max())


class _StableKeyMixin:
    """Stable external-key plumbing shared by `LiveFilteredIndex` and
    `ShardedLiveIndex` (it had drifted into two near-identical copies).

    Concrete classes provide `_lock`, `_keys`, `_next_key`, `n_total`,
    `delete(rows)`, and `_row_live(rows) -> bool[R]`; the mixin owns the
    `KeyTable` lifecycle (`_key_rows`, built lazily by `_key_index`,
    extended incrementally via `_note_new_keys` on upsert, dropped to
    None at the compaction swap) and the public key API."""

    def _key_index(self) -> KeyTable:
        """key -> current-generation row table (caller holds the lock).
        Re-used keys map to their newest row."""
        if self._key_rows is None:
            n_tot = self.n_total
            table = KeyTable(max(n_tot, 64))
            if n_tot:
                table.insert(self._keys[:n_tot],
                             np.arange(n_tot, dtype=np.int64))
            self._key_rows = table
        return self._key_rows

    def _note_new_keys(self, ks: np.ndarray, start_row: int) -> None:
        """Extend the key table for freshly appended rows (lock held;
        no-op while the table hasn't been built)."""
        if self._key_rows is not None and ks.size:
            self._key_rows.insert(
                ks, np.arange(start_row, start_row + ks.size,
                              dtype=np.int64))

    def _claim_keys(self, keys, n: int) -> np.ndarray:
        """Validate/assign [n] external keys (caller holds the lock)."""
        if keys is None:
            ks = np.arange(self._next_key, self._next_key + n,
                           dtype=np.int64)
        else:
            ks = np.atleast_1d(np.asarray(keys, dtype=np.int64))
            if ks.shape != (n,):
                raise ValueError(
                    f"upsert keys must be [{n}]; got shape {ks.shape}")
            if np.unique(ks).size != n:
                raise ValueError("upsert keys must be unique per batch")
            rows = self._key_index().lookup(ks)
            known = rows >= 0
            if known.any():
                live = self._row_live(rows[known])
                if live.any():
                    bad_key = int(ks[known][live][0])
                    bad_row = int(rows[known][live][0])
                    raise ValueError(
                        f"key {bad_key} already names a live row (id "
                        f"{bad_row}); delete it first to re-point the key")
        if n:
            self._next_key = max(self._next_key, int(ks.max()) + 1)
        return ks

    def keys_of(self, ids, snapshot=None) -> np.ndarray:
        """Stable external keys for (current-generation or snapshot)
        ids: int64 array of `ids`' shape, −1 where the id is −1. Keys
        survive `compact()` and a `repro.ann.store` round trip;
        per-generation ids do not."""
        ids = np.asarray(ids, dtype=np.int64)
        if snapshot is not None:
            keys = snapshot.keys
        else:
            with self._lock:
                keys = self._keys[: self.n_total]
        out = np.full(ids.shape, -1, dtype=np.int64)
        valid = ids >= 0
        if valid.any():
            out[valid] = keys[ids[valid]]
        return out

    def rows_of(self, keys) -> np.ndarray:
        """Current-generation ids for external keys (−1 for a key that
        has never been assigned). A re-used key maps to its newest
        row."""
        keys = np.atleast_1d(np.asarray(keys, dtype=np.int64))
        with self._lock:
            return self._key_index().lookup(keys)

    def delete_keys(self, keys) -> int:
        """Tombstone rows by stable external key; unknown keys raise
        KeyError. Returns the number of newly deleted rows."""
        keys = np.atleast_1d(np.asarray(keys, dtype=np.int64))
        with self._lock:
            rows = self.rows_of(keys)
            if (rows < 0).any():
                missing = keys[rows < 0].tolist()
                raise KeyError(f"unknown external keys: {missing}")
            return self.delete(rows)


class LiveSnapshot:
    """Consistent read epoch over a `LiveFilteredIndex`.

    Captures the delta high-watermark, a tombstone copy, the external-key
    prefix, and the base generation — and *pins* that generation (the
    sealed base handle stays open) until `release()` / the context
    manager exits. Searches that are handed a snapshot see exactly this
    state regardless of concurrent `upsert`/`delete`/`compact` calls.
    """

    __slots__ = ("generation", "base_n", "delta_rows", "tombstones",
                 "tombstone_version", "delta", "keys", "next_key",
                 "_owner", "_released", "_lease")

    def __init__(self, owner, generation, base_n, delta_rows, tombstones,
                 tombstone_version, delta, keys, next_key):
        self.generation = generation
        self.base_n = base_n
        self.delta_rows = delta_rows
        self.tombstones = tombstones
        self.tombstone_version = tombstone_version
        self.delta = delta
        self.keys = keys
        self.next_key = next_key
        self._owner = owner
        self._released = False
        self._lease = None          # ledger pin, set by snapshot()

    @property
    def n_total(self) -> int:
        return self.base_n + self.delta_rows

    @property
    def n_live(self) -> int:
        return self.n_total - int(self.tombstones.sum())

    def release(self) -> None:
        """Unpin the snapshot's generation (idempotent, thread-safe). A
        drained, superseded generation frees its base handle here."""
        with self._owner._lock:        # flag flip atomic wrt double release
            if self._released:
                return
            self._released = True
        if self._lease is not None:
            self._lease.release()
        self._owner._release_reader(self.generation)

    def __enter__(self) -> "LiveSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return (f"LiveSnapshot(gen={self.generation}, base_n={self.base_n}, "
                f"delta_rows={self.delta_rows}, "
                f"tombstones={int(self.tombstones.sum())})")


class LiveFilteredIndex(_StableKeyMixin, _LabelClockMixin, _StageTimings):
    """Mutable serving handle: sealed base + delta segment + tombstones.

    Args:
        ds: the sealed base dataset, or None for an empty live index
            (then `name`/`dim`/`universe` are required — e.g. via the
            `empty` constructor). Routed serving (`RouterService`) needs
            a non-empty base for its dataset-level features; direct
            method search works from empty.
        registry: optional `MethodRegistry` for method-name resolution.
        device: optional jax device pin (forwarded to the base handle
            and the delta mirror uploads).
        delta_chunk: delta device-mirror block size in rows.
        base_keys: optional [N] int64 stable external keys for the base
            rows (defaults to the row ids 0..N-1). `repro.ann.store`
            passes the persisted per-generation key map here on reopen.
        next_key: first key `upsert` auto-assigns (defaults past the
            largest base key).
        generation: starting generation counter (restored stores resume
            at the persisted generation instead of 0).
        fused: serve reads through the single-launch fused kernel
            (default). False falls back to the three-stage parity path
            (`_run_staged`) — bit-identical, slower.
        graft: let `compact()` splice built method indexes through
            `Method.graft_index` instead of rebuilding (default). False
            forces the full rebuild.
        delta_prune_min_rows: delta size above which the sealed-chunk
            mini-IVF pruner engages (default `4 * delta_chunk`; the
            ball-bound test isn't worth its host matmul below that).
    """

    def __init__(self, ds: ANNDataset | None = None, *, name: str | None = None,
                 dim: int | None = None, universe: int | None = None,
                 registry=None, device=None,
                 delta_chunk: int = DEFAULT_DELTA_CHUNK,
                 base_keys: np.ndarray | None = None,
                 next_key: int | None = None, generation: int = 0,
                 fused: bool = True, graft: bool = True,
                 delta_prune_min_rows: int | None = None):
        if ds is None:
            if name is None or dim is None or universe is None:
                raise ValueError(
                    "an empty LiveFilteredIndex needs name=, dim= and "
                    "universe= (or pass a base ANNDataset)")
            self._name, self._dim = str(name), int(dim)
            self._universe = int(universe)
            self._width = lb.n_words(self._universe)
            self._base_fx: FilteredIndex | None = None
            self._base_n = 0
            base_counts = np.zeros(self._universe, dtype=np.int64)
        else:
            self._name, self._dim = ds.name, ds.dim
            self._universe = ds.universe
            self._width = ds.bitmaps.shape[1]
            self._base_fx = FilteredIndex(ds, registry=registry,
                                          device=device)
            self._base_n = ds.n
            base_counts = _label_counts(
                ds.group_bitmaps, ds.universe,
                weights=ds.group_size.astype(np.int64))
        self._registry = registry
        self._placement = device
        self._delta_chunk = int(delta_chunk)
        self._delta = DeltaSegment(self._dim, self._width, chunk=delta_chunk)
        self._tomb = np.zeros(self._base_n, bool)
        self._tomb_version = 0
        self._live_label_counts = base_counts
        self._clock_init()
        self._generation = int(generation)
        if base_keys is None:
            self._keys = np.arange(self._base_n, dtype=np.int64)
        else:
            self._keys = np.asarray(base_keys, dtype=np.int64).copy()
            if self._keys.shape != (self._base_n,):
                raise ValueError(
                    f"base_keys must be [{self._base_n}]; got shape "
                    f"{self._keys.shape}")
        self._next_key = int(next_key) if next_key is not None else \
            (int(self._keys.max()) + 1 if self._base_n else 0)
        self._key_rows: KeyTable | None = None   # built lazily
        self._wal = None                      # attached write-ahead log
        self._lock = threading.RLock()
        self._readers: dict[int, int] = {}      # generation -> pin count
        self._retired: dict[int, FilteredIndex | None] = {}
        self._retired_leases: dict[int, object] = {}   # gen -> ledger lease
        self._compact_pool: ThreadPoolExecutor | None = None
        self._compacting: Future | None = None
        self._last_remap: np.ndarray | None = None
        self._features = None       # repro.core.features cache slot
        self.fused = bool(fused)
        self._graft = bool(graft)
        self._delta_prune_min_rows = (4 * self._delta_chunk
                                      if delta_prune_min_rows is None
                                      else int(delta_prune_min_rows))
        self._tomb_words_cache = None   # ((gen, version, n_pad), device arr)
        self._prune_stats = {"calls": 0, "clusters": 0, "pruned": 0,
                             "label_pruned": 0}
        self._closed = False
        # delta/device bytes + reader pins as pull gauges on the process
        # ledger (collected only at scrape/snapshot time)
        self._ledger_key = f"live:{self._name}:{id(self):x}"
        ledger_mod.get_ledger().register_collector(
            self._ledger_key, self._ledger_gauges)

    def _ledger_gauges(self) -> dict:
        with self._lock:
            if self._closed:
                return {"closed": 1}
            d = self._delta
            return {"generation": self._generation,
                    "delta_rows": d.rows,
                    "delta_host_bytes": d.host_bytes(),
                    "delta_device_rows": d.device_rows(),
                    "delta_device_bytes": d.device_bytes(),
                    "tombstones": int(self._tomb.sum()),
                    "pinned_readers": sum(self._readers.values()),
                    "retired_generations": len(self._retired)}

    @classmethod
    def empty(cls, name: str, dim: int, universe: int,
              **kw) -> "LiveFilteredIndex":
        """A live index with no sealed base — everything starts as delta."""
        return cls(None, name=name, dim=dim, universe=universe, **kw)

    # ---- lifecycle ------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def ds(self) -> ANNDataset | None:
        """The current generation's sealed base dataset (None when the
        index started empty and has not compacted yet)."""
        fx = self._base_fx
        return None if fx is None else fx.ds

    @property
    def generation(self) -> int:
        return self._generation

    @property
    def base_n(self) -> int:
        return self._base_n

    @property
    def n_total(self) -> int:
        return self._base_n + self._delta.rows

    @property
    def n_live(self) -> int:
        with self._lock:
            return self.n_total - int(self._tomb.sum())

    @property
    def device(self):
        """Base device tensors (routing-feature kernels). Requires a
        non-empty base."""
        if self._base_fx is None:
            raise RuntimeError(
                f"LiveFilteredIndex({self._name!r}) has no sealed base yet "
                f"(compact() first, or serve it unrouted)")
        return self._base_fx.device

    def close(self) -> None:
        """Stop the handle: wait out a running compaction (its swap is
        skipped once closed), close the base of every generation, drop
        the delta device mirror. Idempotent."""
        ledger_mod.get_ledger().deregister_collector(self._ledger_key)
        with self._lock:
            if self._closed:
                return
            self._closed = True
            comp = self._compacting
        if comp is not None:
            try:
                comp.result(timeout=300)
            except BaseException:
                pass
        with self._lock:
            if self._base_fx is not None:
                self._base_fx.close()
            for fx in self._retired.values():
                if fx is not None:
                    fx.close()
            self._retired.clear()
            for lease in self._retired_leases.values():
                lease.release()
            self._retired_leases.clear()
            self._delta.drop_device()
            self._features = None
        if self._compact_pool is not None:
            self._compact_pool.shutdown(wait=True)
            self._compact_pool = None

    def __enter__(self) -> "LiveFilteredIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"LiveFilteredIndex({self._name!r}) is closed")

    def _device_scope(self):
        import contextlib

        if self._placement is None:
            return contextlib.nullcontext()
        import jax

        return jax.default_device(self._placement)

    # ---- write path -----------------------------------------------------
    def upsert(self, vectors, bitmaps, *, keys=None) -> np.ndarray:
        """Append rows to the delta segment.

        Args:
            vectors: [R, d] (or [d]) float embeddings.
            bitmaps: [R, W] (or [W]) packed uint32 label sets.
            keys: optional [R] int64 stable external keys for the rows
                (auto-assigned sequentially when omitted). A key that
                already names a *live* row is rejected — delete the old
                row first to re-point a key.
        Returns: [R] int64 assigned ids (valid for this generation;
            `compact()` remaps them — `keys_of` gives the stable keys).
        Raises: RuntimeError if closed; ValueError on shape mismatch or
            a duplicate live key.
        """
        vectors = np.asarray(vectors, dtype=np.float32)
        bitmaps = np.asarray(bitmaps, dtype=np.uint32)
        if vectors.ndim == 1:
            vectors = vectors[None]
        if bitmaps.ndim == 1:
            bitmaps = bitmaps[None]
        if vectors.ndim != 2 or vectors.shape[1] != self._dim:
            raise ValueError(
                f"upsert vectors must be [R, {self._dim}]; got "
                f"{vectors.shape}")
        if bitmaps.shape != (vectors.shape[0], self._width):
            raise ValueError(
                f"upsert bitmaps must be [{vectors.shape[0]}, "
                f"{self._width}]; got {bitmaps.shape}")
        # the bit expansion only depends on the arguments — keep it out
        # of the lock so big ingest batches don't stall readers
        counts = _label_counts(bitmaps, self._universe)
        with self._lock:
            self._check_open()
            ks = self._claim_keys(keys, vectors.shape[0])
            wal = self._wal
            if wal is not None:              # logged before applied
                seq = wal.log_upsert(self._generation, ks, vectors, bitmaps)
            start, stop = self._delta.append(vectors, bitmaps)
            self._tomb = np.concatenate(
                [self._tomb, np.zeros(stop - start, bool)])
            self._keys = np.concatenate([self._keys, ks])
            self._note_new_keys(ks, self._base_n + start)
            self._live_label_counts = self._live_label_counts + counts
            self._clock_touch(counts)
            out = np.arange(self._base_n + start, self._base_n + stop,
                            dtype=np.int64)
        if wal is not None:
            wal.commit(seq)                  # durable before acked, off-lock
        return out

    def _row_live(self, rows: np.ndarray) -> np.ndarray:
        """bool[R]: which current-generation rows are not tombstoned
        (mixin hook; caller holds the lock)."""
        return ~self._tomb[rows]

    def delete(self, ids) -> int:
        """Tombstone ids (base or delta rows of the current generation).
        Returns the number of *newly* deleted rows; already-dead ids are
        no-ops. Raises IndexError on out-of-range ids."""
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        with self._lock:
            self._check_open()
            n_tot = self.n_total
            if ids.size and (ids.min() < 0 or ids.max() >= n_tot):
                raise IndexError(
                    f"delete ids must be in [0, {n_tot}); got range "
                    f"[{ids.min()}, {ids.max()}]")
            wal = self._wal
            if wal is not None:              # replay is idempotent
                seq = wal.log_delete(self._generation, ids)
            fresh = ids[~self._tomb[ids]]
            fresh = np.unique(fresh)
            if fresh.size:
                self._tomb[fresh] = True
                self._tomb_version += 1
                dcounts = _label_counts(self._bitmaps_of(fresh),
                                        self._universe)
                self._live_label_counts = self._live_label_counts - dcounts
                self._clock_touch(dcounts)
            out = int(fresh.size)
        if wal is not None:
            wal.commit(seq)                  # durable before acked, off-lock
        return out

    # stable external keys (`keys_of`/`rows_of`/`delete_keys`/`_claim_keys`)
    # come from _StableKeyMixin.

    # ---- durability hook (repro.ann.store) -------------------------------
    def attach_wal(self, wal) -> None:
        """Attach a write-ahead log: every subsequent `upsert`/`delete`
        appends a record *before* the state mutates, and `compact_async`
        logs a compaction barrier at its snapshot point. Pass None to
        detach. The store owns the WAL lifecycle (rotation, fsync,
        close); the live handle only appends."""
        with self._lock:
            self._wal = wal

    def _bitmaps_of(self, gids: np.ndarray) -> np.ndarray:
        """[R, W] packed bitmaps for current-generation global ids."""
        out = np.zeros((gids.size, self._width), np.uint32)
        base = gids < self._base_n
        if base.any():
            out[base] = self._base_fx.ds.bitmaps[gids[base]]
        if (~base).any():
            out[~base] = self._delta._bm[gids[~base] - self._base_n]
        return out

    def fetch(self, ids, snapshot: LiveSnapshot | None = None) -> np.ndarray:
        """[R, d] vectors for result ids (−1 rows come back as NaN).
        With a snapshot, ids are interpreted in that epoch's id space."""
        snap = snapshot or self.snapshot()
        try:
            ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
            out = np.full((ids.size, self._dim), np.nan, np.float32)
            fx = self._base_for(snap)
            base = (ids >= 0) & (ids < snap.base_n)
            if base.any():
                out[base] = fx.ds.vectors[ids[base]]
            delta = ids >= snap.base_n
            if delta.any():
                dvec, _, _ = snap.delta.host_view(snap.delta_rows)
                out[delta] = dvec[ids[delta] - snap.base_n]
            return out
        finally:
            if snapshot is None:
                snap.release()

    # ---- snapshots / epochs ---------------------------------------------
    def snapshot(self) -> LiveSnapshot:
        """Pin a consistent read epoch (see `LiveSnapshot`). Callers that
        hold one across writes must `release()` it (context manager
        supported); searches without an explicit snapshot take and
        release one internally."""
        with self._lock:
            self._check_open()
            rows = self._delta.rows
            gen = self._generation
            self._readers[gen] = self._readers.get(gen, 0) + 1
            # keys: a view is enough — _keys is only ever *reassigned*
            # (concatenate on upsert, fresh array at the compaction
            # swap), never written in place, so the sliced object stays
            # frozen; tombstones mutate in place and must copy
            snap = LiveSnapshot(self, gen, self._base_n, rows,
                                self._tomb[: self._base_n + rows].copy(),
                                self._tomb_version, self._delta,
                                self._keys[: self._base_n + rows],
                                self._next_key)
        # the pin lease carries the acquiring trace id + caller stack —
        # a snapshot held past the ledger's leak age names its taker
        snap._lease = ledger_mod.get_ledger().acquire(
            "snapshot_pin", self._name, meta={"generation": int(gen)})
        return snap

    def _release_reader(self, gen: int) -> None:
        with self._lock:
            left = self._readers.get(gen, 0) - 1
            if left > 0:
                self._readers[gen] = left
                return
            self._readers.pop(gen, None)
            had_retired = gen in self._retired
            fx = self._retired.pop(gen, None)
            lease = (self._retired_leases.pop(gen, None)
                     if had_retired else None)
        if lease is not None:
            lease.release()
        if fx is not None:
            fx.close()

    def _base_for(self, snap: LiveSnapshot) -> FilteredIndex | None:
        with self._lock:
            if snap.generation == self._generation:
                return self._base_fx
            if snap.generation in self._retired:
                return self._retired[snap.generation]
        raise RuntimeError(
            f"snapshot generation {snap.generation} has been released "
            f"(current generation {self._generation})")

    # ---- read path -------------------------------------------------------
    def _resolve(self, method):
        if isinstance(method, str):
            reg = self._registry or registry_mod.default_registry()
            return reg.get(method)
        return method

    def run_method(self, method, setting: ParamSetting, batch: QueryBatch,
                   *, snapshot: LiveSnapshot | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Raw live execution of one (method, setting): routed method on
        the base, brute-force `masked_topk` on the delta, tombstones
        masked in both, candidates folded through `merge_topk`.

        Returns the `FilteredIndex.run_method` contract: ([Q, k] int32
        ids with −1 pad, [Q, k] float32 ranking scores with +inf at −1).
        Stage timings (`base_s`/`delta_s`/`merge_s`) accumulate on a
        thread-local, drained by `pop_stage_timings()`.
        """
        self._check_open()
        snap = snapshot
        if snap is None:
            snap = self.snapshot()
        try:
            return self._run(method, setting, batch, snap)
        finally:
            if snapshot is None:
                snap.release()

    def _run(self, method, setting, batch: QueryBatch, snap: LiveSnapshot):
        if self.fused and snap.delta_rows:
            return self._run_fused(method, setting, batch, snap)
        return self._run_staged(method, setting, batch, snap)

    def _run_base(self, method, setting, batch: QueryBatch,
                  snap: LiveSnapshot, base_dead: int
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Routed base candidates [Q, KB] (numpy), overfetched by the
        full base tombstone count (bucketed to bound recompiles, clamped
        to the base size) so deletions can't crowd out live rows: among
        the top-(k + dead) ranked matches at most `dead` are tombstoned,
        leaving >= k live ones — any smaller overfetch can miss live
        rows once the dead outnumber k in a query's neighborhood. [Q, 0]
        for an empty base."""
        fx = self._base_for(snap) if snap.base_n else None
        if fx is None:
            return (np.zeros((batch.q, 0), np.int32),
                    np.zeros((batch.q, 0), np.float32))
        k = batch.k
        kb = (max(k, min(_bucket(k + base_dead), snap.base_n))
              if base_dead else k)
        trace.annotate(overfetch=int(kb))
        b_ids, b_raw = fx.run_method(
            self._resolve(method), setting,
            QueryBatch(batch.vectors, batch.bitmaps, batch.pred, kb))
        return (np.asarray(b_ids, dtype=np.int32),
                np.asarray(b_raw, dtype=np.float32))

    def _run_fused(self, method, setting, batch: QueryBatch,
                   snap: LiveSnapshot):
        """Single-launch live read: routed base candidates and the delta
        scan fold through one `ops.fused_live_topk` call; tombstones are
        applied to both candidate sets in-kernel (packed-word gather),
        so there is no host mask, no per-stage delta overfetch, and no
        separate merge launch. Bit-identical to `_run_staged`."""
        import jax.numpy as jnp

        from repro.kernels import ops

        k = batch.k
        tomb = snap.tombstones
        base_dead = int(tomb[: snap.base_n].sum())
        t0 = time.perf_counter()
        with trace.span("live.base", base_n=int(snap.base_n),
                        dead=base_dead):
            b_ids, b_raw = self._run_base(method, setting, batch, snap,
                                          base_dead)
        t1 = time.perf_counter()
        with trace.span("live.delta", rows=int(snap.delta_rows),
                        fused=True):
            dvec, dnorm, dbm = snap.delta.device_view(
                snap.delta_rows, self._device_scope)
            tomb_words = self._tomb_words(snap)
            sel = self._delta_select(snap, batch, b_ids, b_raw)
            if sel is not None and sel.size == 0:
                # every sealed cluster was pruned and there is no tail
                # row.  Re-include one pruned row to keep the kernel
                # operand non-empty: a pruned row provably cannot
                # displace any query's top-k, so the result bits are
                # unchanged.
                sel = np.zeros(1, np.int32)
            qv = jnp.asarray(batch.vectors)
            qb = jnp.asarray(batch.bitmaps)
            if sel is None:
                ids, raw = ops.fused_live_topk(
                    qv, qb, b_ids, b_raw, dvec, dnorm, dbm,
                    np.int32(snap.base_n), tomb_words,
                    pred=int(batch.pred), k=k)
            else:
                ids, raw = ops.fused_live_topk_select(
                    qv, qb, b_ids, b_raw, dvec, dnorm, dbm, sel,
                    np.int32(snap.base_n), tomb_words,
                    pred=int(batch.pred), k=k)
            ids = np.asarray(ids, dtype=np.int32)
            raw = np.asarray(raw, dtype=np.float32)
        t2 = time.perf_counter()
        self._stage_add({"base_s": t1 - t0, "delta_s": t2 - t1,
                         "merge_s": 0.0})    # merge happens in-kernel
        return ids, raw

    def _run_staged(self, method, setting, batch: QueryBatch,
                    snap: LiveSnapshot):
        """Pre-PR-6 three-stage live read (base launch → delta
        `masked_topk` → host tombstone mask → `merge_topk`): the parity
        reference for the fused path, and the fallback when the delta is
        empty (nothing to fuse over)."""
        k = batch.k
        tomb = snap.tombstones
        base_dead = int(tomb[: snap.base_n].sum())
        delta_dead = int(tomb[snap.base_n:].sum())
        parts = []
        t0 = time.perf_counter()
        if snap.base_n:
            with trace.span("live.base", base_n=int(snap.base_n),
                            dead=base_dead):
                b_ids, b_raw = self._run_base(method, setting, batch,
                                              snap, base_dead)
            if base_dead:
                valid = b_ids >= 0
                dead = np.zeros_like(valid)
                dead[valid] = tomb[b_ids[valid]]
                b_ids = np.where(dead, np.int32(-1), b_ids)
                b_raw = np.where(dead, np.float32(np.inf), b_raw)
            parts.append((b_ids, b_raw))
        t1 = time.perf_counter()
        if snap.delta_rows:
            import jax.numpy as jnp

            from repro.kernels import ops

            # exact overfetch: top-(k + dead) over the delta always
            # contains the live top-k
            kd = _bucket(k + min(delta_dead, snap.delta_rows))
            with trace.span("live.delta", rows=int(snap.delta_rows),
                            overfetch=int(kd), fused=False):
                dvec, dnorm, dbm = snap.delta.device_view(
                    snap.delta_rows, self._device_scope)
                d_ids, d_raw = ops.masked_topk(
                    jnp.asarray(batch.vectors),
                    jnp.asarray(batch.bitmaps),
                    dvec, dnorm, dbm, pred=int(batch.pred), k=kd)
            d_ids = np.asarray(d_ids, dtype=np.int32)
            d_raw = np.asarray(d_raw, dtype=np.float32)
            # sentinel/pad rows are already −1; rows past the watermark
            # (appended since the snapshot) and tombstoned rows drop here
            valid = (d_ids >= 0) & (d_ids < snap.delta_rows)
            dead = ~valid
            dead[valid] |= tomb[snap.base_n + d_ids[valid]]
            d_ids = np.where(dead, np.int32(-1),
                             d_ids + np.int32(snap.base_n))
            d_raw = np.where(dead, np.float32(np.inf), d_raw)
            parts.append((d_ids, d_raw))
        t2 = time.perf_counter()
        if not parts:
            ids = np.full((batch.q, k), -1, np.int32)
            raw = np.full((batch.q, k), np.inf, np.float32)
        else:
            with trace.span("live.merge"):
                ids, raw = merge_candidates(*stack_candidates(parts),
                                            k=k)
        t3 = time.perf_counter()
        self._stage_add({"base_s": t1 - t0, "delta_s": t2 - t1,
                         "merge_s": t3 - t2})
        return ids, raw

    def _tomb_words(self, snap: LiveSnapshot):
        """[TW] uint32 packed device tombstones for the fused kernel.

        Cached by (generation, tombstone version, padded length): rows
        appended after the pack only add zero bits, so the cached words
        stay valid until a delete bumps the version or the padded length
        grows past the next 4096-row bucket."""
        import jax.numpy as jnp

        n_pad = _bucket(max(snap.n_total, 1), 4096)
        key = (snap.generation, snap.tombstone_version, n_pad)
        cached = self._tomb_words_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        words = np.zeros(n_pad // 8, np.uint8)
        packed = np.packbits(snap.tombstones, bitorder="little")
        words[: packed.size] = packed
        with self._device_scope():
            dev = jnp.asarray(words.view(np.uint32))
        self._tomb_words_cache = (key, dev)
        return dev

    @staticmethod
    def _label_drop(chunk_idx: list[ChunkIndex],
                    batch: QueryBatch) -> np.ndarray:
        """[Q, C] True where a cluster's exact label bounds prove no
        member can satisfy the query's predicate. Chunks persisted
        without label bounds contribute all-False columns (no label
        pruning, distance pruning unaffected)."""
        qb = batch.bitmaps.astype(np.uint32)
        nq = qb.shape[0]
        qx = qb[:, None, :]                       # [Q, 1, W]
        pred = Predicate(batch.pred)
        cols = []
        for c in chunk_idx:
            ncl = c.radius.size
            if c.label_union is None:
                cols.append(np.zeros((nq, ncl), bool))
                continue
            uq = c.label_union[None, :, :] & qx   # [Q, C, W]
            if pred == Predicate.OR:
                # OR needs a shared bit; the union has none of q's bits
                drop = (uq == 0).all(axis=2)
            elif pred == Predicate.AND:
                # AND needs q ⊆ row; a q-bit missing from the union is
                # missing from every member
                drop = (uq != qx).any(axis=2)
            else:                                 # EQUALITY: row == q
                # a q-bit missing from the union, or a bit carried by
                # every member (intersection) that q lacks
                drop = ((uq != qx).any(axis=2)
                        | ((c.label_inter[None, :, :] & ~qx) != 0)
                        .any(axis=2))
            cols.append(drop)
        return np.concatenate(cols, axis=1)

    def _delta_select(self, snap: LiveSnapshot, batch: QueryBatch,
                      b_ids: np.ndarray, b_raw: np.ndarray
                      ) -> np.ndarray | None:
        """Exact ball-bound + label-bound pruning over the sealed
        chunks' mini-IVFs.

        Returns None to scan the whole delta mirror, or a sorted [NS]
        i32 array of delta-local rows that provably contains every
        query's live top-k among the delta. A cluster is dropped only
        when, for *every* query, it provably cannot contribute:

        * distance: the exact lower bound max(0, ‖q−c‖ − radius)² on
          any member's distance exceeds the query's k-th best live
          base-candidate distance (plus a rounding margin) — such rows
          cannot displace the eventual top-k;
        * labels: the cluster's exact label union/intersection is
          incompatible with the query's predicate (OR: no shared bit
          with the union; AND: a required bit missing from the union;
          EQUALITY: a required bit missing from the union, or a bit
          every member carries that the query lacks) — such rows are
          masked out by `masked_topk` anyway.

        Either way the result stays bit-identical to the full scan.
        Label pruning needs no base candidates, so it also fires for
        queries with fewer than k live base matches (where the distance
        threshold is +inf). The partial tail chunk is always scanned."""
        rows = snap.delta_rows
        if rows < self._delta_prune_min_rows:
            return None
        chunk_idx = snap.delta.chunk_indexes(rows)
        if not chunk_idx:
            return None
        # per-query threshold: k-th smallest live base candidate (raw
        # score scale ‖v‖² − 2·q·v); +inf disables distance pruning for
        # queries with fewer than k live base candidates
        if b_ids.shape[1] >= batch.k:
            live = b_ids >= 0
            live[live] = ~snap.tombstones[b_ids[live]]
            cand = np.where(live, b_raw, np.inf).astype(np.float64)
            cand.sort(axis=1)
            bound = cand[:, batch.k - 1]                   # [Q]
        else:
            bound = np.full(batch.q, np.inf)
        qv = batch.vectors.astype(np.float64)
        qn = (qv ** 2).sum(axis=1)
        cent = np.concatenate([c.centroids for c in chunk_idx]
                              ).astype(np.float64)
        cn = np.concatenate([c.cnorms for c in chunk_idx])
        rad = np.concatenate([c.radius for c in chunk_idx])
        d2 = np.maximum(cn[None, :] - 2.0 * (qv @ cent.T) + qn[:, None],
                        0.0)
        lb = np.maximum(np.sqrt(d2) - rad[None, :], 0.0) ** 2   # [Q, C]
        # margin absorbs the kernel's f32 rounding of candidate scores;
        # an infinite bound yields an infinite margin and never drops
        margin = 1e-3 * (1.0 + np.abs(bound))
        dist_drop = (lb - qn[:, None]) > (bound + margin)[:, None]
        label_drop = self._label_drop(chunk_idx, batch)         # [Q, C]
        drop = (dist_drop | label_drop).all(axis=0)
        with self._lock:
            self._prune_stats["calls"] += 1
            self._prune_stats["clusters"] += int(drop.size)
            self._prune_stats["pruned"] += int(drop.sum())
            self._prune_stats["label_pruned"] += int(
                label_drop.all(axis=0).sum())
        if not drop.any():
            return None
        chunk = snap.delta.chunk
        keep_rows = []
        ci = 0
        for i, c in enumerate(chunk_idx):
            ncl = c.radius.size
            kept = ~drop[ci: ci + ncl]
            off = i * chunk
            if kept.all():
                keep_rows.append(off + np.arange(chunk, dtype=np.int64))
            elif kept.any():
                parts = [c.members[c.starts[j]: c.starts[j + 1]]
                         for j in np.nonzero(kept)[0]]
                keep_rows.append(off + np.concatenate(parts
                                                      ).astype(np.int64))
            ci += ncl
        covered = len(chunk_idx) * chunk
        keep_rows.append(np.arange(covered, rows, dtype=np.int64))
        sel = np.concatenate(keep_rows)
        sel.sort()                 # scan order matches the full scan
        return sel.astype(np.int32)

    def search(self, batch: QueryBatch, method,
               setting: ParamSetting | str | None = None, *,
               snapshot: LiveSnapshot | None = None) -> SearchResult:
        """Direct single-method live search (no routing). Args/semantics
        match `FilteredIndex.search`, plus `snapshot=` to read a pinned
        epoch; timings gain `base_s`/`delta_s`/`merge_s`."""
        self._check_open()
        method = self._resolve(method)
        if not isinstance(setting, ParamSetting):
            setting = resolve_setting(method, setting)
        self.pop_stage_timings()
        t0 = time.perf_counter()
        snap = snapshot if snapshot is not None else self.snapshot()
        try:
            ids, raw = self.run_method(method, setting, batch,
                                       snapshot=snap)
            keys = self.keys_of(ids, snapshot=snap)
        finally:
            if snapshot is None:
                snap.release()
        dt = time.perf_counter() - t0
        timings = {"search_s": dt, "total_s": dt}
        timings.update(self.pop_stage_timings())
        return SearchResult(
            ids=ids, distances=exact_distances(raw, ids, batch.vectors),
            decisions=None, timings=timings, keys=keys)

    # ---- routing-feature freshness ---------------------------------------
    def live_stats(self) -> LiveStats:
        """Current live-set summary for the routing features (exact live
        size, live per-label fractions, correction bitmaps)."""
        with self._lock:
            rows = self._delta.rows
            tomb = self._tomb
            n_live = self._base_n + rows - int(tomb.sum())
            base_dead = np.nonzero(tomb[: self._base_n])[0]
            base_bm = (self._base_fx.ds.bitmaps[base_dead]
                       if base_dead.size else
                       np.zeros((0, self._width), np.uint32))
            delta_live = ~tomb[self._base_n: self._base_n + rows]
            delta_bm = self._delta._bm[:rows][delta_live]
            return LiveStats(
                n_live=n_live,
                label_freq=(self._live_label_counts.astype(np.float64)
                            / max(n_live, 1)),
                base_tomb_bitmaps=base_bm,
                delta_bitmaps=delta_bm.copy(),
                base_ds=self.ds)

    # ---- compaction ------------------------------------------------------
    def compact(self, timeout: float | None = None) -> int:
        """Merge base + delta (minus tombstones) into a fresh sealed base
        and swap it in. Blocks until done; returns the new generation.
        See `compact_async` for the non-blocking form."""
        return self.compact_async().result(timeout=timeout)

    def compact_async(self) -> Future:
        """Start (or join) a background compaction.

        The worker thread gathers the surviving rows under a snapshot,
        builds the new group-sorted `ANNDataset` + `FilteredIndex`,
        replays the old base's built method indexes, then swaps
        atomically under the write lock: rows upserted and tombstones
        set *during* the rebuild are carried over (tail rows become the
        new delta; late deletes are translated through the id remap).
        Old-generation readers keep their base until their snapshots
        release. Returns a Future of the new generation; a second call
        while one runs returns the same Future.
        """
        with self._lock:
            self._check_open()
            if self._compacting is not None and not self._compacting.done():
                return self._compacting
            if self._compact_pool is None:
                self._compact_pool = ThreadPoolExecutor(
                    max_workers=1,
                    thread_name_prefix=f"compact-{self._name}")
            snap = self.snapshot()
            wal = self._wal
            if wal is not None:
                # barrier record: replay compacts synchronously at this
                # point, reproducing the snapshot's fold exactly
                seq = wal.log_compact(self._generation)
            fut = self._compact_pool.submit(self._compact_job, snap)
            self._compacting = fut
        if wal is not None:
            wal.commit(seq)
        return fut

    def _compact_job(self, snap: LiveSnapshot) -> int:
        try:
            keep_base = ~snap.tombstones[: snap.base_n]
            keep_delta = ~snap.tombstones[snap.base_n:]
            dvec, dbm, _ = snap.delta.host_view(snap.delta_rows)
            base_ds = None if snap.base_n == 0 else self._base_for(snap).ds
            vec_parts, bm_parts = [], []
            if base_ds is not None:
                vec_parts.append(base_ds.vectors[keep_base])
                bm_parts.append(base_ds.bitmaps[keep_base])
            vec_parts.append(dvec[keep_delta])
            bm_parts.append(dbm[keep_delta])
            vectors = np.concatenate(vec_parts) if vec_parts else \
                np.zeros((0, self._dim), np.float32)
            bitmaps = np.concatenate(bm_parts) if bm_parts else \
                np.zeros((0, self._width), np.uint32)
            kept = np.concatenate([
                np.nonzero(keep_base)[0],
                snap.base_n + np.nonzero(keep_delta)[0]])
            new_ds, order = ANNDataset.from_packed(
                self._name, vectors, bitmaps, self._universe,
                return_order=True)
            inv = np.empty(order.size, np.int64)
            inv[order] = np.arange(order.size)
            remap = np.full(snap.n_total, -1, np.int64)
            remap[kept] = inv
            # stable keys follow their rows through the remap
            new_keys = np.empty(new_ds.n, np.int64)
            new_keys[remap[kept]] = snap.keys[kept]
            new_fx = FilteredIndex(new_ds, registry=self._registry,
                                   device=self._placement)
            old_fx = self._base_for(snap) if snap.base_n else None
            if old_fx is not None:
                # graft where the method supports it: splice the old
                # built index through the id remap (sublinear in base
                # size) instead of rebuilding; fall back to a build
                base_remap = remap[: snap.base_n]
                new_from_delta = remap[snap.base_n:]
                new_from_delta = np.sort(
                    new_from_delta[new_from_delta >= 0])
                for m_name, build in old_fx.built_keys():
                    try:
                        m = self._resolve(m_name)
                        grafted = None
                        old_index = old_fx._indexes.get((m_name, build))
                        if self._graft and old_index is not None:
                            grafted = m.graft_index(
                                new_ds, old_index, old_fx.ds, base_remap,
                                new_from_delta, dict(build))
                        if grafted is not None:
                            new_fx.adopt_index(m, build, grafted)
                        else:
                            new_fx.get_index(m_name, build)
                    except KeyError:
                        pass        # method no longer registered
            with self._lock:
                if self._closed:
                    new_fx.close()
                    return self._generation
                rows_now = self._delta.rows
                tvec, tbm, _ = self._delta.host_view(rows_now)
                tail = slice(snap.delta_rows, rows_now)
                new_delta = DeltaSegment(self._dim, self._width,
                                         chunk=self._delta_chunk)
                n_tail = rows_now - snap.delta_rows
                if n_tail:
                    new_delta.append(tvec[tail], tbm[tail])
                new_tomb = np.zeros(new_ds.n + n_tail, bool)
                # deletes that landed after the compaction snapshot
                newly = self._tomb[: snap.n_total] & ~snap.tombstones
                ng = remap[np.nonzero(newly)[0]]
                new_tomb[ng[ng >= 0]] = True
                new_tomb[new_ds.n:] = self._tomb[snap.n_total:
                                                 snap.n_total + n_tail]
                old_gen = self._generation
                old_base = self._base_fx
                self._base_fx = new_fx
                self._base_n = new_ds.n
                self._delta = new_delta
                self._tomb = new_tomb
                self._keys = np.concatenate(
                    [new_keys, self._keys[snap.n_total:
                                          snap.n_total + n_tail]])
                self._key_rows = None
                self._tomb_version += 1
                self._generation = old_gen + 1
                self._features = None       # dataset features went stale
                self._tomb_words_cache = None
                self._last_remap = remap
                if self._readers.get(old_gen):
                    # record the retirement even for an empty base (None)
                    # so pinned snapshots of generation 0 stay resolvable
                    self._retired[old_gen] = old_base
                    old_ds = (old_base.ds if old_base is not None
                              else None)
                    self._retired_leases[old_gen] = \
                        ledger_mod.get_ledger().acquire(
                            "retired_generation", self._name,
                            bytes=(old_ds.vectors.nbytes
                                   + old_ds.bitmaps.nbytes
                                   if old_ds is not None else 0),
                            meta={"generation": int(old_gen)})
                elif old_base is not None:
                    old_base.close()
                return self._generation
        finally:
            snap.release()
            with self._lock:
                self._compacting = None

    # ---- maintenance -----------------------------------------------------
    def export_state(self, snap: LiveSnapshot) -> dict:
        """Full logical state of a pinned snapshot — what a
        `repro.ann.store` checkpoint persists: the sealed base dataset,
        per-row stable keys, the delta rows in insertion order (with
        keys), and the tombstoned ids of the epoch."""
        base_fx = self._base_for(snap) if snap.base_n else None
        dvec, dbm, _ = snap.delta.host_view(snap.delta_rows)
        return {
            "generation": snap.generation,
            "base_ds": None if base_fx is None else base_fx.ds,
            "base_keys": snap.keys[: snap.base_n],
            "delta_vectors": dvec,
            "delta_bitmaps": dbm,
            "delta_keys": snap.keys[snap.base_n:],
            "dead_ids": np.nonzero(snap.tombstones)[0].astype(np.int64),
            "next_key": snap.next_key,
        }

    def last_remap(self) -> np.ndarray | None:
        """Id translation of the most recent `compact()`: `remap[old_id]`
        is the row's id in the new generation, −1 if it was deleted.
        None before the first compaction. Ids are per-generation, so
        clients holding ids across a compaction re-resolve through
        this."""
        return self._last_remap

    def built_keys(self) -> list[tuple]:
        return [] if self._base_fx is None else self._base_fx.built_keys()

    def stats(self) -> dict:
        """State snapshot: generation, live/total row counts, delta and
        tombstone sizes, mirror coverage, compaction status."""
        with self._lock:
            rows = self._delta.rows
            return {
                "dataset": self._name,
                "generation": self._generation,
                "base_n": self._base_n,
                "delta_rows": rows,
                "delta_device_rows": self._delta.device_rows(),
                "tombstones": int(self._tomb.sum()),
                "n_live": self._base_n + rows - int(self._tomb.sum()),
                "tombstone_version": self._tomb_version,
                "next_key": self._next_key,
                "wal_attached": self._wal is not None,
                "compacting": (self._compacting is not None
                               and not self._compacting.done()),
                "retired_generations": sorted(self._retired),
                "fused": self.fused,
                "graft": self._graft,
                "delta_chunk_indexes": len(self._delta._chunk_idx),
                "delta_prune": dict(self._prune_stats),
                "closed": self._closed,
            }


# ---------------------------------------------------------------------------
# sharded live index — round-robin upserts over per-shard delta segments
# ---------------------------------------------------------------------------

class ShardedLiveSnapshot:
    """Consistent cross-shard read epoch: one pinned `LiveSnapshot` per
    shard plus the shard list / bounds / gid maps / global key prefix of
    the epoch, all captured under the sharded index's write lock. Pins
    the epoch (old shard lists survive a compaction swap) until
    `release()`."""

    __slots__ = ("epoch", "shards", "bounds", "snaps", "gmaps", "keys",
                 "next_key", "locs", "base_ds", "_owner", "_released")

    def __init__(self, owner, epoch, shards, bounds, snaps, gmaps,
                 keys, next_key, locs, base_ds):
        self.epoch = epoch
        self.shards = shards
        self.bounds = bounds
        self.snaps = snaps
        self.gmaps = gmaps
        self.keys = keys
        self.next_key = next_key
        self.locs = locs
        self.base_ds = base_ds
        self._owner = owner
        self._released = False

    def release(self) -> None:
        """Unpin this epoch (idempotent, thread-safe)."""
        with self._owner._lock:
            if self._released:
                return
            self._released = True
        for snap in self.snaps:
            snap.release()
        self._owner._release_epoch(self.epoch)

    def __enter__(self) -> "ShardedLiveSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShardedLiveIndex(_StableKeyMixin, _LabelClockMixin, _StageTimings):
    """Row-sharded live handle: one `LiveFilteredIndex` per shard.

    Upserts round-robin row-by-row across shards; global delta ids are
    assigned in insertion order (`total_base_n + j`) and mapped to
    (shard, local-row) so `delete()` and result globalisation agree.
    `run_method` snapshots every shard under one lock (a consistent
    cross-shard epoch), fans out, globalises per-shard ids, and reduces
    through `merge_topk`. Each shard serves its own read through the
    fused single-launch kernel (the `fused`/`delta_prune_min_rows`
    knobs forward to the per-shard handles), so the sharded handle
    inherits the fused path wholesale. `compact()` rebuilds
    **globally**: all surviving rows merge into one fresh dataset that
    is re-sharded contiguously, so the result is exactly a
    `ShardedFilteredIndex` over the compacted data (rows migrate across
    shard boundaries, so per-shard method indexes are rebuilt, not
    grafted).

    Args mirror `ShardedFilteredIndex` (+ the empty-base form of
    `LiveFilteredIndex` via `name`/`dim`/`universe`).
    """

    def __init__(self, ds: ANNDataset | None = None, n_shards: int = 1, *,
                 name: str | None = None, dim: int | None = None,
                 universe: int | None = None, devices=None, registry=None,
                 parallel: bool = True,
                 delta_chunk: int = DEFAULT_DELTA_CHUNK,
                 base_keys: np.ndarray | None = None,
                 next_key: int | None = None, generation: int = 0,
                 fused: bool = True,
                 delta_prune_min_rows: int | None = None):
        from repro.ann.distributed import shard_bounds, shard_devices

        n_shards = int(n_shards)
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1; got {n_shards}")
        if devices is None:
            devices = shard_devices(n_shards)
        self._registry = registry
        self._delta_chunk = int(delta_chunk)
        self._devices = devices
        self._fused = bool(fused)
        self._delta_prune_min_rows = delta_prune_min_rows

        def _shard_kw():
            return dict(registry=registry, delta_chunk=delta_chunk,
                        fused=self._fused,
                        delta_prune_min_rows=self._delta_prune_min_rows)

        self._shard_kw = _shard_kw
        if ds is None:
            if name is None or dim is None or universe is None:
                raise ValueError(
                    "an empty ShardedLiveIndex needs name=, dim= and "
                    "universe= (or pass a base ANNDataset)")
            self._name, self._dim = str(name), int(dim)
            self._universe = int(universe)
            self._base_ds: ANNDataset | None = None
            self.bounds = np.zeros(n_shards + 1, dtype=np.int64)
            self.shards = [
                LiveFilteredIndex.empty(
                    f"{self._name}/shard{i}", self._dim, self._universe,
                    device=devices[i], **_shard_kw())
                for i in range(n_shards)]
        else:
            self._name, self._dim = ds.name, ds.dim
            self._universe = ds.universe
            self._base_ds = ds
            self.bounds = shard_bounds(ds.n, n_shards)
            self.shards = [
                LiveFilteredIndex(
                    ds.row_slice(int(s), int(e),
                                 name=f"{ds.name}/shard{i}"),
                    device=devices[i], **_shard_kw())
                for i, (s, e) in enumerate(zip(self.bounds[:-1],
                                               self.bounds[1:]))]
        self._total_base = 0 if ds is None else ds.n
        self._delta_loc: list[tuple[int, int]] = []  # gid-j -> (shard, row)
        self._shard_gids: list[list[int]] = [[] for _ in self.shards]
        self._gid_arrays: list[np.ndarray] | None = None   # search cache
        self._last_remap: np.ndarray | None = None
        self._next_shard = 0
        if base_keys is None:
            self._keys = np.arange(self._total_base, dtype=np.int64)
        else:
            self._keys = np.asarray(base_keys, dtype=np.int64).copy()
            if self._keys.shape != (self._total_base,):
                raise ValueError(
                    f"base_keys must be [{self._total_base}]; got shape "
                    f"{self._keys.shape}")
        self._next_key = int(next_key) if next_key is not None else \
            (int(self._keys.max()) + 1 if self._total_base else 0)
        self._key_rows: KeyTable | None = None   # key -> gid, built lazily
        self._wal = None
        self._wal_quiet = False               # compaction's internal replay
        self._parallel = bool(parallel) and n_shards > 1
        self._pool = (ThreadPoolExecutor(
            max_workers=n_shards,
            thread_name_prefix=f"live-shard-{self._name}")
            if self._parallel else None)
        self._lock = threading.RLock()
        self._clock_init()
        self._epoch = int(generation)
        self._epoch_readers: dict[int, int] = {}
        self._old_shards: dict[int, list] = {}
        self._feature_fx: FilteredIndex | None = None
        self._compact_pool: ThreadPoolExecutor | None = None
        self._compacting: Future | None = None
        self._features = None
        self._closed = False

    # ---- lifecycle ------------------------------------------------------
    @property
    def fused(self) -> bool:
        """Whether shards serve reads through the fused kernel; setting
        it propagates to every current shard (and to shards created by
        later compactions)."""
        return self._fused

    @fused.setter
    def fused(self, value: bool) -> None:
        self._fused = bool(value)
        for s in self.shards:
            s.fused = self._fused

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def ds(self) -> ANNDataset | None:
        """The current generation's full base dataset (None before the
        first compact of an empty-started index)."""
        return self._base_ds

    @property
    def generation(self) -> int:
        return self._epoch

    @property
    def n_live(self) -> int:
        with self._lock:
            return sum(s.n_live for s in self.shards)

    @property
    def base_n(self) -> int:
        return self._total_base

    @property
    def n_total(self) -> int:
        with self._lock:
            return self._total_base + len(self._delta_loc)

    @property
    def feature_index(self) -> FilteredIndex:
        """Full-base `FilteredIndex` on shard-0's device for the TPU
        routing-feature kernels (lazy, like `ShardedFilteredIndex`)."""
        self._check_open()
        if self._base_ds is None:
            raise RuntimeError(
                f"ShardedLiveIndex({self._name!r}) has no sealed base yet")
        if self._feature_fx is None:
            self._feature_fx = FilteredIndex(
                self._base_ds, registry=self._registry,
                device=self._devices[0])
        return self._feature_fx

    @property
    def device(self):
        return self.feature_index.device

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            comp = self._compacting
        if comp is not None:
            try:
                comp.result(timeout=300)
            except BaseException:
                pass
        with self._lock:
            for s in self.shards:
                s.close()
            for old in self._old_shards.values():
                for s in old:
                    s.close()
            self._old_shards.clear()
            if self._feature_fx is not None:
                self._feature_fx.close()
                self._feature_fx = None
            self._features = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        if self._compact_pool is not None:
            self._compact_pool.shutdown(wait=True)
            self._compact_pool = None

    def __enter__(self) -> "ShardedLiveIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"ShardedLiveIndex({self._name!r}) is closed")

    # ---- write path -----------------------------------------------------
    def upsert(self, vectors, bitmaps, *, keys=None) -> np.ndarray:
        """Append rows, round-robin across shards. Returns [R] global
        ids (current generation); `keys=` as in
        `LiveFilteredIndex.upsert` (stable global keys, auto-assigned
        when omitted)."""
        vectors = np.asarray(vectors, dtype=np.float32)
        bitmaps = np.asarray(bitmaps, dtype=np.uint32)
        if vectors.ndim == 1:
            vectors = vectors[None]
        if bitmaps.ndim == 1:
            bitmaps = bitmaps[None]
        if vectors.ndim != 2 or vectors.shape[1] != self._dim:
            raise ValueError(
                f"upsert vectors must be [R, {self._dim}]; got "
                f"{vectors.shape}")
        width = lb.n_words(self._universe)
        if bitmaps.shape != (vectors.shape[0], width):
            raise ValueError(
                f"upsert bitmaps must be [{vectors.shape[0]}, {width}]; "
                f"got {bitmaps.shape}")
        with self._lock:
            self._check_open()
            n = vectors.shape[0]
            ks = self._claim_keys(keys, n)
            wal = self._wal if not self._wal_quiet else None
            if wal is not None:
                seq = wal.log_upsert(self._epoch, ks, vectors, bitmaps)
            nsh = self.n_shards
            shard_of = (self._next_shard + np.arange(n)) % nsh
            gid0 = self._total_base + len(self._delta_loc)
            d0 = len(self._delta_loc)
            self._delta_loc.extend([None] * n)
            for s in range(nsh):
                rows = np.nonzero(shard_of == s)[0]
                if rows.size == 0:
                    continue
                start_local = self.shards[s]._delta.rows
                self.shards[s].upsert(vectors[rows], bitmaps[rows])
                for off, j in enumerate(rows):
                    self._delta_loc[d0 + int(j)] = (s, start_local + off)
                    self._shard_gids[s].append(gid0 + int(j))
            self._keys = np.concatenate([self._keys, ks])
            self._note_new_keys(ks, gid0)
            self._clock_touch(_label_counts(bitmaps, self._universe))
            self._gid_arrays = None           # searches rebuild lazily
            self._next_shard = (self._next_shard + n) % nsh
            out = np.arange(gid0, gid0 + n, dtype=np.int64)
        if wal is not None:
            wal.commit(seq)                  # durable before acked, off-lock
        return out

    def _row_live(self, rows: np.ndarray) -> np.ndarray:
        """bool[R]: which current-generation global ids are live (mixin
        hook; caller holds the lock)."""
        return np.array([self._gid_live(int(g)) for g in rows], bool)

    def _shard_local(self, gid: int) -> tuple[int, int]:
        """(shard, shard-local id) for a current-generation global id."""
        if gid < self._total_base:
            s = int(np.searchsorted(self.bounds, gid, side="right")) - 1
            return s, gid - int(self.bounds[s])
        s, row = self._delta_loc[gid - self._total_base]
        return s, self.shards[s].base_n + row

    def _gid_live(self, gid: int) -> bool:
        s, lid = self._shard_local(int(gid))
        return not self.shards[s]._tomb[lid]

    def delete(self, ids) -> int:
        """Tombstone global ids; returns the number newly deleted."""
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        with self._lock:
            self._check_open()
            n_tot = self._total_base + len(self._delta_loc)
            if ids.size and (ids.min() < 0 or ids.max() >= n_tot):
                raise IndexError(
                    f"delete ids must be in [0, {n_tot}); got range "
                    f"[{ids.min()}, {ids.max()}]")
            wal = self._wal if not self._wal_quiet else None
            if wal is not None:
                seq = wal.log_delete(self._epoch, ids)
            per: dict[int, list] = {}
            for gid in ids.tolist():
                s, lid = self._shard_local(gid)
                per.setdefault(s, []).append(lid)
            # stamp before delegating: labels of every named id (a
            # conservative superset — already-dead ids stamp too)
            if ids.size:
                bms = np.concatenate(
                    [self.shards[s]._bitmaps_of(np.asarray(lids, np.int64))
                     for s, lids in per.items()])
                self._clock_touch(_label_counts(bms, self._universe))
            out = sum(self.shards[s].delete(lids)
                      for s, lids in per.items())
        if wal is not None:
            wal.commit(seq)                  # durable before acked, off-lock
        return out

    # stable external keys (`keys_of`/`rows_of`/`delete_keys`/`_claim_keys`)
    # come from _StableKeyMixin (global ids / global keys).

    # ---- durability hook (repro.ann.store) -------------------------------
    def attach_wal(self, wal) -> None:
        """Attach a write-ahead log at the sharded level (global ids and
        keys; per-shard handles stay WAL-less). See
        `LiveFilteredIndex.attach_wal`."""
        with self._lock:
            self._wal = wal

    # ---- read path -------------------------------------------------------
    def _map_shards(self, fn, items):
        if self._pool is not None:
            return list(self._pool.map(fn, items))
        return [fn(it) for it in items]

    def snapshot(self) -> ShardedLiveSnapshot:
        """Pin a consistent cross-shard read epoch (see
        `ShardedLiveSnapshot`); callers must `release()` it."""
        with self._lock:
            self._check_open()
            epoch = self._epoch
            shards = list(self.shards)
            bounds = self.bounds.copy()
            snaps = [s.snapshot() for s in shards]
            if self._gid_arrays is None:      # invalidated by upsert
                self._gid_arrays = [np.asarray(g, dtype=np.int64)
                                    for g in self._shard_gids]
            gmaps = self._gid_arrays
            n_tot = self._total_base + len(self._delta_loc)
            self._epoch_readers[epoch] = \
                self._epoch_readers.get(epoch, 0) + 1
            # keys slice is a view: _keys is reassigned, never mutated
            # in place (see LiveFilteredIndex.snapshot)
            return ShardedLiveSnapshot(self, epoch, shards, bounds,
                                       snaps, gmaps,
                                       self._keys[:n_tot],
                                       self._next_key,
                                       list(self._delta_loc),
                                       self._base_ds)

    def run_method(self, method, setting: ParamSetting, batch: QueryBatch,
                   *, snapshot: ShardedLiveSnapshot | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Raw sharded live execution: consistent per-shard snapshots,
        parallel fan-out, id globalisation (base via shard offsets,
        delta via the insertion-order map), `merge_topk` reduction.
        Pass `snapshot=` to pin several calls to one epoch."""
        self._check_open()
        snap = snapshot if snapshot is not None else self.snapshot()
        shards, bounds = snap.shards, snap.bounds
        snaps, gmaps = snap.snaps, snap.gmaps
        try:
            parent = trace.current()
            times = [0.0] * len(shards)

            def shard_run(jsv):
                # drain the shard's stage timings *in the worker thread*
                # (they live on a thread-local) and return them alongside
                j, sv = jsv
                s0 = time.perf_counter()
                with trace.attach(parent):
                    with trace.span("shard", shard=j):
                        out = sv[0].run_method(method, setting, batch,
                                               snapshot=sv[1])
                times[j] = time.perf_counter() - s0
                return out, sv[0].pop_stage_timings()

            ran = self._map_shards(shard_run,
                                   list(enumerate(zip(shards, snaps))))
            per = [r for r, _ in ran]
            # shards overlap in wall-clock: report the slowest stage
            for key in ("base_s", "delta_s"):
                vals = [t.get(key, 0.0) for _, t in ran]
                if any(vals):
                    self._stage_add({key: max(vals)})
            # per-shard wall seconds + the straggler (the latency the
            # fan-out actually waits for — a sum would hide it)
            self._stage_add({f"shard{j}_s": s
                             for j, s in enumerate(times)})
            self._stage_add({"shard_max_s": max(times)})
            t0 = time.perf_counter()
            parts = []
            for s, ((ids, raw), ssnap) in enumerate(zip(per, snaps)):
                ids = np.asarray(ids, dtype=np.int64)
                raw = np.asarray(raw, dtype=np.float32)
                out = np.full(ids.shape, -1, np.int64)
                is_base = (ids >= 0) & (ids < ssnap.base_n)
                out[is_base] = ids[is_base] + int(bounds[s])
                is_delta = ids >= ssnap.base_n
                if is_delta.any():
                    out[is_delta] = gmaps[s][ids[is_delta] - ssnap.base_n]
                parts.append((out.astype(np.int32), raw))
            gids, graw = merge_candidates(*stack_candidates(parts),
                                          k=batch.k)
            self._stage_add({"merge_s": time.perf_counter() - t0})
            return gids, graw
        finally:
            if snapshot is None:
                snap.release()

    def _release_epoch(self, epoch: int) -> None:
        with self._lock:
            left = self._epoch_readers.get(epoch, 0) - 1
            if left > 0:
                self._epoch_readers[epoch] = left
                return
            self._epoch_readers.pop(epoch, None)
            old = (self._old_shards.pop(epoch, None)
                   if epoch != self._epoch else None)
        if old:
            for s in old:
                s.close()

    def search(self, batch: QueryBatch, method,
               setting: ParamSetting | str | None = None) -> SearchResult:
        """Direct single-method sharded live search (no routing)."""
        self._check_open()
        if isinstance(method, str):
            reg = self._registry or registry_mod.default_registry()
            method = reg.get(method)
        if not isinstance(setting, ParamSetting):
            setting = resolve_setting(method, setting)
        self.pop_stage_timings()
        t0 = time.perf_counter()
        snap = self.snapshot()
        try:
            ids, raw = self.run_method(method, setting, batch,
                                       snapshot=snap)
            keys = self.keys_of(ids, snapshot=snap)
        finally:
            snap.release()
        dt = time.perf_counter() - t0
        timings = {"search_s": dt, "total_s": dt}
        timings.update(self.pop_stage_timings())
        return SearchResult(
            ids=ids, distances=exact_distances(raw, ids, batch.vectors),
            decisions=None, timings=timings, keys=keys)

    def fetch(self, ids, snapshot: ShardedLiveSnapshot | None = None
              ) -> np.ndarray:
        """[R, d] vectors for global result ids (−1 rows come back as
        NaN) — the sharded mirror of `LiveFilteredIndex.fetch`. With a
        snapshot, ids are interpreted in that epoch's global id space."""
        snap = snapshot or self.snapshot()
        try:
            ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
            out = np.full((ids.size, self._dim), np.nan, np.float32)
            base_n = int(snap.bounds[-1])
            base = (ids >= 0) & (ids < base_n)
            if base.any():
                out[base] = snap.base_ds.vectors[ids[base]]
            delta = ids >= base_n
            if delta.any():
                didx = np.nonzero(delta)[0]
                loc = [snap.locs[int(ids[j]) - base_n] for j in didx]
                loc_shard = np.array([l[0] for l in loc], np.int64)
                loc_row = np.array([l[1] for l in loc], np.int64)
                for s, ssnap in enumerate(snap.snaps):
                    mine = loc_shard == s
                    if mine.any():
                        sv, _, _ = ssnap.delta.host_view(ssnap.delta_rows)
                        out[didx[mine]] = sv[loc_row[mine]]
            return out
        finally:
            if snapshot is None:
                snap.release()

    # ---- routing-feature freshness ---------------------------------------
    def live_stats(self) -> LiveStats:
        """Aggregate live-set summary across shards (one consistent
        epoch: shard stats and the base dataset are read under the same
        lock a compaction swap takes)."""
        with self._lock:
            per = [s.live_stats() for s in self.shards]
            base_ds = self._base_ds
        n_live = sum(p.n_live for p in per)
        counts = sum((p.label_freq * p.n_live for p in per),
                     np.zeros(self._universe))
        return LiveStats(
            n_live=n_live,
            label_freq=counts / max(n_live, 1),
            base_tomb_bitmaps=np.concatenate(
                [p.base_tomb_bitmaps for p in per]),
            delta_bitmaps=np.concatenate([p.delta_bitmaps for p in per]),
            base_ds=base_ds)

    # ---- compaction ------------------------------------------------------
    def compact(self, timeout: float | None = None) -> int:
        """Global rebuild + re-shard; blocks, returns the new epoch."""
        return self.compact_async().result(timeout=timeout)

    def compact_async(self) -> Future:
        """Background global compaction: merge every shard's surviving
        base + delta rows (in global id order) into one fresh dataset,
        re-shard it contiguously, swap the shard list atomically, and
        drain old-epoch readers before closing the old shards. Writes
        during the rebuild carry over exactly as in
        `LiveFilteredIndex.compact_async`."""
        with self._lock:
            self._check_open()
            if self._compacting is not None and not self._compacting.done():
                return self._compacting
            if self._compact_pool is None:
                self._compact_pool = ThreadPoolExecutor(
                    max_workers=1,
                    thread_name_prefix=f"compact-{self._name}")
            fut = self._compact_pool.submit(self._compact_job)
            self._compacting = fut
            return fut

    def _gather(self, snaps, locs):
        """Surviving rows in global id order + the kept-gid list."""
        vec_parts, bm_parts, kept = [], [], []
        for s, snap in enumerate(snaps):
            if snap.base_n == 0:
                continue
            keep = ~snap.tombstones[: snap.base_n]
            ds = self.shards[s]._base_for(snap).ds
            vec_parts.append(ds.vectors[keep])
            bm_parts.append(ds.bitmaps[keep])
            kept.append(int(self.bounds[s]) + np.nonzero(keep)[0])
        n_delta = len(locs)
        if n_delta:
            dvec = np.zeros((n_delta, self._dim), np.float32)
            dbm = np.zeros((n_delta, lb.n_words(self._universe)), np.uint32)
            alive = np.zeros(n_delta, bool)
            loc_shard = np.array([l[0] for l in locs], np.int64)
            loc_row = np.array([l[1] for l in locs], np.int64)
            for s, snap in enumerate(snaps):
                mine = loc_shard == s
                if not mine.any():
                    continue
                sv, sb, _ = snap.delta.host_view(snap.delta_rows)
                rows = loc_row[mine]
                dvec[mine] = sv[rows]
                dbm[mine] = sb[rows]
                alive[mine] = ~snap.tombstones[snap.base_n + rows]
            vec_parts.append(dvec[alive])
            bm_parts.append(dbm[alive])
            kept.append(self._total_base + np.nonzero(alive)[0])
        if vec_parts:
            return (np.concatenate(vec_parts), np.concatenate(bm_parts),
                    np.concatenate(kept))
        width = lb.n_words(self._universe)
        return (np.zeros((0, self._dim), np.float32),
                np.zeros((0, width), np.uint32),
                np.zeros(0, np.int64))

    def _compact_job(self) -> int:
        from repro.ann.distributed import shard_bounds

        snaps = None
        try:
            with self._lock:
                snaps = [s.snapshot() for s in self.shards]
                locs = list(self._delta_loc)
                old_total = self._total_base + len(locs)
                old_keys = self._keys[:old_total].copy()
                wal = self._wal
                if wal is not None:
                    seq = wal.log_compact(self._epoch)
            if wal is not None:
                wal.commit(seq)
            vectors, bitmaps, kept = self._gather(snaps, locs)
            new_ds, order = ANNDataset.from_packed(
                self._name, vectors, bitmaps, self._universe,
                return_order=True)
            inv = np.empty(order.size, np.int64)
            inv[order] = np.arange(order.size)
            remap = np.full(old_total, -1, np.int64)
            remap[kept] = inv
            new_keys = np.empty(new_ds.n, np.int64)
            new_keys[remap[kept]] = old_keys[kept]
            nsh = self.n_shards
            built = []
            for s in self.shards:
                built.extend(k for k in s.built_keys() if k not in built)
            if new_ds.n >= nsh:
                new_bounds = shard_bounds(new_ds.n, nsh)
                new_shards = [
                    LiveFilteredIndex(
                        new_ds.row_slice(int(a), int(b),
                                         name=f"{self._name}/shard{i}"),
                        device=self._devices[i], **self._shard_kw())
                    for i, (a, b) in enumerate(zip(new_bounds[:-1],
                                                   new_bounds[1:]))]
                new_base: ANNDataset | None = new_ds
            else:
                # fewer surviving rows than shards: restart from empty
                # shards and replay the rows as delta below
                new_bounds = np.zeros(nsh + 1, dtype=np.int64)
                new_shards = [
                    LiveFilteredIndex.empty(
                        f"{self._name}/shard{i}", self._dim,
                        self._universe, device=self._devices[i],
                        **self._shard_kw())
                    for i in range(nsh)]
                new_base = None
            for shard in new_shards:
                if shard._base_fx is None:
                    continue
                for m_name, build in built:
                    try:
                        shard._base_fx.get_index(m_name, build)
                    except KeyError:
                        pass
            with self._lock:
                if self._closed:
                    for s in new_shards:
                        s.close()
                    return self._epoch
                old_shards = self.shards
                old_locs_n = len(locs)
                tail = self._delta_loc[old_locs_n:]
                late_tomb: list[int] = []       # old gids deleted late
                for s, snap in enumerate(snaps):
                    cur = old_shards[s]._tomb
                    newly = cur[: snap.n_total] & ~snap.tombstones
                    lids = np.nonzero(newly)[0]
                    for lid in lids:
                        if lid < snap.base_n:
                            late_tomb.append(int(self.bounds[s]) + int(lid))
                        else:
                            row = int(lid) - snap.base_n
                            gid = self._shard_gids[s][row]
                            late_tomb.append(int(gid))
                # collect tail rows (upserted during the rebuild) in
                # global insertion order, with their current tombstones
                tail_rows = []
                for j, (s, row) in enumerate(tail):
                    shard = old_shards[s]
                    vec = shard._delta._vec[row]
                    bm = shard._delta._bm[row]
                    dead = bool(shard._tomb[shard.base_n + row])
                    tail_rows.append((vec, bm, dead))
                tail_keys = self._keys[old_total: old_total + len(tail)]
                old_epoch = self._epoch
                self.shards = new_shards
                self.bounds = new_bounds
                self._base_ds = new_base
                self._total_base = new_ds.n if new_base is not None else 0
                self._delta_loc = []
                self._shard_gids = [[] for _ in new_shards]
                self._gid_arrays = None
                self._next_shard = 0
                self._keys = (new_keys if new_base is not None
                              else np.zeros(0, np.int64))
                self._key_rows = None
                self._epoch = old_epoch + 1
                self._last_remap = remap
                self._features = None
                if self._feature_fx is not None:
                    self._feature_fx.close()
                    self._feature_fx = None
                # replay: rows that didn't make the snapshot (and every
                # row when the base fell below the shard count), carrying
                # their stable keys; the WAL stays quiet — these rows'
                # original upsert/delete records already cover them
                replay = []
                if new_base is None and new_ds.n:
                    replay.append((new_ds.vectors, new_ds.bitmaps, None,
                                   new_keys))
                if tail_rows:
                    replay.append((
                        np.stack([t[0] for t in tail_rows]),
                        np.stack([t[1] for t in tail_rows]),
                        np.array([t[2] for t in tail_rows], bool),
                        tail_keys))
                self._wal_quiet = True
                try:
                    for vecs, bms, dead, ks in replay:
                        gids = self.upsert(vecs, bms, keys=ks)
                        if dead is not None and dead.any():
                            self.delete(gids[dead])
                    if late_tomb:
                        ng = remap[np.asarray(late_tomb, np.int64)]
                        ng = ng[(ng >= 0) & (ng < self._total_base
                                             + len(self._delta_loc))]
                        if ng.size:
                            self.delete(ng)
                finally:
                    self._wal_quiet = False
                if self._epoch_readers.get(old_epoch):
                    self._old_shards[old_epoch] = old_shards
                else:
                    for s in old_shards:
                        s.close()
                return self._epoch
        finally:
            if snaps is not None:
                for snap in snaps:
                    snap.release()
            with self._lock:
                self._compacting = None

    # ---- maintenance -----------------------------------------------------
    def export_state(self, snap: ShardedLiveSnapshot) -> dict:
        """Full logical state of a pinned cross-shard epoch, in *global*
        id order — the same contract as `LiveFilteredIndex.export_state`
        (what a `repro.ann.store` checkpoint persists)."""
        base_n = int(snap.bounds[-1])
        n_delta = len(snap.locs)
        width = lb.n_words(self._universe)
        dvec = np.zeros((n_delta, self._dim), np.float32)
        dbm = np.zeros((n_delta, width), np.uint32)
        delta_dead = np.zeros(n_delta, bool)
        if n_delta:
            loc_shard = np.array([l[0] for l in snap.locs], np.int64)
            loc_row = np.array([l[1] for l in snap.locs], np.int64)
            for s, ssnap in enumerate(snap.snaps):
                mine = loc_shard == s
                if not mine.any():
                    continue
                sv, sb, _ = ssnap.delta.host_view(ssnap.delta_rows)
                rows = loc_row[mine]
                dvec[mine] = sv[rows]
                dbm[mine] = sb[rows]
                delta_dead[mine] = ssnap.tombstones[ssnap.base_n + rows]
        dead = [base_n + np.nonzero(delta_dead)[0]]
        for s, ssnap in enumerate(snap.snaps):
            lids = np.nonzero(ssnap.tombstones[: ssnap.base_n])[0]
            if lids.size:
                dead.append(int(snap.bounds[s]) + lids)
        return {
            "generation": snap.epoch,
            "base_ds": snap.base_ds,
            "base_keys": snap.keys[:base_n],
            "delta_vectors": dvec,
            "delta_bitmaps": dbm,
            "delta_keys": snap.keys[base_n:],
            "dead_ids": np.sort(np.concatenate(dead)).astype(np.int64),
            "next_key": snap.next_key,
        }

    def last_remap(self) -> np.ndarray | None:
        """Global-id translation of the most recent `compact()` (see
        `LiveFilteredIndex.last_remap`)."""
        return self._last_remap

    def stats(self) -> dict:
        with self._lock:
            return {
                "dataset": self._name,
                "generation": self._epoch,
                "n_shards": self.n_shards,
                "base_n": self._total_base,
                "delta_rows": len(self._delta_loc),
                "n_live": sum(s.n_live for s in self.shards),
                "next_key": self._next_key,
                "wal_attached": self._wal is not None,
                "compacting": (self._compacting is not None
                               and not self._compacting.done()),
                "closed": self._closed,
                "shards": [s.stats() for s in self.shards],
            }
