"""Pluggable filtered-ANN method registry.

Methods register once (the six built-ins auto-register on first use; new
methods call `register_method` from anywhere — no core edits needed) and
every consumer resolves them through live *views*:
`candidate_methods()` is what the router selects among, `all_methods()`
additionally includes non-candidates such as the exact Pre-filter
baseline. `repro.ann.methods.CANDIDATE_METHODS` / `ALL_METHODS` are these
views, so existing `dict`-style call sites keep working unchanged.
"""

from __future__ import annotations

from collections.abc import Mapping


class MethodRegistry:
    """Name -> Method instance, insertion-ordered, with a candidate flag.

    `candidate=True` methods are the router's selection pool; candidates
    are what `RouterService` dispatches among, non-candidates (e.g. the
    exact Pre-filter reference) are still searchable directly.
    """

    def __init__(self):
        self._methods: dict[str, object] = {}
        self._candidate: dict[str, bool] = {}

    # ---- registration ---------------------------------------------------
    def register(self, method, *, candidate: bool = True,
                 overwrite: bool = False, name: str | None = None):
        name = name or getattr(method, "name", None)
        if not name or name == "?":
            raise ValueError("method must carry a non-empty .name "
                             "(or pass name= explicitly)")
        if name in self._methods and not overwrite:
            raise ValueError(
                f"method {name!r} is already registered; pass "
                f"overwrite=True to replace it")
        self._methods[name] = method
        self._candidate[name] = bool(candidate)
        return method

    def unregister(self, name: str) -> None:
        self._methods.pop(name, None)
        self._candidate.pop(name, None)

    # ---- resolution -----------------------------------------------------
    def get(self, name: str):
        try:
            return self._methods[name]
        except KeyError:
            raise KeyError(
                f"unknown method {name!r}; registered: "
                f"{sorted(self._methods)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._methods

    def names(self, *, candidates_only: bool = False) -> list[str]:
        return [n for n in self._methods
                if not candidates_only or self._candidate[n]]

    def is_candidate(self, name: str) -> bool:
        return self._candidate.get(name, False)

    def view(self, *, candidates_only: bool = False) -> "RegistryView":
        return RegistryView(self, candidates_only=candidates_only)


class RegistryView(Mapping):
    """Live, read-only Mapping over a registry subset — reflects later
    registrations immediately (this is what makes `CANDIDATE_METHODS`
    extensible without core edits)."""

    def __init__(self, registry: MethodRegistry, *, candidates_only: bool):
        self._registry = registry
        self._candidates_only = candidates_only

    def __getitem__(self, name: str):
        if self._candidates_only and not self._registry.is_candidate(name):
            raise KeyError(name)
        return self._registry.get(name)

    def __iter__(self):
        return iter(self._registry.names(
            candidates_only=self._candidates_only))

    def __len__(self) -> int:
        return len(self._registry.names(
            candidates_only=self._candidates_only))

    def __repr__(self) -> str:
        kind = "candidates" if self._candidates_only else "all"
        return f"RegistryView({kind}: {list(self)})"


_DEFAULT = MethodRegistry()
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    """Import repro.ann.methods once so the six built-ins register."""
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True   # set first: guards re-entrant import
        try:
            import repro.ann.methods  # noqa: F401  (registers on import)
        except BaseException:
            _BUILTINS_LOADED = False   # don't poison the flag on failure
            raise


def default_registry() -> MethodRegistry:
    _ensure_builtins()
    return _DEFAULT


def register_method(method, *, candidate: bool = True,
                    overwrite: bool = False, name: str | None = None):
    """Register a Method instance in the default registry.

    Args:
        method: an `engine.Method` instance carrying a non-empty `.name`
            (or pass `name=` explicitly).
        candidate: True puts the method in the router's selection pool
            (`CANDIDATE_METHODS`); False keeps it direct-search only.
        overwrite: allow replacing an already-registered name.
        name: optional explicit registration name.
    Returns: the method (so the call can be used as a decorator-ish
        one-liner at module import).
    Raises: ValueError for a missing name or a duplicate without
        `overwrite=True`.
    """
    return _DEFAULT.register(method, candidate=candidate,
                             overwrite=overwrite, name=name)


def unregister_method(name: str) -> None:
    _DEFAULT.unregister(name)


def get_method(name: str):
    return default_registry().get(name)


def serialize_index(method, index) -> dict | None:
    """Persistable array form of a built index, resolving method names
    through the default registry. Returns a dict of numpy arrays when
    the method supports (de)serialization (`Method.index_arrays`), else
    None — the caller records the build key and rebuilds from the
    dataset on load."""
    if isinstance(method, str):
        method = get_method(method)
    return method.index_arrays(index)


def deserialize_index(method, ds, build_params: dict, arrays: dict):
    """Restore a built index from `serialize_index` output (method name
    or instance; `build_params` as passed to `Method.build`)."""
    if isinstance(method, str):
        method = get_method(method)
    return method.index_from_arrays(ds, dict(build_params), dict(arrays))


def candidate_methods() -> RegistryView:
    """Live view of the router's candidate pool."""
    return default_registry().view(candidates_only=True)


def all_methods() -> RegistryView:
    """Live view of every registered method (candidates + baselines)."""
    return default_registry().view()
