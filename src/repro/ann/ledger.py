"""Resource ledger: central accounting for lifecycle-bound resources.

Serving leaks are rarely loud: a snapshot pin that is never released
keeps a whole retired generation's device arrays alive, an unsynced WAL
tail silently grows until a crash eats minutes of writes, a cache that
never evicts looks healthy until the allocator stalls.  The ledger
makes all of those *observable* through one registry with two
complementary mechanisms:

* **Leases** — explicit acquire/release records for resources with a
  lifecycle (snapshot pins, retired generations).  Each lease stamps
  the acquiring request's trace id (via :func:`repro.ann.trace.trace_id`)
  and a short caller stack, so a leak report answers "who took it and
  from where", not just "something is held".  :meth:`ResourceLedger.leaks`
  returns every lease held past a configurable age.
* **Collectors** — zero-hot-path-cost pull gauges.  A subsystem
  registers a callable returning ``{gauge_name: number}``; the ledger
  invokes it only at :meth:`snapshot` / scrape time.  Delta/device
  bytes, cache entries/bytes, WAL backlog and queue depth all report
  this way, so attaching the ledger costs the serve path nothing.

A process-wide default ledger (:func:`get_ledger`) lets deep layers
(live index, WAL) register without threading a handle through every
constructor; tests isolate with :func:`scoped`.
"""

from __future__ import annotations

import contextlib
import itertools
import sys
import threading
import time
from typing import Any, Callable

from repro.ann import trace

__all__ = [
    "Lease",
    "ResourceLedger",
    "get_ledger",
    "set_ledger",
    "scoped",
]


def _caller_stack(skip: int, depth: int) -> list[str]:
    """``file:line:function`` for up to ``depth`` frames above the
    acquire call.  A manual frame walk, not ``traceback.extract_stack``:
    the latter renders source lines and costs tens of µs, which matters
    on the snapshot-pin path."""
    out: list[str] = []
    try:
        f = sys._getframe(skip)
    except ValueError:  # pragma: no cover - shallow interpreter stack
        return out
    while f is not None and len(out) < depth:
        code = f.f_code
        fname = code.co_filename.rsplit("/", 1)[-1]
        out.append(f"{fname}:{f.f_lineno}:{code.co_name}")
        f = f.f_back
    return out


class Lease:
    """One held resource.  Release exactly once (idempotent); usable as
    a context manager for scope-bound holds."""

    __slots__ = ("lease_id", "kind", "owner", "count", "bytes", "meta",
                 "t0", "t_wall", "trace_id", "stack", "_ledger",
                 "released")

    def __init__(self, lease_id: int, kind: str, owner: str, *,
                 count: int, bytes: int, meta: dict | None,
                 trace_id: str | None, stack: list[str],
                 ledger: "ResourceLedger"):
        self.lease_id = lease_id
        self.kind = kind
        self.owner = owner
        self.count = int(count)
        self.bytes = int(bytes)
        self.meta = meta or {}
        self.t0 = time.monotonic()
        self.t_wall = time.time()
        self.trace_id = trace_id
        self.stack = stack
        self._ledger = ledger
        self.released = False

    @property
    def age_s(self) -> float:
        return time.monotonic() - self.t0

    def release(self) -> None:
        led = self._ledger
        if led is not None:
            self._ledger = None
            led._release(self)

    def __enter__(self) -> "Lease":
        return self

    def __exit__(self, et, ev, tb) -> bool:
        self.release()
        return False

    def to_dict(self) -> dict:
        return {"id": self.lease_id, "kind": self.kind,
                "owner": self.owner, "count": self.count,
                "bytes": self.bytes, "age_s": round(self.age_s, 3),
                "trace_id": self.trace_id, "stack": list(self.stack),
                "meta": dict(self.meta)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Lease({self.kind}/{self.owner}, id={self.lease_id}, "
                f"age={self.age_s:.3f}s)")


class ResourceLedger:
    """Registry of held leases + pull-time gauge collectors.

    Args:
        leak_age_s: default age beyond which a held lease counts as a
            leak (override per :meth:`leaks` call).
        capture_stacks: stamp a short caller stack on every acquire
            (cheap frame walk; disable for the absolute minimum cost).
        stack_depth: frames kept per lease.
    """

    def __init__(self, *, leak_age_s: float = 30.0,
                 capture_stacks: bool = True, stack_depth: int = 5):
        self.leak_age_s = float(leak_age_s)
        self.capture_stacks = bool(capture_stacks)
        self.stack_depth = int(stack_depth)
        self._mu = threading.Lock()
        self._ids = itertools.count(1)
        self._leases: dict[int, Lease] = {}
        self._collectors: dict[str, Callable[[], dict]] = {}
        self._acquired: dict[str, int] = {}
        self._released: dict[str, int] = {}

    # -- leases ------------------------------------------------------------
    def acquire(self, kind: str, owner: str, *, count: int = 1,
                bytes: int = 0, meta: dict | None = None) -> Lease:
        """Record a held resource; returns the lease to release."""
        stack = (_caller_stack(2, self.stack_depth)
                 if self.capture_stacks else [])
        lease = Lease(next(self._ids), str(kind), str(owner),
                      count=count, bytes=bytes, meta=meta,
                      trace_id=trace.trace_id(), stack=stack, ledger=self)
        with self._mu:
            self._leases[lease.lease_id] = lease
            self._acquired[lease.kind] = \
                self._acquired.get(lease.kind, 0) + 1
        return lease

    def _release(self, lease: Lease) -> None:
        with self._mu:
            if self._leases.pop(lease.lease_id, None) is None:
                return
            lease.released = True
            self._released[lease.kind] = \
                self._released.get(lease.kind, 0) + 1

    def leases(self, kind: str | None = None) -> list[Lease]:
        with self._mu:
            out = list(self._leases.values())
        if kind is not None:
            out = [l for l in out if l.kind == kind]
        return sorted(out, key=lambda l: l.lease_id)

    def leaks(self, max_age_s: float | None = None) -> list[dict]:
        """Held leases older than ``max_age_s`` (default: the ledger's
        ``leak_age_s``), oldest first — each with the acquiring trace id
        and stack so the pin can be chased to its call site."""
        limit = self.leak_age_s if max_age_s is None else float(max_age_s)
        out = [l.to_dict() for l in self.leases() if l.age_s > limit]
        out.sort(key=lambda d: -d["age_s"])
        return out

    # -- collectors --------------------------------------------------------
    def register_collector(self, name: str,
                           fn: Callable[[], dict]) -> None:
        """Register a pull gauge source: ``fn() -> {gauge: number}``.
        Re-registering a name replaces the previous collector."""
        with self._mu:
            self._collectors[str(name)] = fn

    def deregister_collector(self, name: str) -> None:
        with self._mu:
            self._collectors.pop(str(name), None)

    def gauges(self) -> dict[str, dict[str, float]]:
        """Pull every collector; a failing collector reports an
        ``error`` pseudo-gauge instead of poisoning the scrape."""
        with self._mu:
            items = list(self._collectors.items())
        out: dict[str, dict[str, float]] = {}
        for name, fn in items:
            try:
                vals = fn()
                out[name] = {str(k): float(v) for k, v in vals.items()}
            except Exception as e:  # collector bug != scrape outage
                out[name] = {"error": 1.0}
                out[name]["_error_msg"] = str(e)  # type: ignore[assignment]
        return out

    # -- accounting --------------------------------------------------------
    def accounting(self) -> dict[str, dict[str, dict[str, int]]]:
        """``{kind: {owner: {leases, count, bytes}}}`` over held leases."""
        out: dict[str, dict[str, dict[str, int]]] = {}
        for l in self.leases():
            row = out.setdefault(l.kind, {}).setdefault(
                l.owner, {"leases": 0, "count": 0, "bytes": 0})
            row["leases"] += 1
            row["count"] += l.count
            row["bytes"] += l.bytes
        return out

    def counters(self) -> dict[str, dict[str, int]]:
        with self._mu:
            kinds = set(self._acquired) | set(self._released)
            return {k: {"acquired": self._acquired.get(k, 0),
                        "released": self._released.get(k, 0)}
                    for k in sorted(kinds)}

    def snapshot(self, *, leak_age_s: float | None = None) -> dict:
        """One JSON-able view: held accounting, lifetime counters,
        collector gauges, and the current leak report."""
        gauges = self.gauges()
        errors = {n: g.pop("_error_msg") for n, g in gauges.items()
                  if "_error_msg" in g}
        snap = {"t_wall": time.time(),
                "held": self.accounting(),
                "counters": self.counters(),
                "gauges": gauges,
                "leaks": self.leaks(leak_age_s)}
        if errors:
            snap["collector_errors"] = errors
        return snap

    def clear(self) -> None:
        with self._mu:
            self._leases.clear()
            self._collectors.clear()
            self._acquired.clear()
            self._released.clear()


_DEFAULT = ResourceLedger()
_CURRENT: ResourceLedger = _DEFAULT


def get_ledger() -> ResourceLedger:
    """The process-wide ledger deep layers register against."""
    return _CURRENT


def set_ledger(ledger: ResourceLedger) -> ResourceLedger:
    """Swap the process-wide ledger; returns the previous one."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = ledger
    return prev


@contextlib.contextmanager
def scoped(ledger: ResourceLedger | None = None):
    """Install a fresh (or given) ledger for the scope — test isolation
    without cross-test lease bleed-through."""
    led = ledger if ledger is not None else ResourceLedger()
    prev = set_ledger(led)
    try:
        yield led
    finally:
        set_ledger(prev)
