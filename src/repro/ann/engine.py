"""Shared execution machinery for filtered-ANN methods.

* `DeviceData` — per-dataset device-resident tensors (vectors, norms,
  bitmaps, group tables), cached per dataset.
* word-looped predicate masks that avoid materialising `[Q, N, W]`
  temporaries (predicate type is a *traced* scalar so one compiled
  executable serves all three predicates).
* query chunking: every method's jitted inner function runs on fixed-size
  query chunks (static shapes), with host-side padding of the tail chunk.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate

DEFAULT_QCHUNK = 64


@dataclasses.dataclass(frozen=True)
class DeviceData:
    vectors: jax.Array        # [N, d] f32
    norms: jax.Array          # [N] f32
    bitmaps: jax.Array        # [N, W] uint32
    group_bitmaps: jax.Array  # [G, W] uint32
    group_start: jax.Array    # [G] i32
    group_size: jax.Array     # [G] i32
    group_centroids: jax.Array  # [G, d] f32
    group_cnorms: jax.Array     # [G] f32


# Device-data cache keyed by stable content identity (ANNDataset.cache_key)
# — id() keys can be recycled after garbage collection and would silently
# serve another dataset's tensors. The array cache pins the host array
# alongside the device copy for the same reason (a live reference makes
# the id stable).
_DEVICE_CACHE: dict[tuple, DeviceData] = {}
_ARRAY_CACHE: dict[int, tuple] = {}


def clear_caches() -> None:
    """Evict cached device tensors, host-array uploads, and built indexes."""
    _DEVICE_CACHE.clear()
    _ARRAY_CACHE.clear()
    _INDEX_CACHE.clear()


def as_device(x):
    """Cached np→device conversion (keeps QPS timing free of re-uploads)."""
    key = id(x)
    hit = _ARRAY_CACHE.get(key)
    if hit is None or hit[0] is not x:
        hit = (x, jnp.asarray(x))
        _ARRAY_CACHE[key] = hit
    return hit[1]


def device_data(ds: ANNDataset) -> DeviceData:
    key = ds.cache_key()
    if key not in _DEVICE_CACHE:
        g = ds.n_groups
        cent = np.zeros((g, ds.dim), dtype=np.float32)
        for j in range(g):
            s, l = int(ds.group_start[j]), int(ds.group_size[j])
            cent[j] = ds.vectors[s:s + l].mean(0)
        _DEVICE_CACHE[key] = DeviceData(
            vectors=jnp.asarray(ds.vectors),
            norms=jnp.asarray(ds.norms_sq),
            bitmaps=jnp.asarray(ds.bitmaps),
            group_bitmaps=jnp.asarray(ds.group_bitmaps),
            group_start=jnp.asarray(ds.group_start),
            group_size=jnp.asarray(ds.group_size),
            group_centroids=jnp.asarray(cent),
            group_cnorms=jnp.asarray((cent ** 2).sum(1).astype(np.float32)),
        )
    return _DEVICE_CACHE[key]


# ---------------------------------------------------------------------------
# predicate masks with traced predicate index (one executable, 3 predicates)
# ---------------------------------------------------------------------------

def mask_shared(base_bm: jax.Array, q_bm: jax.Array, pred_idx) -> jax.Array:
    """base [N, W] × query [Q, W] -> bool [Q, N], word-looped (no 3-D temp)."""
    n, w = base_bm.shape
    q = q_bm.shape[0]

    def eq_():
        acc = jnp.ones((q, n), bool)
        for i in range(w):
            acc &= base_bm[None, :, i] == q_bm[:, i, None]
        return acc

    def and_():
        acc = jnp.ones((q, n), bool)
        for i in range(w):
            qw = q_bm[:, i, None]
            acc &= (base_bm[None, :, i] & qw) == qw
        return acc

    def or_():
        acc = jnp.zeros((q, n), bool)
        for i in range(w):
            acc |= (base_bm[None, :, i] & q_bm[:, i, None]) != 0
        return acc

    return jax.lax.switch(pred_idx, [eq_, and_, or_])


def mask_cand(cand_bm: jax.Array, q_bm: jax.Array, pred_idx) -> jax.Array:
    """candidates [Q, C, W] × query [Q, W] -> bool [Q, C]."""
    q, c, w = cand_bm.shape

    def eq_():
        acc = jnp.ones((q, c), bool)
        for i in range(w):
            acc &= cand_bm[:, :, i] == q_bm[:, i, None]
        return acc

    def and_():
        acc = jnp.ones((q, c), bool)
        for i in range(w):
            qw = q_bm[:, i, None]
            acc &= (cand_bm[:, :, i] & qw) == qw
        return acc

    def or_():
        acc = jnp.zeros((q, c), bool)
        for i in range(w):
            acc |= (cand_bm[:, :, i] & q_bm[:, i, None]) != 0
        return acc

    return jax.lax.switch(pred_idx, [eq_, and_, or_])


# ---------------------------------------------------------------------------
# query chunking
# ---------------------------------------------------------------------------

def run_chunked(fn, n_queries: int, *arrays, chunk: int = DEFAULT_QCHUNK,
                extra_host=None):
    """Run `fn(chunked_arrays..., extra_host_chunk...)` over fixed-size query
    chunks; pads the tail chunk; returns np.concatenate of outputs.

    arrays: per-query arrays, leading axis Q. extra_host: same, but kept as
    numpy (for host-side lookups already resolved to per-query values).
    """
    outs = []
    for s in range(0, n_queries, chunk):
        e = min(s + chunk, n_queries)
        pad = chunk - (e - s)
        parts = []
        for a in arrays:
            part = a[s:e]
            if pad:
                part = np.concatenate([part, np.repeat(part[-1:], pad, axis=0)], axis=0)
            parts.append(part)
        hparts = []
        if extra_host is not None:
            for a in extra_host:
                part = a[s:e]
                if pad:
                    part = np.concatenate([part, np.repeat(part[-1:], pad, axis=0)], axis=0)
                hparts.append(part)
        res = fn(*parts, *hparts)
        res = np.asarray(res)
        outs.append(res[: e - s])
    return np.concatenate(outs, axis=0)


# ---------------------------------------------------------------------------
# method registry base
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSetting:
    ps_id: str
    build: tuple       # sorted (key, value) pairs — hashable
    search: tuple

    @property
    def build_dict(self):
        return dict(self.build)

    @property
    def search_dict(self):
        return dict(self.search)


def ps(ps_id: str, build: dict | None = None, search: dict | None = None) -> ParamSetting:
    return ParamSetting(ps_id,
                        tuple(sorted((build or {}).items())),
                        tuple(sorted((search or {}).items())))


class Method:
    """Interface all filtered-ANN methods implement."""

    name: str = "?"

    def param_settings(self) -> list[ParamSetting]:
        raise NotImplementedError

    def build(self, ds: ANNDataset, build_params: dict):
        """Offline index build; returns opaque index object."""
        return None

    def search(self, ds: ANNDataset, index, qvecs: np.ndarray,
               qbms: np.ndarray, pred: Predicate, k: int,
               search_params: dict) -> np.ndarray:
        """Batched filtered search; returns [Q, k] int32 ids (−1 pad)."""
        raise NotImplementedError


_INDEX_CACHE: dict = {}


def get_index(method: Method, ds: ANNDataset, build_params: tuple):
    key = (method.name, ds.name, ds.n, build_params)
    if key not in _INDEX_CACHE:
        _INDEX_CACHE[key] = method.build(ds, dict(build_params))
    return _INDEX_CACHE[key]
