"""Shared execution machinery for filtered-ANN methods.

* `DeviceData` — per-dataset device-resident tensors (vectors, norms,
  bitmaps, group tables). Ownership lives in `repro.ann.index.
  FilteredIndex` (the PR-2 `device_data`/`as_device`/`get_index`
  deprecation shims are gone; see docs/serving.md for the migration).
* word-looped predicate masks that avoid materialising `[Q, N, W]`
  temporaries (predicate type is a *traced* scalar so one compiled
  executable serves all three predicates).
* query chunking: every method's jitted inner function runs on fixed-size
  query chunks (static shapes), with host-side padding of the tail chunk.
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann.dataset import ANNDataset
from repro.ann.predicates import Predicate

DEFAULT_QCHUNK = 64


# ---------------------------------------------------------------------------
# per-call stage timing plumbing (shared by live/sharded search paths)
# ---------------------------------------------------------------------------

class StageTimings(threading.local):
    """Thread-local per-search stage timing accumulator.

    Search internals call `add(stage, seconds)`; the outermost caller
    drains with `pop()`. Thread-local so concurrent searches (the service
    executor, sharded fan-out threads) never cross-contaminate."""

    def __init__(self):
        self.stages: dict[str, float] = {}

    def add(self, stage: str, seconds: float) -> None:
        self.stages[stage] = self.stages.get(stage, 0.0) + seconds

    def pop(self) -> dict[str, float]:
        out = dict(self.stages)
        self.stages.clear()
        return out


STAGE_TIMINGS = StageTimings()


def stage_add(stage: str, seconds: float) -> None:
    STAGE_TIMINGS.add(stage, seconds)


def pop_stage_timings() -> dict[str, float]:
    """Drain the calling thread's accumulated per-stage timings."""
    return STAGE_TIMINGS.pop()


@dataclasses.dataclass(frozen=True)
class DeviceData:
    vectors: jax.Array        # [N, d] f32
    norms: jax.Array          # [N] f32
    bitmaps: jax.Array        # [N, W] uint32
    group_bitmaps: jax.Array  # [G, W] uint32
    group_start: jax.Array    # [G] i32
    group_size: jax.Array     # [G] i32
    group_centroids: jax.Array  # [G, d] f32
    group_cnorms: jax.Array     # [G] f32


def clear_caches() -> None:
    """Evict the default handle pool (owned caches live on FilteredIndex)."""
    from repro.ann.index import clear_pool

    clear_pool()


# ---------------------------------------------------------------------------
# predicate masks with traced predicate index (one executable, 3 predicates)
# ---------------------------------------------------------------------------

def mask_shared(base_bm: jax.Array, q_bm: jax.Array, pred_idx) -> jax.Array:
    """base [N, W] × query [Q, W] -> bool [Q, N], word-looped (no 3-D temp)."""
    n, w = base_bm.shape
    q = q_bm.shape[0]

    def eq_():
        acc = jnp.ones((q, n), bool)
        for i in range(w):
            acc &= base_bm[None, :, i] == q_bm[:, i, None]
        return acc

    def and_():
        acc = jnp.ones((q, n), bool)
        for i in range(w):
            qw = q_bm[:, i, None]
            acc &= (base_bm[None, :, i] & qw) == qw
        return acc

    def or_():
        acc = jnp.zeros((q, n), bool)
        for i in range(w):
            acc |= (base_bm[None, :, i] & q_bm[:, i, None]) != 0
        return acc

    return jax.lax.switch(pred_idx, [eq_, and_, or_])


def mask_cand(cand_bm: jax.Array, q_bm: jax.Array, pred_idx) -> jax.Array:
    """candidates [Q, C, W] × query [Q, W] -> bool [Q, C]."""
    q, c, w = cand_bm.shape

    def eq_():
        acc = jnp.ones((q, c), bool)
        for i in range(w):
            acc &= cand_bm[:, :, i] == q_bm[:, i, None]
        return acc

    def and_():
        acc = jnp.ones((q, c), bool)
        for i in range(w):
            qw = q_bm[:, i, None]
            acc &= (cand_bm[:, :, i] & qw) == qw
        return acc

    def or_():
        acc = jnp.zeros((q, c), bool)
        for i in range(w):
            acc |= (cand_bm[:, :, i] & q_bm[:, i, None]) != 0
        return acc

    return jax.lax.switch(pred_idx, [eq_, and_, or_])


# ---------------------------------------------------------------------------
# query chunking
# ---------------------------------------------------------------------------

def run_chunked(fn, n_queries: int, *arrays, chunk: int = DEFAULT_QCHUNK,
                extra_host=None):
    """Run `fn(chunked_arrays..., extra_host_chunk...)` over fixed-size query
    chunks; pads the tail chunk; returns np.concatenate of outputs.

    arrays: per-query arrays, leading axis Q. extra_host: same, but kept as
    numpy (for host-side lookups already resolved to per-query values).
    `fn` may return a single array or a tuple of per-query arrays — tuple
    outputs are concatenated position-wise (e.g. (ids, dists)).
    """
    outs = []
    for s in range(0, n_queries, chunk):
        e = min(s + chunk, n_queries)
        pad = chunk - (e - s)
        parts = []
        for a in arrays:
            part = a[s:e]
            if pad:
                part = np.concatenate([part, np.repeat(part[-1:], pad, axis=0)], axis=0)
            parts.append(part)
        hparts = []
        if extra_host is not None:
            for a in extra_host:
                part = a[s:e]
                if pad:
                    part = np.concatenate([part, np.repeat(part[-1:], pad, axis=0)], axis=0)
                hparts.append(part)
        res = fn(*parts, *hparts)
        if isinstance(res, tuple):
            outs.append(tuple(np.asarray(r)[: e - s] for r in res))
        else:
            outs.append(np.asarray(res)[: e - s])
    if isinstance(outs[0], tuple):
        return tuple(np.concatenate([o[i] for o in outs], axis=0)
                     for i in range(len(outs[0])))
    return np.concatenate(outs, axis=0)


# ---------------------------------------------------------------------------
# method interface
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSetting:
    ps_id: str
    build: tuple       # sorted (key, value) pairs — hashable
    search: tuple

    @property
    def build_dict(self):
        return dict(self.build)

    @property
    def search_dict(self):
        return dict(self.search)


def ps(ps_id: str, build: dict | None = None, search: dict | None = None) -> ParamSetting:
    return ParamSetting(ps_id,
                        tuple(sorted((build or {}).items())),
                        tuple(sorted((search or {}).items())))


def resolve_setting(method: "Method", ps_id: str | None) -> ParamSetting:
    """The method's setting for `ps_id`, else its max-budget setting (the
    fallback for deployment datasets the offline table hasn't covered)."""
    settings = method.param_settings()
    for s in settings:
        if s.ps_id == ps_id:
            return s
    return settings[-1]


class Method:
    """Interface all filtered-ANN methods implement.

    Methods are stateless: all per-dataset state (device tensors, upload
    cache, built indexes) is owned by the `FilteredIndex` handle passed
    to `search`.
    """

    name: str = "?"

    def param_settings(self) -> list[ParamSetting]:
        raise NotImplementedError

    def build(self, ds: ANNDataset, build_params: dict):
        """Offline index build; returns opaque index object."""
        return None

    def index_arrays(self, index) -> dict | None:
        """Persistable form of a built index, or None.

        A dict of numpy arrays (possibly empty, for a stateless build)
        means the index is cheap to persist: `repro.ann.store` writes it
        as an ``.npz`` per generation and `index_from_arrays` restores
        it on open. None (the default) means the build is rebuilt from
        the dataset instead — correct for every method, just slower on
        cold open.
        """
        return None

    def index_from_arrays(self, ds: ANNDataset, build_params: dict,
                          arrays: dict):
        """Inverse of `index_arrays`; only called when it returned a
        dict for this method."""
        raise NotImplementedError(
            f"method {self.name!r} does not persist its index")

    def search(self, fx, index, qvecs: np.ndarray, qbms: np.ndarray,
               pred: Predicate, k: int, search_params: dict):
        """Batched filtered search against the owned handle `fx`
        (`repro.ann.index.FilteredIndex`). Returns
        ([Q, k] int32 ids with −1 pad, [Q, k] float32 ranking scores
        ‖v‖² − 2·q·v, +inf where the id is −1)."""
        raise NotImplementedError

    def graft_index(self, new_ds: ANNDataset, old_index, old_ds: ANNDataset,
                    old_to_new: np.ndarray, new_rows: np.ndarray,
                    build_params: dict):
        """Incremental rebuild for compaction: splice the rows of
        `new_ds` into `old_index` via the id remap instead of building
        from scratch.

        `old_to_new` maps old row ids to new ids (−1 = deleted);
        `new_rows` lists the new ids that did not exist in `old_ds`
        (compacted delta rows). Returns the grafted index, or None
        (the default) to signal the caller to fall back to a full
        `build` — correct for every method, just linear in base size.
        """
        return None
