"""Distance + top-k primitives shared by all filtered-ANN methods.

Distances are squared-L2 ranked via ``||v||² − 2·v·q`` (the query norm is
rank-invariant and omitted). Candidate top-k runs on fixed-shape padded id
arrays with −1 padding; duplicate candidates are suppressed with the
sort-adjacency trick (equal ids ⇒ equal distances ⇒ adjacent after a stable
sort by (distance, id)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


def score_all(qvecs: jax.Array, base: jax.Array, base_norms: jax.Array) -> jax.Array:
    """Full [Q, N] ranking scores (squared-L2 up to a per-query constant)."""
    return base_norms[None, :] - 2.0 * (qvecs @ base.T)


def score_candidates(qvecs: jax.Array, cand_vecs: jax.Array,
                     cand_norms: jax.Array) -> jax.Array:
    """Per-candidate scores. qvecs [Q,d], cand_vecs [Q,C,d] -> [Q,C]."""
    dots = jnp.einsum("qd,qcd->qc", qvecs, cand_vecs)
    return cand_norms - 2.0 * dots


def topk_ids(scores: jax.Array, ids: jax.Array, k: int,
             valid=None, dedup: bool = False):
    """Top-k smallest-score candidate ids.

    scores [Q, C] float32; ids [Q, C] int32 (−1 = padding); valid optional
    bool [Q, C]. Returns (ids [Q, k] int32 with −1 fill, scores [Q, k]).
    """
    bad = ids < 0
    if valid is not None:
        bad = bad | ~valid
    scores = jnp.where(bad, INF, scores)
    if dedup:
        order = jnp.argsort(scores, axis=-1, stable=True)
        s = jnp.take_along_axis(scores, order, axis=-1)
        i = jnp.take_along_axis(ids, order, axis=-1)
        dup = jnp.concatenate(
            [jnp.zeros_like(i[:, :1], dtype=bool), (i[:, 1:] == i[:, :-1]) & (i[:, 1:] >= 0)],
            axis=-1)
        s = jnp.where(dup, INF, s)
        scores, ids = s, i
    neg, idx = jax.lax.top_k(-scores, k)
    out_ids = jnp.take_along_axis(ids, idx, axis=-1)
    out_scores = -neg
    out_ids = jnp.where(jnp.isinf(out_scores), -1, out_ids)
    return out_ids.astype(jnp.int32), out_scores


def merge_topk(ids_a, scores_a, ids_b, scores_b, k: int):
    """Merge two padded top-k sets (used by the distributed all-gather merge)."""
    ids = jnp.concatenate([ids_a, ids_b], axis=-1)
    scores = jnp.concatenate([scores_a, scores_b], axis=-1)
    return topk_ids(scores, ids, k, dedup=True)
