"""IVF coarse quantizer: k-means build (numpy, offline) + padded list layout.

Lists are stored as a dense padded `[nlist, max_list]` int32 matrix (−1
padding) — the gather-friendly TPU layout (no pointer chasing; a probe is a
contiguous row gather followed by an MXU distance block).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class IVFIndex:
    centroids: np.ndarray       # [nlist, d] float32
    centroid_norms: np.ndarray  # [nlist] float32
    lists: np.ndarray           # [nlist, max_list] int32, −1 pad
    list_len: np.ndarray        # [nlist] int32


def kmeans(x: np.ndarray, k: int, iters: int = 8, seed: int = 0,
           sample: int = 20000) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    if n > sample:
        x_fit = x[rng.choice(n, sample, replace=False)]
    else:
        x_fit = x
    k = min(k, x_fit.shape[0])
    cent = x_fit[rng.choice(x_fit.shape[0], k, replace=False)].copy()
    for _ in range(iters):
        d = (cent ** 2).sum(1)[None, :] - 2.0 * x_fit @ cent.T
        assign = d.argmin(1)
        for j in range(k):
            m = assign == j
            if m.any():
                cent[j] = x_fit[m].mean(0)
    return cent.astype(np.float32)


def assign_to_centroids(x: np.ndarray, cent: np.ndarray, block: int = 8192) -> np.ndarray:
    out = np.empty(x.shape[0], dtype=np.int64)
    cn = (cent ** 2).sum(1)
    for s in range(0, x.shape[0], block):
        xb = x[s:s + block]
        d = cn[None, :] - 2.0 * xb @ cent.T
        out[s:s + block] = d.argmin(1)
    return out


def pack_lists(assign: np.ndarray, nlist: int,
               max_list_cap: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Cluster assignments -> (`[nlist, max_list]` padded lists, fill counts).

    Each list fills in ascending row-id order and overflowing lists drop
    their highest row ids — the vectorised form of the original
    one-row-at-a-time fill loop, shared by `build_ivf` and `graft_ivf`
    so both produce the same layout by construction.
    """
    n = assign.shape[0]
    lens = np.bincount(assign, minlength=nlist)
    max_list = int(lens.max()) if lens.size else 1
    if max_list_cap is not None:
        max_list = min(max_list, max_list_cap)
    lists = np.full((nlist, max_list), -1, dtype=np.int32)
    order = np.argsort(assign, kind="stable")
    starts = np.zeros(nlist + 1, dtype=np.int64)
    np.cumsum(lens, out=starts[1:])
    pos = np.arange(n, dtype=np.int64) - starts[assign[order]]
    ok = pos < max_list
    lists[assign[order][ok], pos[ok]] = order[ok].astype(np.int32)
    return lists, np.minimum(lens, max_list).astype(np.int32)


def build_ivf(vectors: np.ndarray, nlist: int, *, seed: int = 0,
              max_list_cap: int | None = None) -> IVFIndex:
    cent = kmeans(vectors, nlist, seed=seed)
    nlist = cent.shape[0]
    assign = assign_to_centroids(vectors, cent)
    lists, fill = pack_lists(assign, nlist, max_list_cap)
    return IVFIndex(centroids=cent,
                    centroid_norms=(cent ** 2).sum(1).astype(np.float32),
                    lists=lists, list_len=fill)


def graft_ivf(old: IVFIndex, new_vectors: np.ndarray, old_to_new: np.ndarray,
              *, max_list_cap: int | None = None) -> IVFIndex:
    """Splice a compacted dataset into an existing IVF without re-running
    k-means.

    Centroids stay frozen; surviving rows keep their old cluster (their
    vector didn't change, so re-running `assign_to_centroids` would give
    the same argmin), carried through the id remap `old_to_new`
    (old row -> new row, −1 = deleted). Only rows with no carried
    assignment — compacted delta rows plus any old rows a capped layout
    had dropped — are assigned fresh. Bit-identical to re-assigning and
    re-packing every row of `new_vectors` against the frozen centroids,
    at O(|new rows| · nlist) instead of O(n · nlist) distance work.
    """
    nlist = old.centroids.shape[0]
    n_new = new_vectors.shape[0]
    assign = np.full(n_new, -1, dtype=np.int64)
    rows_c, _ = np.nonzero(old.lists >= 0)
    mapped = old_to_new[old.lists[old.lists >= 0].astype(np.int64)]
    keep = mapped >= 0
    assign[mapped[keep]] = rows_c[keep]
    un = np.nonzero(assign < 0)[0]
    if un.size:
        assign[un] = assign_to_centroids(new_vectors[un], old.centroids)
    lists, fill = pack_lists(assign, nlist, max_list_cap)
    return IVFIndex(centroids=old.centroids, centroid_norms=old.centroid_norms,
                    lists=lists, list_len=fill)
