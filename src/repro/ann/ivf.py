"""IVF coarse quantizer: k-means build (numpy, offline) + padded list layout.

Lists are stored as a dense padded `[nlist, max_list]` int32 matrix (−1
padding) — the gather-friendly TPU layout (no pointer chasing; a probe is a
contiguous row gather followed by an MXU distance block).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class IVFIndex:
    centroids: np.ndarray       # [nlist, d] float32
    centroid_norms: np.ndarray  # [nlist] float32
    lists: np.ndarray           # [nlist, max_list] int32, −1 pad
    list_len: np.ndarray        # [nlist] int32


def kmeans(x: np.ndarray, k: int, iters: int = 8, seed: int = 0,
           sample: int = 20000) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    if n > sample:
        x_fit = x[rng.choice(n, sample, replace=False)]
    else:
        x_fit = x
    k = min(k, x_fit.shape[0])
    cent = x_fit[rng.choice(x_fit.shape[0], k, replace=False)].copy()
    for _ in range(iters):
        d = (cent ** 2).sum(1)[None, :] - 2.0 * x_fit @ cent.T
        assign = d.argmin(1)
        for j in range(k):
            m = assign == j
            if m.any():
                cent[j] = x_fit[m].mean(0)
    return cent.astype(np.float32)


def assign_to_centroids(x: np.ndarray, cent: np.ndarray, block: int = 8192) -> np.ndarray:
    out = np.empty(x.shape[0], dtype=np.int64)
    cn = (cent ** 2).sum(1)
    for s in range(0, x.shape[0], block):
        xb = x[s:s + block]
        d = cn[None, :] - 2.0 * xb @ cent.T
        out[s:s + block] = d.argmin(1)
    return out


def build_ivf(vectors: np.ndarray, nlist: int, *, seed: int = 0,
              max_list_cap: int | None = None) -> IVFIndex:
    cent = kmeans(vectors, nlist, seed=seed)
    nlist = cent.shape[0]
    assign = assign_to_centroids(vectors, cent)
    lens = np.bincount(assign, minlength=nlist)
    max_list = int(lens.max()) if lens.size else 1
    if max_list_cap is not None:
        max_list = min(max_list, max_list_cap)
    lists = np.full((nlist, max_list), -1, dtype=np.int32)
    fill = np.zeros(nlist, dtype=np.int64)
    for i, a in enumerate(assign):
        f = fill[a]
        if f < max_list:
            lists[a, f] = i
            fill[a] = f + 1
    return IVFIndex(centroids=cent,
                    centroid_norms=(cent ** 2).sum(1).astype(np.float32),
                    lists=lists, list_len=fill.astype(np.int32))
