"""Predicate evaluation over packed label bitmaps.

Three predicate types (paper §2.1):
  * Equality   : L_i == L_q
  * AND        : L_q ⊆ L_i   (containment)
  * OR         : L_q ∩ L_i ≠ ∅ (overlap)
"""

from __future__ import annotations

import enum

import jax.numpy as jnp


class Predicate(enum.IntEnum):
    EQUALITY = 0
    AND = 1
    OR = 2

    @classmethod
    def parse(cls, s: "str | Predicate") -> "Predicate":
        if isinstance(s, Predicate):
            return s
        return {
            "equality": cls.EQUALITY, "eq": cls.EQUALITY,
            "and": cls.AND, "containment": cls.AND,
            "or": cls.OR, "overlap": cls.OR,
        }[str(s).lower()]


PREDICATES = (Predicate.EQUALITY, Predicate.AND, Predicate.OR)


def eval_predicate(base_bm, query_bm, pred: Predicate):
    """Evaluate `pred` between every base bitmap and the query bitmap(s).

    base_bm : uint32 [..., W]
    query_bm: uint32 broadcastable to base_bm (e.g. [W] or [Q, 1, W])
    returns : bool   [...] (word axis reduced)
    """
    pred = Predicate(pred)
    if pred == Predicate.EQUALITY:
        return jnp.all(base_bm == query_bm, axis=-1)
    if pred == Predicate.AND:
        return jnp.all((base_bm & query_bm) == query_bm, axis=-1)
    if pred == Predicate.OR:
        return jnp.any((base_bm & query_bm) != 0, axis=-1)
    raise ValueError(pred)


def eval_predicate_np(base_bm, query_bm, pred: Predicate):
    """Host (numpy) twin of `eval_predicate` for offline index builds."""
    import numpy as np

    pred = Predicate(pred)
    if pred == Predicate.EQUALITY:
        return np.all(base_bm == query_bm, axis=-1)
    if pred == Predicate.AND:
        return np.all((base_bm & query_bm) == query_bm, axis=-1)
    if pred == Predicate.OR:
        return np.any((base_bm & query_bm) != 0, axis=-1)
    raise ValueError(pred)
