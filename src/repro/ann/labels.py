"""Packed label bitmaps.

The paper uses Roaring bitmaps on CPU for selectivity / predicate checks.
The TPU-native equivalent is a dense packed-uint32 bitmap tensor: one row of
``ceil(|U|/32)`` words per vector, evaluated word-parallel on the VPU with
``bitwise_and/or`` + ``population_count``.
"""

from __future__ import annotations

from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def n_words(universe: int) -> int:
    """Number of uint32 words needed for a universe of `universe` labels."""
    return max(1, (int(universe) + 31) // 32)


def pack_one(labels: Iterable[int], universe: int) -> np.ndarray:
    """Pack one label set into a `[W]` uint32 bitmap."""
    words = np.zeros(n_words(universe), dtype=np.uint32)
    for l in labels:
        if not 0 <= l < universe:
            raise ValueError(f"label {l} outside universe [0,{universe})")
        words[l >> 5] |= np.uint32(1) << np.uint32(l & 31)
    return words


def pack_label_sets(label_sets: Sequence[Iterable[int]], universe: int) -> np.ndarray:
    """Pack `N` label sets into a `[N, W]` uint32 bitmap matrix."""
    out = np.zeros((len(label_sets), n_words(universe)), dtype=np.uint32)
    for i, ls in enumerate(label_sets):
        for l in ls:
            out[i, l >> 5] |= np.uint32(1) << np.uint32(l & 31)
    return out


def unpack_one(bitmap: np.ndarray) -> frozenset[int]:
    """Inverse of `pack_one` (host-side utility)."""
    labels = []
    for w, word in enumerate(np.asarray(bitmap, dtype=np.uint32)):
        word = int(word)
        b = 0
        while word:
            if word & 1:
                labels.append((w << 5) + b)
            word >>= 1
            b += 1
    return frozenset(labels)


def bitmap_key(bitmap: np.ndarray) -> bytes:
    """Hashable host-side key for a bitmap (used by group / pattern lookup
    tables, mirroring the paper's precomputed set-count hash table)."""
    return np.ascontiguousarray(bitmap, dtype=np.uint32).tobytes()


def popcount(bitmaps: jax.Array) -> jax.Array:
    """Total set-bit count along the last (word) axis."""
    return jnp.sum(jax.lax.population_count(bitmaps), axis=-1).astype(jnp.int32)
