"""`ShardedFilteredIndex` — one dataset row-partitioned across devices.

This is the execution layer that scales the serving API past one device:
the dataset is split into contiguous row shards (`ANNDataset.row_slice`),
each shard is an ordinary owned `FilteredIndex` pinned to its own device
(round-robin over the host's jax devices — `distributed.shard_devices`),
and a batched search runs every shard in parallel before a cross-shard
top-k merge (`ops.merge_topk`, the VMEM-accumulated Pallas reduction).

The handle exposes the same `run_method`/`search`/`close` surface as
`FilteredIndex`, so `RouterService` (and its `ShardedRouterService`
subclass) dispatches through it unchanged: a batch is routed **once** —
one fused MLP forward over full-dataset features — and only the chosen
(method, ps) execution fans out per shard. Shard-local ids are globalised
with each shard's row offset (row slices preserve row order), which is
what lets the merge kernel treat per-shard candidates as disjoint.

Relation to `repro.ann.distributed`: `make_sharded_search` is the
single-jit shard_map formulation of the same row partition for the exact
brute-force scan inside one mesh; `ShardedFilteredIndex` is the
host-orchestrated generalisation that serves *every* registered method
(each shard runs its own built index) and composes with the async
micro-batch queue in `repro.ann.service`.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.ann import trace
from repro.ann.dataset import ANNDataset
from repro.ann.distributed import shard_bounds, shard_devices
from repro.ann.engine import (ParamSetting, pop_stage_timings,
                              resolve_setting, stage_add)
from repro.ann.index import (FilteredIndex, QueryBatch, SearchResult,
                             exact_distances)


def stack_candidates(parts) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-segment (ids, raw) pairs into [S, Q, K] arrays.

    Segments may disagree on their candidate width K (the live delta
    path overfetches by its tombstone count); narrower segments are
    padded with −1 ids / +inf scores, which `ops.merge_topk` treats as
    invalid slots. Ids must already be global (disjoint across parts).
    """
    kmax = max(i.shape[1] for i, _ in parts)
    ids, raws = [], []
    for i, r in parts:
        i = np.asarray(i, dtype=np.int32)
        r = np.asarray(r, dtype=np.float32)
        pad = kmax - i.shape[1]
        if pad:
            i = np.concatenate(
                [i, np.full((i.shape[0], pad), -1, np.int32)], axis=1)
            r = np.concatenate(
                [r, np.full((r.shape[0], pad), np.inf, np.float32)], axis=1)
        ids.append(i)
        raws.append(r)
    return np.stack(ids), np.stack(raws)


def merge_candidates(ids: np.ndarray, raw: np.ndarray,
                     k: int) -> tuple[np.ndarray, np.ndarray]:
    """Reduce [S, Q, K] globalised candidates to the global top-k through
    the `ops.merge_topk` kernel. Returns ([Q, k] i32 ids with −1 pad,
    [Q, k] f32 scores with +inf at −1)."""
    import jax.numpy as jnp

    from repro.kernels import ops

    gids, graw = ops.merge_topk(jnp.asarray(ids), jnp.asarray(raw), k=k)
    return np.asarray(gids), np.asarray(graw)


class ShardedFilteredIndex:
    """Row-sharded serving handle: one `FilteredIndex` per shard plus the
    cross-shard merge. API-compatible with `FilteredIndex` wherever the
    serving layer touches it (`ds`, `run_method`, `search`, lifecycle).

    Args:
        ds: the full dataset. Row-partitioned; the parent is kept for
            routing features and exact distances (host arrays are shared
            views — no vector copy).
        n_shards: number of row shards (ignored when `bounds` is given).
        bounds: optional explicit shard boundaries [S+1] (ragged shards);
            defaults to `distributed.shard_bounds(ds.n, n_shards)`.
        devices: optional list of jax devices, one per shard; defaults to
            round-robin over the host's devices (all shards land on the
            single device of a CPU host — still correct, just serial).
        registry: optional `MethodRegistry` forwarded to every shard.
        parallel: fan shard execution out over a thread pool (jax
            releases the GIL during device compute, so per-device shards
            overlap). Serial when False or with a single shard.

    Raises:
        ValueError: if bounds are not a strictly increasing cover of
            [0, ds.n], or n_shards is out of range.
    """

    def __init__(self, ds: ANNDataset, n_shards: int = 1, *,
                 bounds=None, devices=None, registry=None,
                 parallel: bool = True):
        if bounds is None:
            bounds = shard_bounds(ds.n, n_shards)
        bounds = np.asarray(bounds, dtype=np.int64)
        if bounds.ndim != 1 or bounds.size < 2 or bounds[0] != 0 \
                or bounds[-1] != ds.n or np.any(np.diff(bounds) <= 0):
            raise ValueError(
                f"shard bounds must strictly increase from 0 to n={ds.n}; "
                f"got {bounds.tolist()}")
        self.ds = ds
        self.bounds = bounds
        if devices is None:
            devices = shard_devices(bounds.size - 1)
        self.shards = [
            FilteredIndex(ds.row_slice(int(s), int(e),
                                       name=f"{ds.name}/shard{i}"),
                          registry=registry, device=devices[i])
            for i, (s, e) in enumerate(zip(bounds[:-1], bounds[1:]))]
        self._registry = registry
        self._parallel = bool(parallel) and len(self.shards) > 1
        self._pool = (ThreadPoolExecutor(
            max_workers=len(self.shards),
            thread_name_prefix=f"shard-{ds.name}") if self._parallel
            else None)
        self._feature_fx: FilteredIndex | None = None
        self._features = None        # routing-feature cache (full dataset)
        self._closed = False

    # ---- lifecycle ------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Close every shard handle (and the feature handle, if built) and
        shut the dispatch pool down. Idempotent."""
        for fx in self.shards:
            fx.close()
        if self._feature_fx is not None:
            self._feature_fx.close()
            self._feature_fx = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        self._features = None
        self._closed = True

    def __enter__(self) -> "ShardedFilteredIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"ShardedFilteredIndex({self.ds.name!r}) is closed")

    # ---- routing-feature surface (parent dataset, shard-0 device) -------
    @property
    def feature_index(self) -> FilteredIndex:
        """Owned `FilteredIndex` over the *full* dataset on shard-0's
        device — backs the TPU feature kernels (batched selectivity needs
        the whole bitmap tensor; per-shard bitmaps would under-count).
        Built lazily: CPU feature paths never touch it."""
        self._check_open()
        if self._feature_fx is None:
            self._feature_fx = FilteredIndex(
                self.ds, registry=self._registry,
                device=self.shards[0]._placement)
        return self._feature_fx

    @property
    def device(self):
        """Full-dataset device tensors (routing-feature path only; shard
        execution uses each shard's own tensors)."""
        return self.feature_index.device

    # ---- search ----------------------------------------------------------
    def _map_shards(self, fn):
        if self._pool is not None:
            return list(self._pool.map(fn, self.shards))
        return [fn(fx) for fx in self.shards]

    def run_method(self, method, setting: ParamSetting,
                   batch: QueryBatch) -> tuple[np.ndarray, np.ndarray]:
        """Raw sharded execution of one (method, setting) over the batch.

        Every shard runs `FilteredIndex.run_method` on its own tensors
        (in parallel across devices), shard-local ids are globalised with
        the shard row offsets, and the [S, Q, k] candidates reduce to the
        global top-k through `ops.merge_topk`.

        Returns: ([Q, k] int32 global ids with −1 pad, [Q, k] float32
        ranking scores ‖v‖² − 2·q·v with +inf at −1) — identical contract
        to `FilteredIndex.run_method`, so the serving layer can't tell
        the difference.

        Per-shard wall seconds accumulate on the calling thread's stage
        slate (`shard{j}_s`, plus `shard_max_s` — the straggler that
        bounds fan-out latency, which a sum across shards would hide —
        and `merge_s`), drained by `pop_stage_timings()`.  Under an
        active trace each shard's run is a `shard` child span attached
        across the pool's threads.
        Raises: RuntimeError if closed; ValueError on shape mismatch.
        """
        self._check_open()
        parent = trace.current()
        times = [0.0] * len(self.shards)

        def shard_run(jfx):
            j, fx = jfx
            s0 = time.perf_counter()
            with trace.attach(parent):
                with trace.span("shard", shard=j):
                    out = fx.run_method(method, setting, batch)
            times[j] = time.perf_counter() - s0
            return out

        if self._pool is not None:
            per = list(self._pool.map(shard_run, enumerate(self.shards)))
        else:
            per = [shard_run(jfx) for jfx in enumerate(self.shards)]
        offs = self.bounds[:-1]
        parts = [(np.where(np.asarray(i) >= 0,
                           np.asarray(i) + np.int32(off), -1), r)
                 for (i, r), off in zip(per, offs)]
        t_merge = time.perf_counter()
        with trace.span("merge", shards=len(per)):
            ids, raw = stack_candidates(parts)
            out = merge_candidates(ids, raw, batch.k)
        for j, s in enumerate(times):
            stage_add(f"shard{j}_s", s)
        stage_add("shard_max_s", max(times))
        stage_add("merge_s", time.perf_counter() - t_merge)
        return out

    def pop_stage_timings(self) -> dict[str, float]:
        """Drain the calling thread's per-stage timings (`shard{j}_s`
        fan-out seconds, `shard_max_s` straggler, `merge_s`)."""
        return pop_stage_timings()

    def search(self, batch: QueryBatch, method,
               setting: ParamSetting | str | None = None) -> SearchResult:
        """Direct single-method sharded search (no routing).

        Args/semantics match `FilteredIndex.search`; `search_s` covers
        the whole fan-out + cross-shard merge.
        """
        self._check_open()
        if not isinstance(setting, ParamSetting):
            from repro.ann import registry as registry_mod

            m = (method if not isinstance(method, str)
                 else (self._registry
                       or registry_mod.default_registry()).get(method))
            setting = resolve_setting(m, setting)
            method = m
        t0 = time.perf_counter()
        ids, raw = self.run_method(method, setting, batch)
        dt = time.perf_counter() - t0
        return SearchResult(
            ids=ids, distances=exact_distances(raw, ids, batch.vectors),
            decisions=None, timings={"search_s": dt, "total_s": dt},
            keys=self.keys_of(ids))

    @property
    def generation(self) -> int:
        """Sealed sharded indexes never remap rows — generation is a
        constant 0, mirroring `FilteredIndex` so telemetry events carry
        a uniform generation field across handle types."""
        return 0

    # ---- stable external keys -------------------------------------------
    def keys_of(self, ids) -> np.ndarray:
        """Stable external keys for global result ids: identity on a
        sealed sharded index (rows never remap), −1 stays −1 — same
        surface as the live handles."""
        ids = np.asarray(ids, dtype=np.int64)
        return np.where(ids >= 0, ids, np.int64(-1))

    def label_clock(self, labels=None) -> int:
        """Sealed data never changes — constant 0, mirroring the live
        handles' per-label write clock (see `repro.ann.cache`)."""
        return 0

    # ---- maintenance -----------------------------------------------------
    def evict(self, method_name: str | None = None) -> int:
        """Drop built indexes on every shard; returns total evictions."""
        return sum(fx.evict(method_name) for fx in self.shards)

    def stats(self) -> dict:
        """Aggregate + per-shard state snapshot."""
        return {
            "dataset": self.ds.name,
            "n": self.ds.n,
            "n_shards": self.n_shards,
            "shard_rows": np.diff(self.bounds).tolist(),
            "parallel": self._pool is not None,
            "features_cached": self._features is not None,
            "closed": self._closed,
            "shards": [fx.stats() for fx in self.shards],
        }
