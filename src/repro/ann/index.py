"""`FilteredIndex` — the owned serving handle over one dataset.

This replaces the three module-global caches that used to live in
`repro.ann.engine` (`_DEVICE_CACHE`, `_ARRAY_CACHE`, `_INDEX_CACHE`) with
state owned by an explicit handle:

* device tensors (vectors / norms / bitmaps / group tables) are built
  lazily on first use and freed by `close()`;
* per-(method, build-params) indexes are built on demand and individually
  evictable (`evict`);
* the host-array upload cache (`as_device`) is per-handle, so two
  indexes over different datasets can never serve each other's tensors.

Alongside it live the typed request/result objects the serving surface
speaks: `QueryBatch` (vectors + bitmaps + predicate + k, validated on
construction) and `SearchResult` (ids, exact distances, per-query routing
decisions, stage timings). `repro.ann.service.RouterService` binds an
`MLRouter` to a `FilteredIndex` and routes between methods; a bare
`FilteredIndex.search` runs one named method directly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import numpy as np

from repro.ann import registry as registry_mod
from repro.ann.dataset import ANNDataset
from repro.ann.engine import (DeviceData, Method, ParamSetting,
                              resolve_setting)
from repro.ann.predicates import Predicate


class RoutingDecision(NamedTuple):
    """Per-query routing outcome. Tuple-compatible: compares and unpacks
    exactly like the legacy `(method, ps_id)` pairs."""
    method: str
    ps_id: str | None


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """A validated batch of filtered queries of one predicate type.

    Args:
        vectors: [Q, d] query embeddings (coerced to float32).
        bitmaps: [Q, W] packed query label sets (coerced to uint32).
        pred: the batch's `Predicate` (or its int value).
        k: result width per query (>= 1).
    Raises:
        ValueError: on construction, for non-2-D vectors/bitmaps, a Q
            mismatch between them, an empty batch, or k < 1.
    """
    vectors: np.ndarray       # [Q, d] float32
    bitmaps: np.ndarray       # [Q, W] uint32 packed label sets
    pred: Predicate
    k: int = 10

    def __post_init__(self):
        vectors = np.asarray(self.vectors, dtype=np.float32)
        bitmaps = np.asarray(self.bitmaps, dtype=np.uint32)
        if vectors.ndim != 2:
            raise ValueError(
                f"QueryBatch.vectors must be [Q, d]; got shape "
                f"{vectors.shape}")
        if bitmaps.ndim != 2:
            raise ValueError(
                f"QueryBatch.bitmaps must be [Q, W]; got shape "
                f"{bitmaps.shape}")
        if vectors.shape[0] != bitmaps.shape[0]:
            raise ValueError(
                f"QueryBatch vectors/bitmaps disagree on Q: "
                f"{vectors.shape[0]} vs {bitmaps.shape[0]}")
        if vectors.shape[0] == 0:
            raise ValueError("QueryBatch must contain at least one query")
        if int(self.k) < 1:
            raise ValueError(f"QueryBatch.k must be >= 1; got {self.k}")
        object.__setattr__(self, "vectors", vectors)
        object.__setattr__(self, "bitmaps", bitmaps)
        object.__setattr__(self, "pred", Predicate(self.pred))
        object.__setattr__(self, "k", int(self.k))

    @property
    def q(self) -> int:
        return int(self.vectors.shape[0])

    @property
    def dim(self) -> int:
        return int(self.vectors.shape[1])

    def take(self, idxs) -> "QueryBatch":
        """Sub-batch at the given query indices (for group dispatch)."""
        idxs = np.asarray(idxs)
        return QueryBatch(self.vectors[idxs], self.bitmaps[idxs],
                          self.pred, self.k)

    @staticmethod
    def from_queryset(qs, k: int | None = None) -> "QueryBatch":
        """Adapt a `repro.ann.dataset.QuerySet`."""
        return QueryBatch(qs.vectors, qs.bitmaps, qs.pred,
                          qs.k if k is None else k)


@dataclasses.dataclass
class SearchResult:
    """Typed result batch.

    * `ids` — [Q, k] int32 base ids, −1 padded;
    * `distances` — [Q, k] float32 **exact squared-L2** distances for the
      returned ids (NaN where the id is −1), so callers never recompute
      them from raw vectors;
    * `decisions` — per-query `RoutingDecision` (None for direct
      single-method searches);
    * `timings` — stage wall-clock seconds (`route_s`, `search_s`,
      `total_s`; live indexes additionally report `base_s`, `delta_s`
      and `merge_s` for the base scan / delta scan / candidate fold);
    * `keys` — [Q, k] int64 **stable external keys** for the returned
      rows (−1 pad). Unlike `ids` — which are per-generation row ids a
      live index remaps at every compaction — keys survive
      `compact()` and a `repro.ann.store` save/reopen, so clients
      should hold on to these. For sealed indexes keys equal the row
      ids.
    * `cache` — per-query serving provenance when a
      `repro.ann.cache.SemanticResultCache` fronted the request: a
      [Q] list of ``"exact"`` / ``"semantic"`` / ``None`` (None for a
      query that missed and was searched). None (default) means no
      cache was involved.
    """
    ids: np.ndarray
    distances: np.ndarray
    decisions: list[RoutingDecision] | None = None
    timings: dict = dataclasses.field(default_factory=dict)
    keys: np.ndarray | None = None
    cache: list | None = None

    @property
    def q(self) -> int:
        return int(self.ids.shape[0])

    @property
    def k(self) -> int:
        return int(self.ids.shape[1])


def exact_distances(raw_scores: np.ndarray, ids: np.ndarray,
                    qvecs: np.ndarray) -> np.ndarray:
    """Ranking scores (‖v‖² − 2·q·v) -> exact squared-L2, NaN at −1 pad."""
    qn = np.sum(np.asarray(qvecs, dtype=np.float32) ** 2, axis=1)
    d = np.asarray(raw_scores, dtype=np.float32) + qn[:, None]
    d = np.maximum(d, 0.0)          # float round-off can dip below zero
    return np.where(ids >= 0, d, np.float32(np.nan)).astype(np.float32)


class FilteredIndex:
    """Owned per-dataset serving handle.

    Owns every piece of per-dataset serving state and ties it to one
    lifecycle: device-resident tensors (`device`), the host→device upload
    cache (`as_device`), per-(method, build-params) offline indexes
    (`get_index`), and the per-dataset routing features
    (`repro.core.features.dataset_features` caches onto the handle).
    `close()` — or exiting the context manager — frees all of it.

    Args:
        ds: the dataset this handle serves.
        registry: optional `MethodRegistry` overriding the default when
            method names are resolved (`search("prefilter")` etc.).
        device: optional `jax.Device` to pin this handle's tensors to —
            the placement hook `ShardedFilteredIndex` uses to spread
            shards across a multi-device host. Default: jax's default
            device.
    """

    def __init__(self, ds: ANNDataset, *, registry=None, device=None):
        self.ds = ds
        self._registry = registry
        self._placement = device
        self._device: DeviceData | None = None
        self._indexes: dict = {}     # (method_name, build_tuple) -> index
        self._arrays: dict = {}      # id(host_array) -> (host, device)
        self._features = None        # repro.core.features.DatasetFeatures
        self._closed = False

    # ---- lifecycle ------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Drop every owned device tensor, upload, built index, and cached
        feature state. Subsequent use raises RuntimeError; closing twice
        is a no-op."""
        self._device = None
        self._indexes.clear()
        self._arrays.clear()
        self._features = None
        self._closed = True

    def __enter__(self) -> "FilteredIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"FilteredIndex({self.ds.name!r}) is closed")

    # ---- owned device state ---------------------------------------------
    @property
    def device(self) -> DeviceData:
        """Device-resident dataset tensors (built lazily, owned; placed on
        this handle's pinned device when one was given).

        Raises RuntimeError if the handle is closed."""
        self._check_open()
        if self._device is None:
            with self._device_scope():
                self._device = _build_device_data(self.ds)
        return self._device

    def _device_scope(self):
        """Context placing uploads on the pinned device (no-op if unset)."""
        import contextlib

        import jax

        if self._placement is None:
            return contextlib.nullcontext()
        return jax.default_device(self._placement)

    def as_device(self, x):
        """Cached np→device upload, owned by this handle.

        Args:
            x: a host array. Keyed by identity — re-uploading the same
               array object is free; a new object with equal contents
               uploads again.
        Returns: the device-resident `jax.Array`.
        Raises: RuntimeError if the handle is closed.
        """
        import jax.numpy as jnp

        self._check_open()
        key = id(x)
        hit = self._arrays.get(key)
        if hit is None or hit[0] is not x:
            with self._device_scope():
                hit = (x, jnp.asarray(x))
            self._arrays[key] = hit
        return hit[1]

    # ---- built indexes ---------------------------------------------------
    def _resolve_method(self, method) -> Method:
        if isinstance(method, str):
            reg = self._registry or registry_mod.default_registry()
            return reg.get(method)
        return method

    def get_index(self, method, build_params: tuple | dict | None = None):
        """Built (offline) index for (method, build-params), cached.

        Args:
            method: a `Method` instance or registered method name.
            build_params: the method's build-parameter dict (or its
                sorted-items tuple); None means no build parameters.
        Returns: the method's opaque built-index object.
        Raises: RuntimeError if closed; KeyError for an unknown name.
        """
        self._check_open()
        method = self._resolve_method(method)
        if build_params is None:
            build_params = ()
        if isinstance(build_params, dict):
            build_params = tuple(sorted(build_params.items()))
        key = (method.name, build_params)
        if key not in self._indexes:
            self._indexes[key] = method.build(self.ds, dict(build_params))
        return self._indexes[key]

    def adopt_index(self, method, build_params, index) -> None:
        """Install an already-built index under (method, build-params) —
        the deserialization hook `repro.ann.store` uses to rebuild
        `built_keys()` on load without re-running the offline build.
        Key normalisation matches `get_index`."""
        self._check_open()
        method = self._resolve_method(method)
        if build_params is None:
            build_params = ()
        if isinstance(build_params, dict):
            build_params = tuple(sorted(build_params.items()))
        self._indexes[(method.name, tuple(build_params))] = index

    def built_keys(self) -> list[tuple]:
        """Keys of every built index: (method_name, build_params_tuple).
        `LiveFilteredIndex.compact` replays these against the new base so
        a compaction swap doesn't cold-start the serving methods."""
        return list(self._indexes.keys())

    # ---- stable external keys -------------------------------------------
    @property
    def generation(self) -> int:
        """A sealed index never remaps rows — constant 0, mirroring the
        live handles so telemetry events carry a uniform field."""
        return 0

    def keys_of(self, ids) -> np.ndarray:
        """Stable external keys for result ids (−1 stays −1). A sealed
        `FilteredIndex` never remaps its rows, so keys are the row ids —
        this mirror of `LiveFilteredIndex.keys_of` keeps the serving
        surface uniform across sealed and live handles."""
        ids = np.asarray(ids, dtype=np.int64)
        return np.where(ids >= 0, ids, np.int64(-1))

    def label_clock(self, labels=None) -> int:
        """Sealed data never changes — constant 0, mirroring the live
        handles' per-label write clock so cache invalidation
        (`repro.ann.cache`) reads one uniform surface."""
        return 0

    def evict(self, method_name: str | None = None) -> int:
        """Drop built indexes (all of one method, or every method).
        Returns the number of evicted entries."""
        keys = [k for k in self._indexes
                if method_name is None or k[0] == method_name]
        for k in keys:
            del self._indexes[k]
        return len(keys)

    def stats(self) -> dict:
        """Snapshot of the handle's owned state (for logging/debugging)."""
        return {
            "dataset": self.ds.name,
            "n": self.ds.n,
            "device_resident": self._device is not None,
            "built_indexes": sorted(k[0] for k in self._indexes),
            "cached_uploads": len(self._arrays),
            "features_cached": self._features is not None,
            "closed": self._closed,
        }

    # ---- search ----------------------------------------------------------
    def run_method(self, method, setting: ParamSetting,
                   batch: QueryBatch) -> tuple[np.ndarray, np.ndarray]:
        """Raw single-method execution: ([Q, k] ids, [Q, k] ranking
        scores ‖v‖²−2·q·v). Building blocks for `search` and the bench
        harness; most callers want `search`/`RouterService` instead."""
        if batch.bitmaps.shape[1] != self.ds.bitmaps.shape[1]:
            raise ValueError(
                f"QueryBatch bitmap width {batch.bitmaps.shape[1]} does "
                f"not match dataset width {self.ds.bitmaps.shape[1]}")
        if batch.dim != self.ds.dim:
            raise ValueError(
                f"QueryBatch vector dim {batch.dim} does not match "
                f"dataset dim {self.ds.dim}")
        method = self._resolve_method(method)
        index = self.get_index(method, setting.build)
        return method.search(self, index, batch.vectors, batch.bitmaps,
                             batch.pred, batch.k, setting.search_dict)

    def search(self, batch: QueryBatch, method,
               setting: ParamSetting | str | None = None) -> SearchResult:
        """Direct single-method search (no routing).

        Args:
            batch: the validated query batch.
            method: a `Method` instance or registered method name.
            setting: a `ParamSetting`, a ps_id string, or None (the
                method's max-budget setting).
        Returns: a `SearchResult` with [Q, k] ids + exact squared-L2
            distances (`decisions` is None — no routing happened).
        Raises: RuntimeError if closed; ValueError on dataset/batch
            shape mismatch; KeyError for an unknown method name.
        """
        method = self._resolve_method(method)
        if not isinstance(setting, ParamSetting):
            setting = resolve_setting(method, setting)
        t0 = time.perf_counter()
        ids, raw = self.run_method(method, setting, batch)
        dt = time.perf_counter() - t0
        return SearchResult(
            ids=ids, distances=exact_distances(raw, ids, batch.vectors),
            decisions=None, timings={"search_s": dt, "total_s": dt},
            keys=self.keys_of(ids))


def _build_device_data(ds: ANNDataset) -> DeviceData:
    import jax.numpy as jnp

    g = ds.n_groups
    cent = np.zeros((g, ds.dim), dtype=np.float32)
    for j in range(g):
        s, l = int(ds.group_start[j]), int(ds.group_size[j])
        cent[j] = ds.vectors[s:s + l].mean(0)
    return DeviceData(
        vectors=jnp.asarray(ds.vectors),
        norms=jnp.asarray(ds.norms_sq),
        bitmaps=jnp.asarray(ds.bitmaps),
        group_bitmaps=jnp.asarray(ds.group_bitmaps),
        group_start=jnp.asarray(ds.group_start),
        group_size=jnp.asarray(ds.group_size),
        group_centroids=jnp.asarray(cent),
        group_cnorms=jnp.asarray((cent ** 2).sum(1).astype(np.float32)),
    )


# ---------------------------------------------------------------------------
# default pool — backs the one-PR-cycle deprecation shims in engine.py and
# callers that pass bare ANNDataset objects. Each entry is an ordinary
# owned FilteredIndex; `clear_pool` closes them all.
# ---------------------------------------------------------------------------

_POOL: dict[tuple, FilteredIndex] = {}


def default_index(ds: ANNDataset) -> FilteredIndex:
    """Process-wide shared handle for `ds` (keyed by content identity)."""
    key = ds.cache_key()
    fx = _POOL.get(key)
    if fx is None or fx.closed:
        fx = FilteredIndex(ds)
        _POOL[key] = fx
    return fx


def as_index(obj) -> FilteredIndex:
    """Coerce an ANNDataset to its pooled handle; pass handles through."""
    return obj if isinstance(obj, FilteredIndex) else default_index(obj)


def clear_pool() -> None:
    """Close and drop every pooled handle."""
    for fx in _POOL.values():
        fx.close()
    _POOL.clear()
