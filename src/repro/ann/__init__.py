"""Filtered-ANN engine: label bitmaps, predicates, datasets, the six
TPU-native filtered-ANN methods, and the owned serving surface
(`FilteredIndex` + `QueryBatch`/`SearchResult` + `RouterService`)."""

from repro.ann.predicates import Predicate
from repro.ann.dataset import ANNDataset
from repro.ann.index import (FilteredIndex, QueryBatch, RoutingDecision,
                             SearchResult)

__all__ = ["Predicate", "ANNDataset", "FilteredIndex", "QueryBatch",
           "RoutingDecision", "SearchResult"]
