"""Filtered-ANN engine: label bitmaps, predicates, datasets, and the six
TPU-native filtered-ANN methods the router selects among."""

from repro.ann.predicates import Predicate
from repro.ann.dataset import ANNDataset

__all__ = ["Predicate", "ANNDataset"]
