"""Filtered-ANN engine: label bitmaps, predicates, datasets, the six
TPU-native filtered-ANN methods, and the owned serving surface
(`FilteredIndex` + `QueryBatch`/`SearchResult` + `RouterService`, scaled
out by `ShardedFilteredIndex`/`ShardedRouterService` and the async
micro-batch queue, made writable by `LiveFilteredIndex`/
`ShardedLiveIndex` — streaming upserts/deletes with delta segments,
tombstones, snapshot epochs, and background compaction — and made
durable by `IndexStore` — segment files, write-ahead log, stable
external keys, crash recovery — see docs/serving.md and
docs/persistence.md; and observable end to end by `Tracer` spans +
the Prometheus `metrics_text` exposition — see docs/observability.md)."""

from repro.ann.predicates import Predicate
from repro.ann.dataset import ANNDataset
from repro.ann.cache import SemanticResultCache
from repro.ann.index import (FilteredIndex, QueryBatch, RoutingDecision,
                             SearchResult)
from repro.ann.live import LiveFilteredIndex, LiveSnapshot, ShardedLiveIndex
from repro.ann.metrics import MetricsServer, metrics_text
from repro.ann.sharded import ShardedFilteredIndex
from repro.ann.store import IndexStore, WriteAheadLog
from repro.ann.trace import Span, Tracer

__all__ = ["Predicate", "ANNDataset", "FilteredIndex", "QueryBatch",
           "RoutingDecision", "SearchResult", "SemanticResultCache",
           "ShardedFilteredIndex", "LiveFilteredIndex", "LiveSnapshot",
           "ShardedLiveIndex", "IndexStore", "WriteAheadLog",
           "Span", "Tracer", "MetricsServer", "metrics_text"]
