"""ANN dataset container: vectors + label sets + group structure.

Vectors are stored **reordered by label-set group** (all vectors sharing an
identical label set are contiguous). This is the layout the UNG-analogue
(`labelnav`) searches directly, and it makes Equality selectivity an O(1)
group lookup — the paper's "precomputed set-count table".
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Sequence

import numpy as np

from repro.ann import labels as lb
from repro.ann.predicates import Predicate, eval_predicate_np

# on-disk segment format (one directory per sealed generation; see
# docs/persistence.md): .npy array files + a segment.json manifest with
# per-file sha1 checksums, readable zero-copy via np.memmap.
# Version 2 adds word-level RLE for the per-row label bitmaps (rows are
# group-sorted, so each bitmap column is ~G runs of ~group_size words —
# the raw N·W·4 bytes compress to ~2·G·W entries); files record their
# "encoding" and v1 segments (all raw) load unchanged.
SEGMENT_FORMAT = "repro.ann-segment"
SEGMENT_VERSION = 2
SEGMENT_META = "segment.json"
_SEGMENT_ARRAYS = ("vectors", "bitmaps", "norms_sq", "group_of",
                   "group_bitmaps", "group_start", "group_size")
# fields eligible for RLE (the [N, W] bitmaps dominate label bytes;
# everything else stays raw + memmapped)
_RLE_FIELDS = ("bitmaps",)
_RLE_ENCODING = "rle-u32-colmajor"


def rle_encode_words(arr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Column-major word-level run-length encoding of a [N, W] uint32
    array. Returns (values u32, counts i64) with
    ``np.repeat(values, counts)`` reproducing ``arr.T.ravel()`` —
    column-major order because rows are group-sorted, so each bitmap
    column is long runs of identical words (one per label group)."""
    flat = np.ascontiguousarray(arr.T).ravel()
    if flat.size == 0:
        return (np.empty(0, np.uint32), np.empty(0, np.int64))
    edge = np.flatnonzero(np.diff(flat)) + 1
    starts = np.concatenate(([0], edge))
    counts = np.diff(np.concatenate((starts, [flat.size])))
    # smallest int dtype that holds the longest run (decode repeats
    # regardless of dtype, so this is pure size win)
    for dt in (np.uint16, np.uint32):
        if counts.max() <= np.iinfo(dt).max:
            counts = counts.astype(dt)
            break
    else:
        counts = counts.astype(np.int64)
    return flat[starts].astype(np.uint32), counts


def rle_decode_words(values: np.ndarray, counts: np.ndarray,
                     shape: tuple[int, int]) -> np.ndarray:
    """Inverse of `rle_encode_words`: exact [N, W] uint32 round-trip."""
    n, w = int(shape[0]), int(shape[1])
    flat = np.repeat(values.astype(np.uint32), counts)
    if flat.size != n * w:
        raise ValueError(
            f"RLE stream decodes to {flat.size} words; shape "
            f"{(n, w)} needs {n * w} (torn or corrupt segment)")
    return np.ascontiguousarray(flat.reshape(w, n).T)


def sha1_file(path: str, block: int = 1 << 22) -> str:
    h = hashlib.sha1()
    with open(path, "rb") as f:
        while True:
            chunk = f.read(block)
            if not chunk:
                return h.hexdigest()
            h.update(chunk)


def fsync_path(path: str) -> None:
    """fsync a file or directory — durability before a manifest commit
    may reference it (a committed manifest must never point at pages
    still in the page cache)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclasses.dataclass
class ANNDataset:
    name: str
    vectors: np.ndarray            # [N, d] float32, group-sorted order
    bitmaps: np.ndarray            # [N, W] uint32, group-sorted order
    universe: int                  # |U|
    group_of: np.ndarray           # [N] int32 group id per vector
    group_bitmaps: np.ndarray      # [G, W] uint32 (one per unique label set)
    group_start: np.ndarray        # [G] int32 start offset in sorted order
    group_size: np.ndarray         # [G] int32
    group_lookup: dict             # bitmap bytes -> group id (host-side hash)
    norms_sq: np.ndarray           # [N] float32 squared L2 norms

    # ---- constructors -------------------------------------------------
    @staticmethod
    def build(name: str, vectors: np.ndarray,
              label_sets: Sequence[Sequence[int]], universe: int) -> "ANNDataset":
        vectors = np.asarray(vectors, dtype=np.float32)
        assert len(label_sets) == vectors.shape[0]
        bitmaps = lb.pack_label_sets(label_sets, universe)
        return ANNDataset.from_packed(name, vectors, bitmaps, universe)

    @staticmethod
    def from_packed(name: str, vectors: np.ndarray, bitmaps: np.ndarray,
                    universe: int, *, return_order: bool = False):
        """Group-sorted construction from already-packed bitmaps.

        Same grouping as `build` (group ids assigned by first appearance
        of a bitmap, rows stably sorted by group), so re-building from
        rows that are already group-sorted reproduces the identical row
        order — the property `LiveFilteredIndex.compact` relies on for
        sealed/live equivalence.

        With `return_order=True` also returns the [N] permutation where
        `order[i]` is the *input* row index of output row `i` (the id
        remap the live-index compaction uses to translate tombstones).
        """
        vectors = np.asarray(vectors, dtype=np.float32)
        bitmaps = np.asarray(bitmaps, dtype=np.uint32)
        n = vectors.shape[0]
        assert bitmaps.shape[0] == n
        lookup: dict[bytes, int] = {}
        gid = np.empty(n, dtype=np.int32)
        for i in range(n):
            k = lb.bitmap_key(bitmaps[i])
            if k not in lookup:
                lookup[k] = len(lookup)
            gid[i] = lookup[k]
        order = np.argsort(gid, kind="stable")
        vectors = vectors[order]
        bitmaps = bitmaps[order]
        gid = gid[order]
        g = len(lookup)
        group_bitmaps = np.zeros((g, bitmaps.shape[1]), dtype=np.uint32)
        # contiguous runs after stable sort
        starts = np.searchsorted(gid, np.arange(g), side="left").astype(np.int32)
        ends = np.searchsorted(gid, np.arange(g), side="right").astype(np.int32)
        for k, j in lookup.items():
            group_bitmaps[j] = np.frombuffer(k, dtype=np.uint32)
        ds = ANNDataset(
            name=name, vectors=vectors, bitmaps=bitmaps, universe=universe,
            group_of=gid, group_bitmaps=group_bitmaps,
            group_start=starts, group_size=(ends - starts).astype(np.int32),
            group_lookup=lookup,
            norms_sq=np.sum(vectors.astype(np.float64) ** 2, axis=1).astype(np.float32),
        )
        return (ds, order) if return_order else ds

    # ---- durable segment files (repro.ann.store) -----------------------
    def save_segment(self, dirpath: str) -> dict:
        """Write this dataset as an immutable on-disk segment.

        One ``.npy`` file per array plus a ``segment.json`` manifest
        carrying shape metadata and per-file sha1 checksums. Segments are
        written once per generation and never mutated; `load_segment`
        maps them back zero-copy. The [N, W] label bitmaps are stored
        word-level run-length encoded (``.rle.npz``) when that is
        smaller than raw — rows are group-sorted, so each column runs
        in group-length blocks; a raw fallback keeps adversarial inputs
        no worse than v1. Returns the manifest dict.
        """
        os.makedirs(dirpath, exist_ok=True)
        files = {}
        for field in _SEGMENT_ARRAYS:
            arr = np.ascontiguousarray(getattr(self, field))
            encoding = "raw"
            if field in _RLE_FIELDS and arr.ndim == 2:
                values, counts = rle_encode_words(arr)
                if values.nbytes + counts.nbytes < arr.nbytes:
                    encoding = _RLE_ENCODING
            if encoding == _RLE_ENCODING:
                fname = f"{field}.rle.npz"
                fpath = os.path.join(dirpath, fname)
                np.savez(fpath, values=values, counts=counts)
            else:
                fname = f"{field}.npy"
                fpath = os.path.join(dirpath, fname)
                np.save(fpath, arr)
            files[field] = {"file": fname, "sha1": sha1_file(fpath),
                            "bytes": os.path.getsize(fpath),
                            "shape": list(arr.shape),
                            "dtype": str(arr.dtype),
                            "encoding": encoding}
        meta = {
            "format": SEGMENT_FORMAT,
            "version": SEGMENT_VERSION,
            "name": self.name,
            "n": self.n,
            "dim": self.dim,
            "universe": self.universe,
            "width": int(self.bitmaps.shape[1]),
            "n_groups": self.n_groups,
            "files": files,
        }
        tmp = os.path.join(dirpath, SEGMENT_META + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(dirpath, SEGMENT_META))
        # a manifest commit may reference this segment immediately: the
        # array bytes and directory entries must be durable first
        for field in _SEGMENT_ARRAYS:
            fsync_path(os.path.join(dirpath, files[field]["file"]))
        fsync_path(dirpath)
        return meta

    @staticmethod
    def load_segment(dirpath: str, *, mmap: bool = True,
                     verify: bool = False) -> "ANNDataset":
        """Open an on-disk segment written by `save_segment`.

        With ``mmap=True`` (default) every array is an ``np.memmap``
        view of the segment file — a cold open touches only the
        manifest, not the vector bytes. ``verify=True`` re-hashes every
        file against the recorded sha1 (full read) and raises
        ValueError on corruption; the default checks file sizes only.
        """
        meta_path = os.path.join(dirpath, SEGMENT_META)
        if not os.path.exists(meta_path):
            raise ValueError(f"{dirpath!r} is not a segment directory "
                             f"(no {SEGMENT_META})")
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("format") != SEGMENT_FORMAT:
            raise ValueError(
                f"{dirpath!r} is not a {SEGMENT_FORMAT} segment "
                f"(format={meta.get('format')!r})")
        if int(meta.get("version", -1)) > SEGMENT_VERSION:
            raise ValueError(
                f"segment version {meta['version']} is newer than "
                f"supported version {SEGMENT_VERSION}")
        arrays = {}
        for field in _SEGMENT_ARRAYS:
            info = meta["files"][field]
            fpath = os.path.join(dirpath, info["file"])
            size = os.path.getsize(fpath)
            if size != info["bytes"]:
                raise ValueError(
                    f"segment file {fpath!r} is {size} bytes; manifest "
                    f"records {info['bytes']} (torn or corrupt segment)")
            if verify and sha1_file(fpath) != info["sha1"]:
                raise ValueError(
                    f"segment file {fpath!r} fails its sha1 checksum")
            encoding = info.get("encoding", "raw")
            if encoding == _RLE_ENCODING:
                # compressed fields decode into memory (they're small);
                # raw fields stay memmapped
                with np.load(fpath) as z:
                    arrays[field] = rle_decode_words(
                        z["values"], z["counts"], info["shape"])
            elif encoding == "raw":
                arrays[field] = np.load(fpath,
                                        mmap_mode="r" if mmap else None)
            else:
                raise ValueError(
                    f"segment file {fpath!r} uses unknown encoding "
                    f"{encoding!r} (newer writer?)")
        lookup = {lb.bitmap_key(np.ascontiguousarray(bm)): j
                  for j, bm in enumerate(arrays["group_bitmaps"])}
        return ANNDataset(name=meta["name"], universe=int(meta["universe"]),
                          group_lookup=lookup, **arrays)

    # ---- basic stats ---------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.vectors.shape[0])

    @property
    def dim(self) -> int:
        return int(self.vectors.shape[1])

    @property
    def n_groups(self) -> int:
        return int(self.group_bitmaps.shape[0])

    def cache_key(self) -> tuple:
        """Stable content-identity key for cross-module caches.

        Metadata alone (name/shape/universe) aliases distinct datasets, and
        id() keys can be recycled after GC — so fold in a fingerprint of
        strided vector/bitmap/group samples. Computed once and memoised on
        the instance.
        """
        key = getattr(self, "_cache_key", None)
        if key is None:
            import hashlib

            h = hashlib.sha1()
            for a in (self.vectors[:: max(1, self.n // 64)],
                      self.bitmaps[:: max(1, self.n // 64)],
                      self.group_size):
                h.update(np.ascontiguousarray(a).tobytes())
            key = (self.name, self.n, self.dim, self.universe,
                   self.n_groups, h.hexdigest())
            object.__setattr__(self, "_cache_key", key)
        return key

    def row_slice(self, start: int, stop: int,
                  name: str | None = None) -> "ANNDataset":
        """Contiguous row partition `[start, stop)` as its own dataset.

        Because rows are stored group-sorted, a contiguous slice is itself
        group-sorted, so the slice preserves row order exactly: local row
        `i` of the shard is global row `start + i` of the parent. This is
        what `ShardedFilteredIndex` relies on to globalise per-shard ids
        with a plain offset. Group tables (bitmaps/start/size/lookup) are
        rebuilt for the groups the slice intersects; a group cut by the
        boundary keeps only its in-slice rows.

        Raises ValueError on an empty/out-of-range slice or if the rows
        are not group-sorted (never the case for `build`/`synthesize`
        outputs).
        """
        start, stop = int(start), int(stop)
        if not (0 <= start < stop <= self.n):
            raise ValueError(
                f"row_slice [{start}, {stop}) out of range for n={self.n}")
        gids = self.group_of[start:stop]
        if np.any(np.diff(gids) < 0):
            raise ValueError("row_slice requires group-sorted row order")
        uniq = np.unique(gids)                     # sorted = slice order
        new_gid = np.searchsorted(uniq, gids).astype(np.int32)
        g = uniq.size
        starts = np.searchsorted(new_gid, np.arange(g),
                                 side="left").astype(np.int32)
        ends = np.searchsorted(new_gid, np.arange(g),
                               side="right").astype(np.int32)
        group_bitmaps = self.group_bitmaps[uniq].copy()
        lookup = {lb.bitmap_key(group_bitmaps[j]): j for j in range(g)}
        return ANNDataset(
            name=name or f"{self.name}[{start}:{stop}]",
            vectors=self.vectors[start:stop], bitmaps=self.bitmaps[start:stop],
            universe=self.universe, group_of=new_gid,
            group_bitmaps=group_bitmaps, group_start=starts,
            group_size=(ends - starts).astype(np.int32), group_lookup=lookup,
            norms_sq=self.norms_sq[start:stop])

    def group_id_of_bitmap(self, query_bm: np.ndarray) -> int:
        """Exact-match group id for a query label set; -1 if absent."""
        return self.group_lookup.get(lb.bitmap_key(query_bm), -1)

    def selectivity(self, query_bm: np.ndarray, pred: Predicate) -> float:
        """Fraction of base vectors satisfying the predicate.

        Evaluated over *groups* (G ≪ N) weighted by group size — the packed
        analogue of the paper's Roaring-bitmap counting.
        """
        pred = Predicate(pred)
        if pred == Predicate.EQUALITY:
            g = self.group_id_of_bitmap(query_bm)
            return 0.0 if g < 0 else float(self.group_size[g]) / self.n
        ok = eval_predicate_np(self.group_bitmaps, query_bm[None, :], pred)
        return float(self.group_size[ok].sum()) / self.n

    def matching_mask(self, query_bm: np.ndarray, pred: Predicate) -> np.ndarray:
        """Boolean [N] mask of predicate-passing vectors (host-side)."""
        ok = eval_predicate_np(self.group_bitmaps, query_bm[None, :], Predicate(pred))
        return ok[self.group_of]


@dataclasses.dataclass
class QuerySet:
    """A batch of filtered queries of a single predicate type."""
    dataset: str
    pred: Predicate
    vectors: np.ndarray        # [Q, d] float32
    bitmaps: np.ndarray        # [Q, W] uint32
    ground_truth: np.ndarray   # [Q, k] int32 ids into dataset order, -1 pad
    k: int

    @property
    def q(self) -> int:
        return int(self.vectors.shape[0])


def ground_truth_topk(ds: ANNDataset, qvecs: np.ndarray, qbms: np.ndarray,
                      pred: Predicate, k: int, block: int = 4096) -> np.ndarray:
    """Brute-force masked exact top-k (the Pre-filter result, recall = 1).

    Returns [Q, k] int32 ids, padded with -1 where fewer than k vectors
    satisfy the predicate.
    """
    qvecs = np.asarray(qvecs, dtype=np.float32)
    nq = qvecs.shape[0]
    out = np.full((nq, k), -1, dtype=np.int32)
    for qi in range(nq):
        mask = ds.matching_mask(qbms[qi], pred)
        idx = np.nonzero(mask)[0]
        if idx.size == 0:
            continue
        cand = ds.vectors[idx]
        d = ds.norms_sq[idx] - 2.0 * cand @ qvecs[qi]
        take = min(k, idx.size)
        part = np.argpartition(d, take - 1)[:take]
        part = part[np.argsort(d[part], kind="stable")]
        out[qi, :take] = idx[part]
    return out


def recall_at_k(result_ids: np.ndarray, gt_ids: np.ndarray) -> np.ndarray:
    """Per-query recall@k per paper Eq. (2): |R ∩ TopK| / min(k, |TopK|)."""
    nq, k = gt_ids.shape
    rec = np.zeros(nq, dtype=np.float64)
    for qi in range(nq):
        gt = set(int(i) for i in gt_ids[qi] if i >= 0)
        if not gt:
            rec[qi] = 1.0  # no valid candidates: vacuous query
            continue
        got = set(int(i) for i in result_ids[qi] if i >= 0)
        rec[qi] = len(got & gt) / min(k, len(gt))
    return rec
