"""Serving telemetry + online router adaptation.

The paper's router consults a *frozen* offline benchmark table; under
production traffic and a live index the measured (recall, QPS) of every
(method, parameter-setting) cell drifts away from it.  This module keeps
routing honest against the *measured* system:

* `TelemetrySink` — a low-overhead per-query event sink.  The serving
  layer (`RouterService.execute`) calls `record_batch` once per executed
  batch; events land in a lock-free ring buffer (slot index from an
  atomic `itertools.count`), per-cell counters fold under one short
  per-batch lock, and a reservoir (algorithm R) keeps an unbiased sample
  of served queries for auditing.  `stats()` exposes counters plus
  latency percentiles computed from the ring.

* `RecallAuditor` — replays reservoir-sampled queries against the
  brute-force oracle (the registered "prefilter" method, i.e.
  `ops.masked_topk`) on a *pinned snapshot*, so audits never race
  compaction, and compares stable external keys so results survive row
  remaps.  Exact per-(method, ps) recall folds into the online table.

* `OnlineBenchmarkTable` — a `BenchmarkTable` whose cells are
  EWMA-updated from audited recall and measured QPS.  Routing reads
  (`routing_arrays`) are served from a per-version cache and republished
  atomically under a version counter; `drift()` scores each audited
  cell's divergence from the offline table.

* `OnlineRouterAdapter` — the adaptation loop.  Attaches the online
  table to a live `RouterService` (cell updates re-route immediately —
  Algorithm 2's passing set is table-driven), and when drift crosses a
  threshold retrains the MLP router off the serving path on
  audit-derived labels, shadow-evaluates the candidate against the
  incumbent on held-out audited queries, and promotes only on
  improvement through the versioned-artifact / `link_router` /
  content-sha machinery (rollback = keep serving the old artifact).
"""

from __future__ import annotations

import itertools
import os
import threading
import time
from typing import NamedTuple

import numpy as np

from repro.ann.index import QueryBatch
from repro.ann.predicates import Predicate

__all__ = [
    "QueryEvent", "AuditSample", "TelemetrySink", "RecallAuditor",
    "OnlineBenchmarkTable", "OnlineRouterAdapter", "DegradedMethod",
    "constant_router",
]

# oracle used for exact-recall audits: the registered brute-force
# method (masked_topk over every live row — exact by construction)
ORACLE_METHOD = "prefilter"


class QueryEvent(NamedTuple):
    """One served query, as recorded on the hot path."""
    method: str          # routed method name
    ps_id: str | None    # parameter-setting id ("" when direct search)
    pred: int            # Predicate value
    k: int
    search_us: float     # per-query share of the batch's execute time
    generation: int      # live-index generation at execute time (0 sealed)
    t_wall: float        # wall-clock seconds (time.time()) — display only
    # trailing defaulted fields keep positional construction compatible
    t_mono: float = 0.0  # time.monotonic() — ordering / duration clock
    shard: int = -1      # shard the query executed on (-1: unsharded)


class AuditSample(NamedTuple):
    """A reservoir-sampled query retained for exact-recall auditing."""
    vector: np.ndarray       # [d] float32 copy
    bitmap: np.ndarray       # [W] uint32 copy
    pred: int
    k: int
    method: str
    ps_id: str | None
    served_keys: np.ndarray  # [k] int64 stable keys the service returned
    generation: int


def _percentile(sorted_vals: np.ndarray, q: float) -> float:
    if sorted_vals.size == 0:
        return 0.0
    return float(np.percentile(sorted_vals, q))


class TelemetrySink:
    """Lock-free per-query event ring + per-cell counters + reservoir.

    Hot-path cost is one `record_batch` call per executed batch: O(Q)
    tuple constructions into ring slots claimed from an atomic counter
    (no lock), one short lock to fold per-cell aggregates, and an
    RNG draw per query for reservoir admission (vector/bitmap copies
    happen only on acceptance, so steady-state admission is nearly
    free once the reservoir has seen many queries).
    """

    def __init__(self, capacity: int = 4096, reservoir: int = 256,
                 seed: int = 0):
        if capacity <= 0 or reservoir < 0:
            raise ValueError("capacity must be > 0 and reservoir >= 0")
        self.capacity = int(capacity)
        self._ring: list[QueryEvent | None] = [None] * self.capacity
        self._seq = itertools.count()        # atomic in CPython
        # per-cell aggregates: (method, ps_id, pred) -> [queries, lat_us]
        self._cells: dict[tuple, list] = {}    # cumulative (stats)
        self._fresh: dict[tuple, list] = {}    # since last drain_cells
        # per-shard stage cells: (shard, stage) -> [calls, seconds]
        self._shards: dict[tuple[int, str], list] = {}
        self._shards_fresh: dict[tuple[int, str], list] = {}
        self._agg_lock = threading.Lock()
        self._batches = 0
        self._queries = 0
        self._counters: dict[str, float] = {}
        # reservoir (algorithm R) of AuditSamples
        self._res_size = int(reservoir)
        self._res: list[AuditSample] = []
        self._res_seen = 0
        self._res_lock = threading.Lock()
        self._rng = np.random.default_rng(seed)

    # ---------------------------------------------------------- hot path

    def record_batch(self, batch: QueryBatch, decisions, *,
                     search_s: float, generation: int = 0,
                     keys: np.ndarray | None = None,
                     shard: int = -1) -> None:
        """Record one executed batch.  `decisions` is the [Q] list of
        `RoutingDecision` (or a single (method, ps_id) applied to all
        queries); `keys` are the served [Q, k] stable keys (row ids are
        an acceptable stand-in for sealed indexes); `shard` stamps the
        events when a shard-local service records its own traffic."""
        q = batch.q
        if q == 0:
            return
        per_q_us = search_s * 1e6 / q
        now = time.time()
        now_m = time.monotonic()
        shard = int(shard)
        one = not isinstance(decisions, (list, tuple)) or (
            len(decisions) != q)
        ring, cap, seq = self._ring, self.capacity, self._seq
        local_cells: dict[tuple, list] = {}
        for i in range(q):
            d = decisions if one else decisions[i]
            ev = QueryEvent(d[0], d[1], int(batch.pred), batch.k,
                            per_q_us, generation, now, now_m, shard)
            ring[next(seq) % cap] = ev
            cell = local_cells.setdefault((d[0], d[1], int(batch.pred)),
                                          [0, 0.0])
            cell[0] += 1
            cell[1] += per_q_us
        with self._agg_lock:
            self._batches += 1
            self._queries += q
            for key, (n, us) in local_cells.items():
                for store in (self._cells, self._fresh):
                    agg = store.setdefault(key, [0, 0.0])
                    agg[0] += n
                    agg[1] += us
        if self._res_size:
            self._offer_samples(batch, decisions, one, keys, generation)

    def note(self, name: str, value: float = 1.0) -> None:
        """Fold a named scalar counter (queue waits, stage timings...)."""
        with self._agg_lock:
            self._counters[name] = self._counters.get(name, 0.0) + value

    def note_shard(self, shard: int, stage: str, seconds: float,
                   n: int = 1) -> None:
        """Fold per-shard stage time into the (shard, stage) cell —
        shard skew shows up in `stats()['shards']` and `/metrics`."""
        with self._agg_lock:
            for store in (self._shards, self._shards_fresh):
                agg = store.setdefault((int(shard), stage), [0, 0.0])
                agg[0] += n
                agg[1] += seconds

    # ------------------------------------------------------- reservoir

    def _offer_samples(self, batch, decisions, one, keys, generation):
        with self._res_lock:
            for i in range(batch.q):
                self._res_seen += 1
                if len(self._res) < self._res_size:
                    slot = len(self._res)
                    self._res.append(None)  # type: ignore[arg-type]
                else:
                    slot = int(self._rng.integers(0, self._res_seen))
                    if slot >= self._res_size:
                        continue
                d = decisions if one else decisions[i]
                served = (np.asarray(keys[i], dtype=np.int64).copy()
                          if keys is not None
                          else np.empty(0, dtype=np.int64))
                self._res[slot] = AuditSample(
                    batch.vectors[i].copy(), batch.bitmaps[i].copy(),
                    int(batch.pred), batch.k, d[0], d[1], served,
                    generation)

    def take_samples(self, clear: bool = True) -> list[AuditSample]:
        """Drain the reservoir (auditor entry point)."""
        with self._res_lock:
            out = [s for s in self._res if s is not None]
            if clear:
                self._res = []
                self._res_seen = 0
            return out

    def drain_cells(self) -> dict:
        """Per-cell {(method, ps_id, pred): (queries, mean_latency_us)}
        accumulated since the last drain — the adapter's measured-QPS
        feed.  Resets the accumulators."""
        with self._agg_lock:
            out = {k: (n, us / n) for k, (n, us) in self._fresh.items()
                   if n > 0}
            self._fresh = {}
            return out

    def drain_shards(self) -> dict:
        """Per-shard {(shard, stage): (queries, total_seconds)} since the
        last drain — the adapter's per-shard QPS feed.  Resets the fresh
        accumulators (cumulative `shard_aggregates` is untouched)."""
        with self._agg_lock:
            out = {k: (n, s) for k, (n, s) in self._shards_fresh.items()
                   if n > 0}
            self._shards_fresh = {}
            return out

    # ----------------------------------------------------------- stats

    def stats(self) -> dict:
        """Counters, per-method/cell aggregates, and latency percentiles
        computed from the event ring."""
        events = [e for e in self._ring if e is not None]
        lat = np.sort(np.array([e.search_us for e in events],
                               dtype=np.float64))
        with self._agg_lock:
            cells = {f"{m}/{ps}/{Predicate(p).name}":
                     {"queries": n, "mean_us": round(us / n, 2)}
                     for (m, ps, p), (n, us) in self._cells.items()
                     if n > 0}
            by_method: dict[str, int] = {}
            for (m, _ps, _p), (n, _us) in self._cells.items():
                by_method[m] = by_method.get(m, 0) + n
            shards = {f"shard{sh}/{stage}":
                      {"calls": n, "total_s": round(s, 6),
                       "mean_us": round(s / n * 1e6, 2)}
                      for (sh, stage), (n, s) in sorted(
                          self._shards.items()) if n > 0}
            counters = dict(self._counters)
            batches = self._batches
            queries = self._queries
        with self._res_lock:
            res = {"size": len(self._res), "seen": self._res_seen,
                   "capacity": self._res_size}
        return {
            "queries": queries,
            "batches": batches,
            "ring_events": len(events),
            "latency_us": {"p50": round(_percentile(lat, 50), 2),
                           "p90": round(_percentile(lat, 90), 2),
                           "p99": round(_percentile(lat, 99), 2)},
            "by_method": by_method,
            "cells": cells,
            "shards": shards,
            "counters": counters,
            "reservoir": res,
        }

    # raw (unformatted) aggregate accessors for exporters -----------------

    def cell_aggregates(self) -> dict:
        """{(method, ps_id, pred): (queries, total_latency_us)} copy."""
        with self._agg_lock:
            return {k: (n, us) for k, (n, us) in self._cells.items()}

    def shard_aggregates(self) -> dict:
        """{(shard, stage): (calls, total_seconds)} copy."""
        with self._agg_lock:
            return {k: (n, s) for k, (n, s) in self._shards.items()}

    def counter_values(self) -> dict:
        with self._agg_lock:
            return dict(self._counters)

    def seen_events(self) -> int:
        """Total queries recorded (monotone)."""
        with self._agg_lock:
            return self._queries

    def recent(self, n: int = 64) -> list[QueryEvent]:
        """Up to `n` most recently written events (best-effort order).
        Ordered by the monotonic stamp — wall clock can step backwards
        (NTP) and must never drive ordering or durations."""
        events = [e for e in self._ring if e is not None]
        events.sort(key=lambda e: (e.t_mono, e.t_wall))
        return events[-n:]


# --------------------------------------------------------------- auditor


def _audit_recall(served: np.ndarray, exact: np.ndarray, k: int) -> float:
    """|served ∩ exact| / min(k, |exact|); vacuous (no matching rows)
    counts as 1.0 — mirrors `dataset.recall_at_k` but over stable keys."""
    ex = set(int(x) for x in exact if x >= 0)
    if not ex:
        return 1.0
    got = set(int(x) for x in served if x >= 0)
    return len(got & ex) / min(k, len(ex))


class RecallAuditor:
    """Replays sampled queries against the brute-force oracle on a
    pinned snapshot and folds exact recall into the online table.

    The per-pass sampling budget adapts to traffic: with `sample_frac`
    set, each pass audits at most
    `clip(ceil(traffic_since_last_pass * sample_frac), min_budget,
    max_budget)` of the drained reservoir (uniform subsample), so audit
    cost tracks sink throughput instead of reservoir size — quiet
    periods still audit `min_budget` for signal, floods are capped at
    `max_budget`. The default (`sample_frac=None`) audits every drained
    sample, the pre-adaptive behaviour.

    Args:
        index: the serving handle audits replay on.
        sink: the `TelemetrySink` whose reservoir is drained.
        table: optional `OnlineBenchmarkTable` audited recall folds into.
        ds_name: table dataset key (defaults to `index.ds.name`).
        sample_frac: target audited fraction of recorded traffic per
            pass, in (0, 1]; None audits everything.
        min_budget / max_budget: hard floor / cap on the per-pass budget
            when `sample_frac` is set.
        seed: RNG seed for the uniform subsample.
        slo: optional `repro.ann.slo.SLOEngine` — every audit pass
            pushes its per-sample exact recalls into the engine's
            recall objectives (and stamps the table version as alert
            provenance), so quality regressions page.
    """

    def __init__(self, index, sink: TelemetrySink, *,
                 table: "OnlineBenchmarkTable | None" = None,
                 ds_name: str | None = None,
                 sample_frac: float | None = None,
                 min_budget: int = 8, max_budget: int = 256,
                 seed: int = 0, slo=None):
        if sample_frac is not None and not (0.0 < sample_frac <= 1.0):
            raise ValueError(
                f"sample_frac must be in (0, 1] or None; got {sample_frac}")
        if min_budget < 1 or max_budget < min_budget:
            raise ValueError(
                f"need 1 <= min_budget <= max_budget; got "
                f"{min_budget}/{max_budget}")
        self.index = index
        self.sink = sink
        self.table = table
        ds = getattr(index, "ds", None)
        self.ds_name = ds_name or (ds.name if ds is not None else "live")
        self.slo = slo
        self.sample_frac = (None if sample_frac is None
                            else float(sample_frac))
        self.min_budget = int(min_budget)
        self.max_budget = int(max_budget)
        self._budget_rng = np.random.default_rng(seed)
        self._last_seen = 0
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.last_error: BaseException | None = None
        self.audits = 0          # samples audited so far
        self.skipped = 0         # samples dropped by the budget
        self.runs = 0

    def budget_for(self, throughput: int) -> int | None:
        """Per-pass audit budget for `throughput` queries recorded since
        the last pass: `clip(ceil(throughput * sample_frac), min_budget,
        max_budget)`; None (unlimited) when `sample_frac` is unset."""
        if self.sample_frac is None:
            return None
        want = int(np.ceil(max(0, int(throughput)) * self.sample_frac))
        return int(np.clip(want, self.min_budget, self.max_budget))

    # one audit pass -----------------------------------------------------

    def run_once(self) -> dict:
        """Drain the reservoir, replay the oracle per (pred, k) group on
        one pinned snapshot, fold per-cell recall into the table.
        Returns the audit report, including per-sample results for the
        adapter's shadow-eval holdout."""
        samples = self.sink.take_samples()
        self.runs += 1
        if not samples:
            return {"samples": 0, "cells": {}, "results": [],
                    "budget": None}
        seen = self.sink.seen_events()
        budget = self.budget_for(seen - self._last_seen)
        self._last_seen = seen
        if budget is not None and len(samples) > budget:
            # uniform subsample of the drained reservoir (which is
            # itself an unbiased sample of traffic) — order-preserving
            idx = np.sort(self._budget_rng.choice(
                len(samples), size=budget, replace=False))
            self.skipped += len(samples) - budget
            samples = [samples[int(i)] for i in idx]
        groups: dict[tuple, list[AuditSample]] = {}
        for s in samples:
            groups.setdefault((s.pred, s.k), []).append(s)

        results: list[tuple[AuditSample, float, np.ndarray]] = []
        snap_fn = getattr(self.index, "snapshot", None)
        snap = snap_fn() if callable(snap_fn) else None
        try:
            for (pred, k), group in groups.items():
                batch = QueryBatch(
                    np.stack([s.vector for s in group]),
                    np.stack([s.bitmap for s in group]),
                    Predicate(pred), k)
                if snap is not None:
                    res = self.index.search(batch, ORACLE_METHOD,
                                            snapshot=snap)
                else:
                    res = self.index.search(batch, ORACLE_METHOD)
                exact = (res.keys if res.keys is not None else res.ids)
                for j, s in enumerate(group):
                    r = _audit_recall(s.served_keys, exact[j], k)
                    results.append((s, r, np.asarray(exact[j])))
        finally:
            if snap is not None:
                snap.release()

        # fold per-(method, ps, pred) mean recall into the online table
        cells: dict[tuple, list] = {}
        for s, r, _ex in results:
            c = cells.setdefault((s.method, s.ps_id, s.pred), [0, 0.0])
            c[0] += 1
            c[1] += r
        if self.table is not None:
            for (m, ps, pred), (n, tot) in cells.items():
                self.table.observe(self.ds_name, pred, m, ps,
                                   recall=tot / n, n=n)
        self.audits += len(results)
        report_cells = {f"{m}/{ps}/{Predicate(p).name}":
                        {"n": n, "recall": round(tot / n, 4)}
                        for (m, ps, p), (n, tot) in cells.items()}
        report = {"samples": len(results), "cells": report_cells,
                  "results": results, "budget": budget}
        if self.slo is not None:
            self.slo.ingest_audit(report)
            if self.table is not None:
                self.slo.note_provenance(table_version=self.table.version)
        return report

    # background loop ----------------------------------------------------

    def start(self, interval_s: float = 1.0) -> None:
        if self._thread is not None:
            return

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.run_once()
                except Exception as e:        # keep auditing on errors
                    self.last_error = e

        self._stop.clear()
        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="recall-auditor")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=30)
        self._thread = None


# ---------------------------------------------------------- online table


from repro.core.table import BenchmarkTable  # noqa: E402  (cycle-free)


class OnlineBenchmarkTable(BenchmarkTable):
    """`BenchmarkTable` with EWMA-updated cells and versioned,
    atomically-republished routing arrays.

    Writers call `observe(...)` (auditor: recall, adapter: measured
    QPS); each observation advances the version counter and invalidates
    the routing-array cache, so `routing_arrays` always reflects a
    consistent published version — Algorithm 2 consumers re-route the
    moment a cell's EWMA recall crosses the threshold `t`.
    """

    def __init__(self, base: BenchmarkTable, *, alpha: float = 0.25):
        if not (0.0 < alpha <= 1.0):
            raise ValueError("alpha must be in (0, 1]")
        super().__init__(entries=base.copy().entries)
        self._offline = base.copy().entries
        self._alpha = float(alpha)
        self._lock = threading.RLock()
        self._version = 0
        self._ra_cache: dict = {}
        # audited-EWMA per cell (drift is audited-vs-offline, tracked
        # separately so QPS-only observations don't register as drift)
        self._audited: dict[tuple, dict] = {}
        # per-shard EWMA QPS cells (ds, shard) — shard-divergent
        # throughput visible to routing and exported per shard
        self._shard_cells: dict[tuple, dict] = {}

    # properties ---------------------------------------------------------

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    @property
    def alpha(self) -> float:
        return self._alpha

    # writes -------------------------------------------------------------

    def observe(self, ds: str, pt, method: str, ps_id, *,
                recall: float | None = None, qps: float | None = None,
                n: int = 1) -> None:
        """Fold one audited measurement into cell (ds, pt, method, ps).

        EWMA per field: new = (1-a)*old + a*measured; a cell missing
        from the offline table is seeded directly with the measurement.
        The entry dict is *replaced*, never mutated, so concurrent
        readers of `entries` see either the old or the new cell.
        """
        if recall is None and qps is None:
            return
        key = (ds, int(pt), method, ps_id)
        a = self._alpha
        with self._lock:
            cur = self.entries.get(key)
            if cur is None:
                new = {"recall": float(recall if recall is not None
                                       else 0.0),
                       "qps": float(qps if qps is not None else 0.0)}
            else:
                new = dict(cur)
                if recall is not None:
                    new["recall"] = (1 - a) * cur["recall"] + a * recall
                if qps is not None:
                    new["qps"] = (1 - a) * cur["qps"] + a * qps
            self.entries[key] = new
            if recall is not None:
                st = self._audited.setdefault(
                    key, {"recall": float(recall), "n": 0})
                st["recall"] = (1 - a) * st["recall"] + a * float(recall)
                st["n"] += int(n)
            self._version += 1
            self._ra_cache.clear()

    def observe_shard(self, ds: str, shard: int, *, qps: float,
                      stage: str = "exec", n: int = 1) -> None:
        """Fold one measured per-shard QPS sample into the (ds, shard)
        EWMA cell.  Same versioning discipline as `observe`: the entry
        dict is replaced, the version advances, so exporters see a
        consistent published view."""
        key = (str(ds), int(shard), str(stage))
        a = self._alpha
        with self._lock:
            cur = self._shard_cells.get(key)
            if cur is None:
                new = {"qps": float(qps), "n": int(n)}
            else:
                new = {"qps": (1 - a) * cur["qps"] + a * float(qps),
                       "n": cur["n"] + int(n)}
            self._shard_cells[key] = new
            self._version += 1

    # reads --------------------------------------------------------------

    def shard_cells(self, ds: str | None = None) -> dict:
        """{(ds, shard, stage): {qps, n}} copy, optionally filtered."""
        with self._lock:
            return {k: dict(v) for k, v in self._shard_cells.items()
                    if ds is None or k[0] == ds}

    def shard_divergence(self, ds: str | None = None,
                         stage: str = "exec") -> float:
        """max/min EWMA QPS ratio across shards (1.0 = perfectly even;
        0.0 when fewer than two shards have cells)."""
        qps = [v["qps"] for k, v in self.shard_cells(ds).items()
               if k[2] == stage and v["qps"] > 0]
        if len(qps) < 2:
            return 0.0
        return max(qps) / min(qps)

    def routing_arrays(self, ds: str, pt, methods, t: float):
        key = (ds, int(pt), tuple(methods), float(t))
        with self._lock:
            hit = self._ra_cache.get(key)
            if hit is not None:
                return hit
            out = super().routing_arrays(ds, pt, methods, t)
            self._ra_cache[key] = out
            return out

    def drift(self) -> dict:
        """Per-cell |audited EWMA recall − offline recall| for every
        audited cell that exists in the offline table."""
        with self._lock:
            out = {}
            for key, st in self._audited.items():
                off = self._offline.get(key)
                if off is not None:
                    out[key] = abs(st["recall"] - off["recall"])
            return out

    def max_drift(self) -> float:
        d = self.drift()
        return max(d.values()) if d else 0.0

    def audited_cells(self) -> dict:
        with self._lock:
            return {k: dict(v) for k, v in self._audited.items()}

    def snapshot(self) -> BenchmarkTable:
        """Plain frozen copy of the current published entries (what a
        retrained artifact persists)."""
        with self._lock:
            return BenchmarkTable.copy(self)


# --------------------------------------------------------------- adapter


class OnlineRouterAdapter:
    """Drift-triggered background retrain with shadow-eval promotion.

    `attach` swaps the service's table for an `OnlineBenchmarkTable`
    (re-routing is then immediate and table-driven).  Each `step()`:

    1. runs one audit pass (exact recall folds into the table) and
       accumulates audited queries into disjoint train / holdout pools;
    2. folds measured QPS from the sink's per-cell latency aggregates;
    3. if `max_drift()` >= `drift_threshold` and enough samples have
       accumulated, retrains the MLP off the serving path on
       audit-derived per-method recall labels, shadow-evaluates the
       candidate vs the incumbent on the held-out pool, and promotes
       only on improvement — saving a *new* versioned artifact dir,
       validating `artifact_versions`, linking it into the `IndexStore`
       manifest (atomic rename), and swapping `service.router` in one
       reference assignment.  On no improvement, the candidate is
       discarded and the old artifact keeps serving (rollback).
    """

    def __init__(self, service, sink: TelemetrySink, *,
                 store=None, artifact_root: str | None = None,
                 alpha: float = 0.25, drift_threshold: float = 0.05,
                 min_samples: int = 16, holdout_frac: float = 0.5,
                 retrain_epochs: int = 60, retrain_hidden=(32, 16),
                 seed: int = 0, retrain_fn=None, ds_name=None, slo=None):
        self.service = service
        self.sink = sink
        self.store = store
        self.drift_threshold = float(drift_threshold)
        self.min_samples = int(min_samples)
        self.holdout_frac = float(holdout_frac)
        self.retrain_epochs = int(retrain_epochs)
        self.retrain_hidden = tuple(retrain_hidden)
        self.retrain_fn = retrain_fn
        self._seed = int(seed)
        self._rng = np.random.default_rng(seed)
        if artifact_root is None and store is not None:
            artifact_root = os.path.join(str(store.path), "routers")
        self.artifact_root = artifact_root
        self.table = OnlineBenchmarkTable(service.router.table,
                                          alpha=alpha)
        # atomic table swap: MLRouter is a plain mutable dataclass and
        # routing reads go through router.table per call
        service.router.table = self.table
        self.auditor = RecallAuditor(service.index, sink,
                                     table=self.table, ds_name=ds_name,
                                     slo=slo)
        self.ds_name = self.auditor.ds_name
        self._train: list = []      # (sample, recall, exact_keys)
        self._holdout: list = []
        self._pool_cap = 512
        self.promotions = 0
        self.history: list[dict] = []
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.last_error: BaseException | None = None

    # ------------------------------------------------------------- step

    def step(self) -> dict:
        """One adaptation round; returns a report dict."""
        with self._lock:
            return self._step_locked()

    def _step_locked(self) -> dict:
        audit = self.auditor.run_once()
        for rec in audit["results"]:
            pool = (self._holdout if self._rng.random() <
                    self.holdout_frac else self._train)
            pool.append(rec)
            if len(pool) > self._pool_cap:
                pool.pop(0)
        # measured QPS from the hot-path aggregates (pt comes from the
        # cell key — one table cell per (method, ps, predicate type))
        for (m, ps, pred), (_n, mean_us) in self.sink.drain_cells().items():
            if mean_us > 0:
                self.table.observe(self.ds_name, pred, m, ps,
                                   qps=1e6 / mean_us)
        # per-shard telemetry aggregates -> shard-keyed EWMA table cells
        # (shard-divergent QPS becomes visible to routing + /metrics)
        for (sh, stage), (n, sec) in self.sink.drain_shards().items():
            if sec > 0:
                self.table.observe_shard(self.ds_name, sh, qps=n / sec,
                                         stage=stage, n=n)
        drift = self.table.max_drift()
        report = {"samples": audit["samples"],
                  "audited": self.auditor.audits,
                  "max_drift": round(drift, 4),
                  "table_version": self.table.version,
                  "retrained": False, "promoted": False}
        if (drift >= self.drift_threshold
                and len(self._train) >= self.min_samples
                and len(self._holdout) >= max(4, self.min_samples // 4)):
            report.update(self._retrain_and_maybe_promote())
        self.history.append(report)
        return report

    # ---------------------------------------------------------- retrain

    def _retrain_and_maybe_promote(self) -> dict:
        fn = self.retrain_fn or self._default_retrain
        candidate = fn(self)
        out: dict = {"retrained": True, "promoted": False}
        if candidate is None:
            return out
        old_r, new_r = self._shadow_eval(candidate)
        out["shadow"] = {"incumbent_recall": round(old_r, 4),
                         "candidate_recall": round(new_r, 4)}
        if new_r > old_r + 1e-9:
            out.update(self._promote(candidate))
            out["promoted"] = True
        else:
            out["action"] = "rollback"   # old artifact keeps serving
        return out

    def _default_retrain(self, _self=None):
        """Retrain the per-method MLPs on audit-derived labels: each
        training query is replayed through every candidate method at its
        max-recall setting on a pinned snapshot, exact recall vs the
        audit oracle becomes y[:, j].  Runs entirely off the serving
        path."""
        from repro.core import features as F
        from repro.core.training import train_models_from_xy

        router = self.service.router
        index = self.service.index
        ds = getattr(index, "ds", None)
        if ds is None or not self._train:
            return None
        samples = list(self._train)
        methods = list(router.methods)
        # group queries by (pred, k) so replays batch
        groups: dict[tuple, list] = {}
        for rec in samples:
            groups.setdefault((rec[0].pred, rec[0].k), []).append(rec)
        xs, ys = [], []
        snap_fn = getattr(index, "snapshot", None)
        snap = snap_fn() if callable(snap_fn) else None
        try:
            for (pred, k), group in groups.items():
                qb = QueryBatch(np.stack([r[0].vector for r in group]),
                                np.stack([r[0].bitmap for r in group]),
                                Predicate(pred), k)
                x = F.feature_matrix(ds, qb.bitmaps, qb.pred,
                                     router.feature_names, fx=index)
                y = np.zeros((len(group), len(methods)), dtype=np.float64)
                for j, m in enumerate(methods):
                    hit = self.table.max_recall_setting(
                        self.ds_name, pred, m)
                    ps = hit[0] if hit else None
                    kw = {"snapshot": snap} if snap is not None else {}
                    res = index.search(qb, m, ps, **kw)
                    got = res.keys if res.keys is not None else res.ids
                    for qi, rec in enumerate(group):
                        y[qi, j] = _audit_recall(got[qi], rec[2], k)
                xs.append(x)
                ys.append(y)
        finally:
            if snap is not None:
                snap.release()
        x_raw = np.concatenate(xs, axis=0)
        y_all = np.concatenate(ys, axis=0)
        models, scaler = train_models_from_xy(
            x_raw, y_all, methods, seed=self._seed + 17 * self.promotions,
            hidden=self.retrain_hidden, epochs=self.retrain_epochs)
        return router.retrained(models, scaler, table=self.table)

    # ------------------------------------------------------ shadow eval

    def _shadow_eval(self, candidate) -> tuple[float, float]:
        """Mean exact recall of incumbent vs candidate on the held-out
        audited pool (both routed through throwaway services with no
        telemetry, so shadow traffic never pollutes the sink)."""
        from repro.ann.service import RouterService

        svc = self.service
        old = RouterService(svc.index, svc.router, t=svc.t,
                            methods=svc.methods)
        new = RouterService(svc.index, candidate, t=svc.t,
                            methods=svc.methods)
        groups: dict[tuple, list] = {}
        for rec in self._holdout:
            groups.setdefault((rec[0].pred, rec[0].k), []).append(rec)
        tot = [0.0, 0.0]
        n = 0
        for (pred, k), group in groups.items():
            qb = QueryBatch(np.stack([r[0].vector for r in group]),
                            np.stack([r[0].bitmap for r in group]),
                            Predicate(pred), k)
            for slot, s in enumerate((old, new)):
                res = s.search(qb)
                got = res.keys if res.keys is not None else res.ids
                for qi, rec in enumerate(group):
                    tot[slot] += _audit_recall(got[qi], rec[2], k)
            n += len(group)
        return tot[0] / n, tot[1] / n

    # --------------------------------------------------------- promote

    def _promote(self, candidate) -> dict:
        """Persist the candidate as a *new* versioned artifact dir,
        validate `artifact_versions`, atomically link it into the store
        manifest, then swap the serving reference."""
        out: dict = {}
        if self.artifact_root is not None:
            os.makedirs(self.artifact_root, exist_ok=True)
            v = self.promotions + 1
            path = os.path.join(self.artifact_root, f"router-v{v:03d}")
            while os.path.exists(path):
                v += 1
                path = os.path.join(self.artifact_root,
                                    f"router-v{v:03d}")
            # persist with a frozen table snapshot, then re-attach the
            # live online table for serving
            from repro.core.router import artifact_versions

            candidate.table = self.table.snapshot()
            try:
                candidate.save(path)
            finally:
                candidate.table = self.table
            versions = artifact_versions(path)
            out["artifact"] = path
            out["versions"] = versions
            if self.store is not None:
                self.store.link_router(path)
        candidate.table = self.table
        self.service.router = candidate      # atomic reference swap
        self.promotions += 1
        return out

    # ------------------------------------------------- background loop

    def start(self, interval_s: float = 2.0) -> None:
        if self._thread is not None:
            return

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.step()
                except Exception as e:
                    self.last_error = e

        self._stop.clear()
        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="router-adapter")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=60)
        self._thread = None


# ------------------------------------------------- drift fault injection


class DegradedMethod:
    """Wraps a registered `Method` and truncates its results to the
    first `keep` of k — an injected recall regression that only the
    audit loop can see (the method still *returns* k-shaped arrays, so
    nothing crashes; recall just drops). Used by the adaptation tests
    and `benchmarks/bench_telemetry.py` to measure time-to-reroute."""

    def __init__(self, inner, keep: int = 3):
        self._inner = inner
        self._keep = int(keep)
        self.name = inner.name

    def param_settings(self):
        return self._inner.param_settings()

    def build(self, ds, build_params):
        return self._inner.build(ds, build_params)

    def index_arrays(self, index):
        return self._inner.index_arrays(index)

    def index_from_arrays(self, ds, build_params, arrays):
        return self._inner.index_from_arrays(ds, build_params, arrays)

    def search(self, fx, index, qvecs, qbms, pred, k, search_params):
        ids, raw = self._inner.search(fx, index, qvecs, qbms, pred, k,
                                      search_params)
        ids = np.array(ids, copy=True)
        raw = np.array(raw, copy=True)
        if ids.shape[1] > self._keep:
            ids[:, self._keep:] = -1
            raw[:, self._keep:] = np.inf
        return ids, raw


def constant_router(feature_names, methods: list, table,
                    value: float = 0.95):
    """An `MLRouter` whose every prediction is exactly `value` (one
    zero-weight linear layer, identity scaler). With `value >= t` every
    method is in Algorithm 2's candidate set, so routing is decided
    purely by the benchmark table — the deterministic harness the
    adaptation tests and benches use to make re-routing table-driven."""
    from repro.core import mlp
    from repro.core.router import MLRouter

    nf = 0
    for name in feature_names:
        nf += 3 if name == "pred" else 1
    models = {m: [{"w": np.zeros((nf, 1), np.float32),
                   "b": np.full((1,), value, np.float32)}]
              for m in methods}
    scaler = mlp.Scaler(np.zeros(nf), np.ones(nf))
    return MLRouter(feature_names=list(feature_names), methods=methods,
                    models=models, scaler=scaler, table=table)
