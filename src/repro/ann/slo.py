"""Declarative SLOs with multi-window multi-burn-rate alerting.

The router trades recall for QPS; an operator needs *both* sides held
to explicit objectives.  This module evaluates three objective kinds
over sliding windows of good/bad observations:

* ``latency`` — a request is *bad* when its per-query latency exceeds
  ``threshold_us`` (a p99 SLO of 2 ms at target 0.99 reads: "≤1 % of
  queries slower than 2 ms").
* ``recall`` — an audited sample is *bad* when its exact recall falls
  below ``floor``.  Fed by :class:`repro.ann.telemetry.RecallAuditor`
  (``slo=`` hookup), so silent quality sag pages before users notice.
* ``availability`` — a request is *bad* when it errored.

Alerting follows the Google-SRE multi-window multi-burn-rate recipe:
for an objective with target ``T`` the error *budget* is ``1 - T``;
the **burn rate** of a window is ``bad_fraction / budget`` (1.0 means
"spending the budget exactly on schedule").  An alert pair
``(long_s, short_s, factor)`` fires only when *both* windows burn at
≥ ``factor``: the long window gives significance, the short window
confirms the problem is still happening (fast reset once fixed).

Every :class:`Alert` carries provenance: the flight-recorder trace ids
live at fire time and the latest noted routing/table version, so the
page links straight to evidence.

Windows are bucketed monotonic-time rings (``bucket_s`` granularity),
so observation cost is O(objectives) per batch and memory is bounded
by ``horizon / bucket_s``.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

__all__ = ["Objective", "Alert", "SLOEngine", "DEFAULT_WINDOWS"]

# (long_s, short_s, factor) pairs — the classic SRE page/ticket ladder
# compressed to serving-bench timescales (hours, not days).
DEFAULT_WINDOWS: tuple = ((3600.0, 300.0, 14.4), (21600.0, 1800.0, 6.0))


@dataclass(frozen=True)
class Objective:
    """One declarative objective.

    ``target`` is the good-fraction target (0.999 = "three nines");
    the error budget is ``1 - target``.  ``kind`` selects which
    observations feed it; ``pred`` (optional, recall/latency) restricts
    the objective to one predicate type, mirroring the paper's finding
    that quality degrades per predicate regime, not uniformly.
    """

    name: str
    kind: str                       # "latency" | "recall" | "availability"
    target: float
    threshold_us: float | None = None   # latency: bad above this
    floor: float | None = None          # recall: bad below this
    pred: int | None = None             # restrict to one predicate type
    description: str = ""

    def __post_init__(self):
        if self.kind not in ("latency", "recall", "availability"):
            raise ValueError(f"unknown objective kind: {self.kind!r}")
        if not 0.0 < self.target < 1.0:
            raise ValueError("target must be in (0, 1)")
        if self.kind == "latency" and self.threshold_us is None:
            raise ValueError("latency objective needs threshold_us")
        if self.kind == "recall" and self.floor is None:
            raise ValueError("recall objective needs floor")

    @property
    def budget(self) -> float:
        return 1.0 - self.target


@dataclass
class Alert:
    """One firing transition, with evidence attached."""

    objective: str
    kind: str
    t_wall: float
    window: tuple                   # (long_s, short_s, factor) that fired
    burn_long: float
    burn_short: float
    bad_frac_long: float
    budget: float
    trace_ids: list = field(default_factory=list)
    provenance: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"objective": self.objective, "kind": self.kind,
                "t_wall": self.t_wall,
                "window": {"long_s": self.window[0],
                           "short_s": self.window[1],
                           "factor": self.window[2]},
                "burn_long": round(self.burn_long, 3),
                "burn_short": round(self.burn_short, 3),
                "bad_frac_long": round(self.bad_frac_long, 5),
                "budget": self.budget,
                "trace_ids": list(self.trace_ids),
                "provenance": dict(self.provenance)}


class _Window:
    """Bucketed good/bad ring over monotonic time."""

    __slots__ = ("bucket_s", "horizon_buckets", "buckets")

    def __init__(self, bucket_s: float, horizon_s: float):
        self.bucket_s = float(bucket_s)
        self.horizon_buckets = max(int(horizon_s / bucket_s) + 2, 4)
        # list of [bucket_idx, good, bad]; append-only at the tail,
        # evicted at the head once past the horizon
        self.buckets: list[list] = []

    def observe(self, now: float, good: int, bad: int) -> None:
        idx = int(now / self.bucket_s)
        b = self.buckets
        if b and b[-1][0] == idx:
            b[-1][1] += good
            b[-1][2] += bad
        else:
            b.append([idx, good, bad])
            floor = idx - self.horizon_buckets
            while b and b[0][0] < floor:
                b.pop(0)

    def totals(self, now: float, window_s: float) -> tuple[int, int]:
        """(good, bad) inside the trailing ``window_s`` seconds."""
        lo = int((now - window_s) / self.bucket_s)
        good = bad = 0
        for idx, g, x in reversed(self.buckets):
            if idx <= lo:
                break
            good += g
            bad += x
        return good, bad


class SLOEngine:
    """Sliding-window SLO evaluation + burn-rate alerting.

    Args:
        objectives: the declarative targets.
        windows: ``(long_s, short_s, factor)`` alert pairs, shared by
            all objectives.
        bucket_s: observation bucket granularity.
        min_events: a window with fewer observations than this can't
            fire (protects cold starts from one unlucky request).
        tracer: optional — alerts snapshot its flight-recorder trace
            ids as evidence.
        provenance: optional zero-arg callable merged into each alert's
            provenance at fire time (e.g. the live table version).
        clock: injectable monotonic clock for deterministic tests.
    """

    def __init__(self, objectives, *, windows: tuple = DEFAULT_WINDOWS,
                 bucket_s: float = 1.0, min_events: int = 10,
                 tracer=None, provenance: Callable[[], dict] | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.objectives: list[Objective] = list(objectives)
        if not self.objectives:
            raise ValueError("need at least one objective")
        names = [o.name for o in self.objectives]
        if len(set(names)) != len(names):
            raise ValueError("objective names must be unique")
        self.windows = tuple((float(l), float(s), float(f))
                             for (l, s, f) in windows)
        if any(s >= l for (l, s, _f) in self.windows):
            raise ValueError("short window must be < long window")
        self.min_events = int(min_events)
        self.tracer = tracer
        self._provenance = provenance
        self._clock = clock
        self._mu = threading.Lock()
        horizon = max(l for (l, _s, _f) in self.windows)
        self._win = {o.name: _Window(bucket_s, horizon)
                     for o in self.objectives}
        self._firing: dict[str, bool] = {o.name: False
                                         for o in self.objectives}
        self._noted: dict[str, Any] = {}
        self._alerts: list[Alert] = []
        self._evals = 0
        self._observed = {o.name: 0 for o in self.objectives}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- observation (hot path: O(objectives) dict/list ops) ---------------
    def observe_batch(self, q: int, *, per_query_us: float | None = None,
                      errors: int = 0, pred: int | None = None) -> None:
        """Fold one served batch: ``q`` requests at ``per_query_us``
        each (the batch's per-query share), ``errors`` of them failed."""
        now = self._clock()
        q = int(q)
        errors = int(errors)
        with self._mu:
            for o in self.objectives:
                if o.pred is not None and pred is not None \
                        and o.pred != pred:
                    continue
                if o.kind == "latency" and per_query_us is not None:
                    ok = q - errors
                    bad = ok if per_query_us > o.threshold_us else 0
                    self._win[o.name].observe(now, ok - bad, bad)
                    self._observed[o.name] += ok
                elif o.kind == "availability":
                    self._win[o.name].observe(now, q - errors, errors)
                    self._observed[o.name] += q

    def observe_request(self, latency_us: float, *, error: bool = False,
                        pred: int | None = None) -> None:
        """Single-request convenience wrapper over ``observe_batch``."""
        self.observe_batch(1, per_query_us=latency_us,
                           errors=1 if error else 0, pred=pred)

    def observe_recall(self, recall: float, *, pred: int | None = None,
                       n: int = 1) -> None:
        """Fold an audited-recall measurement into recall objectives."""
        now = self._clock()
        with self._mu:
            for o in self.objectives:
                if o.kind != "recall":
                    continue
                if o.pred is not None and pred is not None \
                        and o.pred != pred:
                    continue
                bad = n if recall < o.floor else 0
                self._win[o.name].observe(now, n - bad, bad)
                self._observed[o.name] += n

    def ingest_audit(self, report: dict) -> None:
        """Consume a ``RecallAuditor.run_once`` report: one recall
        observation per audited sample, tagged with its predicate."""
        for sample, recall, _exact in report.get("results", ()):
            self.observe_recall(float(recall),
                                pred=int(getattr(sample, "pred", -1)))

    def note_provenance(self, **kv) -> None:
        """Stamp latest-seen provenance (e.g. ``table_version=…``)
        merged into any alert that fires later."""
        with self._mu:
            self._noted.update(kv)

    # -- evaluation --------------------------------------------------------
    def _burn(self, o: Objective, now: float, window_s: float
              ) -> tuple[float, float, int]:
        good, bad = self._win[o.name].totals(now, window_s)
        total = good + bad
        if total == 0:
            return 0.0, 0.0, 0
        frac = bad / total
        return frac / o.budget, frac, total

    def evaluate(self) -> dict:
        """Run one evaluation pass; fires/clears alerts, returns
        per-objective status (also served at ``/debug/slo``)."""
        now = self._clock()
        new_alerts: list[Alert] = []
        with self._mu:
            self._evals += 1
            status: dict[str, dict] = {}
            for o in self.objectives:
                fired_window = None
                detail: dict[str, Any] = {"kind": o.kind,
                                          "target": o.target,
                                          "budget": o.budget,
                                          "observed": self._observed[o.name]}
                pairs = []
                for (long_s, short_s, factor) in self.windows:
                    bl, fl, nl = self._burn(o, now, long_s)
                    bs, _fs, ns = self._burn(o, now, short_s)
                    pairs.append({"long_s": long_s, "short_s": short_s,
                                  "factor": factor,
                                  "burn_long": round(bl, 3),
                                  "burn_short": round(bs, 3),
                                  "events_long": nl})
                    if (fired_window is None and nl >= self.min_events
                            and ns >= 1 and bl >= factor
                            and bs >= factor):
                        fired_window = ((long_s, short_s, factor),
                                        bl, bs, fl)
                detail["windows"] = pairs
                firing = fired_window is not None
                if firing and not self._firing[o.name]:
                    win, bl, bs, fl = fired_window
                    trace_ids = []
                    if self.tracer is not None:
                        trace_ids = [r.get("trace_id")
                                     for r in self.tracer.flight()
                                     if r.get("trace_id")]
                    prov = dict(self._noted)
                    if self._provenance is not None:
                        try:
                            prov.update(self._provenance())
                        except Exception:
                            pass
                    new_alerts.append(Alert(
                        objective=o.name, kind=o.kind, t_wall=time.time(),
                        window=win, burn_long=bl, burn_short=bs,
                        bad_frac_long=fl, budget=o.budget,
                        trace_ids=trace_ids, provenance=prov))
                self._firing[o.name] = firing
                detail["firing"] = firing
                status[o.name] = detail
            self._alerts.extend(new_alerts)
        return status

    # -- inspection --------------------------------------------------------
    def state(self) -> str:
        """Compact serve-time state: ``"ok"`` or ``"firing:a,b"`` —
        cheap enough to stamp on every wide event."""
        with self._mu:
            firing = [n for n, f in self._firing.items() if f]
        return "firing:" + ",".join(sorted(firing)) if firing else "ok"

    def alerts(self) -> list[Alert]:
        with self._mu:
            return list(self._alerts)

    def status(self) -> dict:
        """Full JSON-able status for ``/debug/slo`` and post-mortems."""
        snap = self.evaluate()
        with self._mu:
            return {"t_wall": time.time(),
                    "state": ("firing:" + ",".join(
                        sorted(n for n, f in self._firing.items() if f))
                        if any(self._firing.values()) else "ok"),
                    "evaluations": self._evals,
                    "objectives": snap,
                    "alerts": [a.to_dict() for a in self._alerts]}

    def stats(self) -> dict:
        with self._mu:
            return {"evaluations": self._evals,
                    "alerts": len(self._alerts),
                    "firing": sum(self._firing.values()),
                    "observed": dict(self._observed)}

    # -- background evaluation --------------------------------------------
    def start(self, interval_s: float = 5.0) -> None:
        """Evaluate on a daemon thread every ``interval_s``."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.evaluate()
                except Exception:  # pragma: no cover - never kill serving
                    pass

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="slo-eval")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
