"""Request-scoped hierarchical tracing for the serving stack.

A *trace* is one tree of :class:`Span` nodes rooted at a request (a
routed search, a queue micro-batch, a cache probe).  The design goals,
in order:

1. **Zero cost when off.**  Instrumented layers call the module-level
   :func:`span` / :func:`annotate` unconditionally; both are no-ops
   (one ``ContextVar.get`` returning ``None``) unless an enclosing
   trace is active.  Layers below the service (live index, store)
   therefore need no tracer reference at all.
2. **Explicit cross-thread propagation.**  ``contextvars`` do *not*
   flow into worker threads spawned before the request, so thread hops
   (the async queue's pipeline executor, per-shard thread pools)
   re-enter a tree with :func:`attach`.
3. **Tail-based sampling.**  :meth:`Tracer.finish` always keeps traces
   that breached the slow threshold or errored (into the flight
   recorder) and head-samples the rest with probability ``sample``;
   per-span latency histograms update for *every* trace regardless of
   the sampling verdict, so `/metrics` stays unbiased.

Exports render a finished tree as Chrome-trace/Perfetto JSON
(:func:`perfetto_json`) — overlapping siblings (parallel shard fan-out)
are pushed onto separate ``tid`` lanes so every lane is properly
nested, which is what trace viewers require of ``"ph": "X"`` events.
"""

from __future__ import annotations

import contextvars
import itertools
import json
import math
import random
import threading
import time
from collections import deque
from typing import Any, Iterator

__all__ = [
    "Span",
    "Tracer",
    "span",
    "annotate",
    "count",
    "attach",
    "current",
    "trace_id",
    "maybe_trace",
    "perfetto_json",
    "BUCKET_BOUNDS_US",
    "LatencyHistogram",
]

_ACTIVE: "contextvars.ContextVar[Span | None]" = contextvars.ContextVar(
    "repro_ann_active_span", default=None)

# Attribute keys hoisted from any span of a kept tree into the flight
# record's flat ``annotations`` dict (first writer wins).
_ANNOT_KEYS = ("decisions", "table_version", "cache", "generation", "shards")


def _jsonable(v: Any) -> Any:
    """Best-effort conversion of span attributes to JSON-safe values."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, set, frozenset)):
        return [_jsonable(x) for x in v]
    item = getattr(v, "item", None)  # numpy scalars
    if callable(item):
        try:
            return _jsonable(item())
        except Exception:
            pass
    return str(v)


class Span:
    """One timed node in a trace tree.  Times are ``time.monotonic()``
    seconds; ``t1 is None`` marks a still-open span.  Children may be
    appended from other threads (list.append is atomic under the GIL);
    the owner closes stragglers at :meth:`Tracer.finish`."""

    __slots__ = ("name", "t0", "t1", "attrs", "children", "error",
                 "trace_id")

    def __init__(self, name: str, attrs: dict | None = None,
                 t0: float | None = None):
        self.name = name
        self.t0 = time.monotonic() if t0 is None else float(t0)
        self.t1: float | None = None
        self.attrs: dict = dict(attrs) if attrs else {}
        self.children: list[Span] = []
        self.error: str | None = None
        self.trace_id: str | None = None

    # -- construction ------------------------------------------------------
    def child(self, name: str, *, t0: float | None = None,
              t1: float | None = None, **attrs) -> "Span":
        """Append a child; pass explicit bounds for spans reconstructed
        after the fact (e.g. enqueue-wait measured from submit time)."""
        s = Span(name, attrs, t0=t0)
        if t1 is not None:
            s.t1 = float(t1)
        s.trace_id = self.trace_id
        self.children.append(s)
        return s

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def finish(self, t1: float | None = None) -> "Span":
        if self.t1 is None:
            self.t1 = time.monotonic() if t1 is None else float(t1)
        return self

    # -- inspection --------------------------------------------------------
    @property
    def duration_s(self) -> float:
        return max(0.0, (self.t1 if self.t1 is not None else self.t0)
                   - self.t0)

    def walk(self) -> Iterator["Span"]:
        stack = [self]
        while stack:
            s = stack.pop()
            yield s
            stack.extend(s.children)

    def find(self, name: str) -> "Span | None":
        for s in self.walk():
            if s.name == name:
                return s
        return None

    def to_dict(self, origin: float | None = None) -> dict:
        origin = self.t0 if origin is None else origin
        d: dict = {"name": self.name,
                   "t0_ms": round((self.t0 - origin) * 1e3, 4),
                   "dur_ms": round(self.duration_s * 1e3, 4)}
        if self.trace_id is not None:
            d["trace_id"] = self.trace_id
        if self.attrs:
            d["attrs"] = _jsonable(self.attrs)
        if self.error:
            d["error"] = self.error
        if self.children:
            d["children"] = [c.to_dict(origin) for c in self.children]
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, dur={self.duration_s * 1e3:.3f}ms, "
                f"children={len(self.children)})")


# ---------------------------------------------------------------------------
# Ambient-context API (no-ops outside an active trace)
# ---------------------------------------------------------------------------

class _SpanCtx:
    __slots__ = ("_name", "_attrs", "_span", "_token")

    def __init__(self, name: str, attrs: dict):
        self._name = name
        self._attrs = attrs
        self._span: Span | None = None
        self._token = None

    def __enter__(self) -> Span | None:
        parent = _ACTIVE.get()
        if parent is None:
            return None
        s = Span(self._name, self._attrs)
        s.trace_id = parent.trace_id
        parent.children.append(s)
        self._span = s
        self._token = _ACTIVE.set(s)
        return s

    def __exit__(self, et, ev, tb) -> bool:
        s = self._span
        if s is None:
            return False
        if et is not None and s.error is None:
            s.error = f"{et.__name__}: {ev}"
        s.finish()
        _ACTIVE.reset(self._token)
        return False


def span(name: str, **attrs) -> _SpanCtx:
    """Open a child span under the ambient trace; no-op (yields ``None``)
    when no trace is active, so call sites need no enabled-check."""
    return _SpanCtx(name, attrs)


def current() -> Span | None:
    return _ACTIVE.get()


def trace_id() -> str | None:
    """Trace id of the ambient trace, ``None`` outside one (or for a
    root created without a `Tracer`).  The id is assigned at the root
    and inherited by every child span, so any layer can stamp logs or
    resource leases with the request it served."""
    s = _ACTIVE.get()
    return s.trace_id if s is not None else None


def annotate(**attrs) -> None:
    """Attach attributes to the innermost active span, if any."""
    s = _ACTIVE.get()
    if s is not None:
        s.attrs.update(attrs)


def count(name: str, n: int = 1) -> None:
    """Increment a numeric attribute on the innermost active span."""
    s = _ACTIVE.get()
    if s is not None:
        s.attrs[name] = s.attrs.get(name, 0) + n


class _Attach:
    __slots__ = ("_span", "_token")

    def __init__(self, s: Span | None):
        self._span = s
        self._token = None

    def __enter__(self) -> Span | None:
        if self._span is not None:
            self._token = _ACTIVE.set(self._span)
        return self._span

    def __exit__(self, et, ev, tb) -> bool:
        if self._token is not None:
            _ACTIVE.reset(self._token)
        return False


def attach(s: Span | None) -> _Attach:
    """Re-enter a span's context on another thread (explicit propagation
    across the queue's pipeline executor / shard pools).  ``attach(None)``
    is a no-op, so call sites can pass an optional root unconditionally."""
    return _Attach(s)


class _RootCtx:
    __slots__ = ("_tracer", "_name", "_attrs", "_root", "_token")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._root: Span | None = None
        self._token = None

    def __enter__(self) -> Span:
        self._root = Span(self._name, self._attrs)
        self._root.trace_id = self._tracer.new_trace_id()
        self._token = _ACTIVE.set(self._root)
        return self._root

    def __exit__(self, et, ev, tb) -> bool:
        _ACTIVE.reset(self._token)
        root = self._root
        if et is not None and root.error is None:
            root.error = f"{et.__name__}: {ev}"
        self._tracer.finish(root)
        return False


def maybe_trace(tracer: "Tracer | None", name: str, **attrs):
    """Nest under the ambient trace if one is active (e.g. the cache or
    queue already opened a root); else open a fresh root on ``tracer``;
    else no-op.  This is how stacked facades produce *one* tree."""
    if _ACTIVE.get() is not None:
        return _SpanCtx(name, attrs)
    if tracer is not None:
        return tracer.trace(name, **attrs)
    return _Attach(None)  # inert context manager yielding None


# ---------------------------------------------------------------------------
# Latency histograms — fixed log2 buckets, independent of any ring size
# ---------------------------------------------------------------------------

# Upper bounds in microseconds: 2^0 .. 2^24 (≈16.8 s), then +Inf.
BUCKET_BOUNDS_US: tuple = tuple(float(1 << i) for i in range(25)) + (math.inf,)


def bucket_index(us: float) -> int:
    if us <= 1.0:
        return 0
    i = (int(math.ceil(us)) - 1).bit_length()
    return i if i < len(BUCKET_BOUNDS_US) - 1 else len(BUCKET_BOUNDS_US) - 1


class LatencyHistogram:
    """Counts per log2-µs bucket plus sum/count, Prometheus-compatible."""

    __slots__ = ("counts", "sum_us", "count")

    def __init__(self):
        self.counts = [0] * len(BUCKET_BOUNDS_US)
        self.sum_us = 0.0
        self.count = 0

    def observe(self, us: float) -> None:
        self.counts[bucket_index(us)] += 1
        self.sum_us += us
        self.count += 1

    def snapshot(self) -> dict:
        return {"bounds_us": BUCKET_BOUNDS_US, "counts": list(self.counts),
                "sum_us": self.sum_us, "count": self.count}

    def quantile_us(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the hit bucket)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return BUCKET_BOUNDS_US[i]
        return BUCKET_BOUNDS_US[-1]


# ---------------------------------------------------------------------------
# Tracer: sampling, flight recorder, histograms
# ---------------------------------------------------------------------------

class Tracer:
    """Owns finished-trace policy: per-span histograms (always), the
    flight recorder (slow/error traces, bounded ring), and head
    sampling for the rest.

    ``slow_ms=None`` disables the threshold (nothing is "slow");
    ``sample`` in [0, 1] is the keep probability for ordinary traces.
    Thread-safe: ``finish`` may be called from any worker thread.
    """

    def __init__(self, *, slow_ms: float | None = None, sample: float = 1.0,
                 flight_capacity: int = 32, recent_capacity: int = 64,
                 seed: int = 0):
        if flight_capacity <= 0:
            raise ValueError("flight_capacity must be positive")
        self.slow_ms = None if slow_ms is None else float(slow_ms)
        self.sample = float(sample)
        self._lock = threading.Lock()
        self._recent: deque[Span] = deque(maxlen=int(recent_capacity))
        self._flight: deque[dict] = deque(maxlen=int(flight_capacity))
        self._hist: dict[str, LatencyHistogram] = {}
        self._seq = itertools.count()
        self._rng = random.Random(seed)
        # separate stream for ids: drawing them from the sampling rng
        # would shift the tail-sampling sequence under a fixed seed
        self._id_rng = random.Random((int(seed) << 1) ^ 0x9E3779B9)
        self._id_seq = itertools.count(1)
        self._counters = {"traces": 0, "kept": 0, "dropped": 0,
                          "slow": 0, "errors": 0}

    # -- roots -------------------------------------------------------------
    def new_trace_id(self) -> str:
        """Deterministic-under-seed unique id: ordinal + random tag."""
        with self._lock:
            return (f"t{next(self._id_seq):06d}-"
                    f"{self._id_rng.getrandbits(32):08x}")

    def start(self, name: str, **attrs) -> Span:
        """Create a detached root; the caller attaches/finishes it
        explicitly (queue-style, where the root outlives one thread)."""
        s = Span(name, attrs)
        s.trace_id = self.new_trace_id()
        return s

    def trace(self, name: str, **attrs) -> _RootCtx:
        """Context manager: root + ambient attach + finish-on-exit."""
        return _RootCtx(self, name, attrs)

    def finish(self, root: Span, *, error: str | None = None) -> None:
        """Close a tree and apply the tail-sampling verdict."""
        if error is not None and root.error is None:
            root.error = str(error)
        root.finish()
        t1 = root.t1
        err = None
        annot: dict = {}
        spans = list(root.walk())
        for s in spans:
            if s.t1 is None:      # straggler (e.g. exception skipped exit)
                s.t1 = t1
            if err is None and s.error:
                err = s.error
            for k in _ANNOT_KEYS:
                if k in s.attrs and k not in annot:
                    annot[k] = s.attrs[k]
        dur_ms = root.duration_s * 1e3
        slow = self.slow_ms is not None and dur_ms >= self.slow_ms
        with self._lock:
            c = self._counters
            c["traces"] += 1
            for s in spans:
                h = self._hist.get(s.name)
                if h is None:
                    h = self._hist[s.name] = LatencyHistogram()
                h.observe(s.duration_s * 1e6)
            if err is not None:
                c["errors"] += 1
            if slow:
                c["slow"] += 1
            if slow or err is not None:
                c["kept"] += 1
                self._flight.append({
                    "seq": next(self._seq),
                    "trace_id": root.trace_id,
                    "t_wall": time.time(),
                    "duration_ms": dur_ms,
                    "reason": "error" if err is not None else "slow",
                    "error": err,
                    "annotations": _jsonable(annot),
                    "root": root,
                })
                self._recent.append(root)
            elif self._rng.random() < self.sample:
                c["kept"] += 1
                self._recent.append(root)
            else:
                c["dropped"] += 1

    # -- inspection --------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            out = dict(self._counters)
            out["flight_size"] = len(self._flight)
            out["span_p50_us"] = {n: h.quantile_us(0.5)
                                  for n, h in self._hist.items()}
        return out

    def histograms(self) -> dict:
        with self._lock:
            return {n: h.snapshot() for n, h in self._hist.items()}

    def recent(self) -> list[Span]:
        with self._lock:
            return list(self._recent)

    def flight(self) -> list[dict]:
        """Flight-recorder entries, oldest first (roots are live Spans)."""
        with self._lock:
            return list(self._flight)

    def clear(self) -> None:
        with self._lock:
            self._recent.clear()
            self._flight.clear()
            self._hist.clear()
            for k in self._counters:
                self._counters[k] = 0

    # -- dumps -------------------------------------------------------------
    def dump_flight_json(self, path: str | None = None, *,
                         indent: int | None = 2) -> str:
        recs = self.flight()
        payload = [{**{k: v for k, v in r.items() if k != "root"},
                    "trace": r["root"].to_dict()} for r in recs]
        text = json.dumps({"flight": payload}, indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def perfetto_json(self, roots=None, *, indent: int | None = None) -> str:
        if roots is None:
            roots = [r["root"] for r in self.flight()] or self.recent()
        return perfetto_json(roots, indent=indent)


# ---------------------------------------------------------------------------
# Chrome-trace / Perfetto export
# ---------------------------------------------------------------------------

def _lane_events(root: Span, origin: float, tid_counter,
                 events: list[dict]) -> None:
    """Emit ``"ph": "X"`` events for one tree.  Children are clamped into
    their parent's bounds, and siblings that overlap in time (parallel
    fan-out) move to fresh ``tid`` lanes — every lane then satisfies the
    viewer's stack discipline (events on a lane nest or are disjoint)."""

    root_tid = next(tid_counter)

    def emit(s: Span, tid: int, lo: float, hi: float) -> None:
        t0 = min(max(s.t0, lo), hi)
        t1 = min(max(s.t1 if s.t1 is not None else t0, t0), hi)
        ev = {"name": s.name, "ph": "X", "pid": 0, "tid": tid,
              "ts": round((t0 - origin) * 1e6, 3),
              "dur": round((t1 - t0) * 1e6, 3)}
        args = _jsonable(s.attrs) if s.attrs else {}
        if s.error:
            args = dict(args)
            args["error"] = s.error
        if args:
            ev["args"] = args
        events.append(ev)
        # Greedy lane assignment for the children: lane 0 is the
        # parent's own tid (nested rendering); overflow lanes get
        # fresh tids from the shared counter.
        lanes: list[tuple[int, float]] = [(tid, -math.inf)]
        for c in sorted(s.children, key=lambda x: x.t0):
            c0 = min(max(c.t0, t0), t1)
            c1 = min(max(c.t1 if c.t1 is not None else c0, c0), t1)
            for i, (ltid, lend) in enumerate(lanes):
                if c0 >= lend:
                    lanes[i] = (ltid, c1)
                    emit(c, ltid, c0, c1)
                    break
            else:
                ltid = next(tid_counter)
                lanes.append((ltid, c1))
                emit(c, ltid, c0, c1)

    emit(root, root_tid, root.t0,
         root.t1 if root.t1 is not None else root.t0)


def perfetto_json(roots, *, indent: int | None = None) -> str:
    """Serialise one Span tree (or an iterable of them) as Chrome-trace
    JSON (µs timestamps, complete events) loadable in Perfetto."""
    if isinstance(roots, Span):
        roots = [roots]
    roots = list(roots)
    if not roots:
        return json.dumps({"traceEvents": [], "displayTimeUnit": "ms"})
    origin = min(r.t0 for r in roots)
    events: list[dict] = []
    tid_counter = itertools.count()
    for r in roots:
        _lane_events(r, origin, tid_counter, events)
    return json.dumps({"traceEvents": events, "displayTimeUnit": "ms"},
                      indent=indent)
