"""Measurement harness: run (dataset × predicate × method × param-setting),
recording per-query recall@k and wall-clock QPS — the raw material for the
offline benchmark table B and the router training set."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.ann import engine
from repro.ann.dataset import ANNDataset, QuerySet, recall_at_k
from repro.ann.predicates import Predicate


@dataclasses.dataclass
class RunResult:
    dataset: str
    pred: int
    method: str
    ps_id: str
    recall_per_query: np.ndarray   # [Q]
    mean_recall: float
    qps: float
    latency_s: float
    ids: np.ndarray                # [Q, k]


def run_method(ds: ANNDataset, method: engine.Method, setting,
               qs: QuerySet, *, warmup: bool = True) -> RunResult:
    index = engine.get_index(method, ds, setting.build)
    sp = setting.search_dict
    if warmup:  # exclude jit compile from the QPS measurement
        method.search(ds, index, qs.vectors[:8], qs.bitmaps[:8], qs.pred,
                      qs.k, sp)
    t0 = time.perf_counter()
    ids = method.search(ds, index, qs.vectors, qs.bitmaps, qs.pred, qs.k, sp)
    dt = time.perf_counter() - t0
    rec = recall_at_k(ids, qs.ground_truth)
    return RunResult(
        dataset=ds.name, pred=int(qs.pred), method=method.name,
        ps_id=setting.ps_id, recall_per_query=rec,
        mean_recall=float(rec.mean()), qps=qs.q / max(dt, 1e-9),
        latency_s=dt, ids=ids)


def sweep(ds: ANNDataset, methods: dict, qs: QuerySet) -> list[RunResult]:
    out = []
    for m in methods.values():
        for setting in m.param_settings():
            out.append(run_method(ds, m, setting, qs))
    return out
