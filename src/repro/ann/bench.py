"""Measurement harness: run (dataset × predicate × method × param-setting),
recording per-query recall@k and wall-clock QPS — the raw material for the
offline benchmark table B and the router training set.

Runs against a `FilteredIndex` handle (owned device tensors + built
indexes); passing a bare `ANNDataset` still works via the shared default
pool."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.ann import engine
from repro.ann.dataset import QuerySet, recall_at_k
from repro.ann.index import QueryBatch, as_index


@dataclasses.dataclass
class RunResult:
    dataset: str
    pred: int
    method: str
    ps_id: str
    recall_per_query: np.ndarray   # [Q]
    mean_recall: float
    qps: float
    latency_s: float
    ids: np.ndarray                # [Q, k]
    dists: np.ndarray              # [Q, k] ranking scores (+inf at −1 pad)


def run_method(fx, method: engine.Method, setting,
               qs: QuerySet, *, warmup: bool = True) -> RunResult:
    fx = as_index(fx)
    batch = QueryBatch.from_queryset(qs)
    if warmup:  # exclude jit compile (and index build) from the QPS timing
        fx.run_method(method, setting, batch.take(np.arange(min(8, qs.q))))
    t0 = time.perf_counter()
    ids, dists = fx.run_method(method, setting, batch)
    dt = time.perf_counter() - t0
    rec = recall_at_k(ids, qs.ground_truth)
    return RunResult(
        dataset=fx.ds.name, pred=int(qs.pred), method=method.name,
        ps_id=setting.ps_id, recall_per_query=rec,
        mean_recall=float(rec.mean()), qps=qs.q / max(dt, 1e-9),
        latency_s=dt, ids=ids, dists=dists)


def sweep(fx, methods: dict, qs: QuerySet) -> list[RunResult]:
    fx = as_index(fx)
    out = []
    for m in methods.values():
        for setting in m.param_settings():
            out.append(run_method(fx, m, setting, qs))
    return out
